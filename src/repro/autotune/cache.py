"""Persistent tuning cache.

On-disk format (JSON, human-editable):

    {
      "version": 1,
      "entries": {
        "dot|n=4096|float32|jnp|single": {
          "kernel": "dot",
          "params": {"block": 4096, "leaf": "vpu"},
          "source": "measured",            # or "analytic"
          "cost_s": 4.1e-06,               # analytic prediction, seconds
          "measured_us": 12.3,             # chosen candidate, if measured
          "timings": {"block=4096,leaf=vpu": 12.3, ...},
          "shape": {"n": 4096}
        }, ...
      }
    }

Keys are ``kernel|shape|dtype|backend|mesh``; every component the compiled
artefact depends on is in the key, so serving never has to re-search — a hit
is always safe to reuse.  Writes are atomic (tmp + rename) and corrupted or
version-skewed files are treated as empty rather than fatal.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Dict, Optional

VERSION = 1

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"


def default_path() -> str:
    env = os.environ.get(_ENV_PATH)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def make_key(kernel: str, shape: Dict[str, object], dtype: str = "float32",
             backend: str = "jnp", mesh: str = "single",
             layout: str = "dense") -> str:
    """``kernel|shape|dtype|backend|mesh[|layout]`` — the serving KV layout
    joins the key like the mesh descriptor, but only when it departs from
    the default, so every pre-paged cache entry keeps its address."""
    shape_s = ",".join(f"{k}={shape[k]}" for k in sorted(shape))
    key = f"{kernel}|{shape_s}|{dtype}|{backend}|{mesh}"
    if layout and layout != "dense":
        key += f"|layout={layout}"
    return key


class TuningCache:
    """JSON-backed tuning cache with in-process memoisation."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_path()
        self._lock = threading.Lock()
        self._mem: Dict[str, dict] = {}
        self._loaded = False

    # -- disk ---------------------------------------------------------------
    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            with open(self.path) as f:
                doc = json.load(f)
            if isinstance(doc, dict) and doc.get("version") == VERSION:
                entries = doc.get("entries", {})
                if isinstance(entries, dict):
                    # disk never overrides fresher in-process results
                    for k, v in entries.items():
                        self._mem.setdefault(k, v)
        except (OSError, ValueError):
            pass  # missing or corrupt cache: start empty

    def _save(self) -> None:
        doc = {"version": VERSION, "entries": self._mem}
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".autotune-", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    # -- API ----------------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            self._load()
            return self._mem.get(key)

    def put(self, key: str, record: dict) -> None:
        with self._lock:
            self._load()
            self._mem[key] = record
            self._save()

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def __len__(self) -> int:
        with self._lock:
            self._load()
            return len(self._mem)

    def keys(self):
        with self._lock:
            self._load()
            return sorted(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            self._loaded = True
            self._save()


_default: Optional[TuningCache] = None
_default_lock = threading.Lock()


def default_cache() -> TuningCache:
    """Process-wide cache at ``$REPRO_AUTOTUNE_CACHE`` or ~/.cache/repro/."""
    global _default
    with _default_lock:
        if _default is None or _default.path != default_path():
            _default = TuningCache()
        return _default
