"""Mesh-level DPIA strategies for the tuned kernel set.

Each builder returns ``(expr, arg_vars)`` like ``repro.kernels.dpia_blas``,
but with the *top* map/reduce bound to a named mesh axis
(:class:`repro.mesh.MeshStrategy` vocabulary):

  dot / asum      reduce-form — ``reduce[mesh(ax)]`` over per-shard partial
                  reductions: the lowered HLO contains exactly one
                  ``all-reduce`` (psum), dictated by the term;
  scal / rmsnorm / softmax / matmul
                  map-form — ``map[mesh(ax)]`` over ``split`` shards the
                  leading extent; the small operands (alpha, w, B) stay
                  replicated; outputs come back sharded over the axis.

``block`` / ``row_block`` / ``bk`` optionally give each shard the familiar
single-device grid/sequential blocking *inside* the mesh level — the chunk
factor of the mesh strategy space — compiled by the inner backend exactly as
on one device.  All builders are pure term constructors: no mesh object is
needed, only the shard count, so the autotuner can enumerate candidates from
a cache descriptor alone.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.dpia import phrases as P
from repro.core.dpia.types import Arr, Num
from repro.kernels.dpia_blas import _softmax_row

Expr = P.Phrase

__all__ = ["mesh_dot", "mesh_asum", "mesh_scal", "mesh_rmsnorm",
           "mesh_softmax", "mesh_matmul", "MESH_KERNELS"]


def _chunk_of(extent: int, shards: int, what: str) -> int:
    if shards < 1 or extent % shards != 0:
        raise ValueError(f"{what}: extent {extent} not divisible into "
                         f"{shards} mesh shards")
    return extent // shards


def _reduce_leaf(op_block: str, block: Optional[int], chunk: int):
    """Per-shard body for the reduce-form kernels: one whole-chunk VPU
    FullReduce, or grid-blocked partials combined sequentially."""
    def leaf(elem):
        if op_block == "abs":
            return P.UnOp("abs", elem)
        return P.mul(P.Fst(elem), P.Snd(elem))

    def body(blk):
        if block is None or block >= chunk:
            return P.FullReduce("add", leaf(blk))
        return P.Reduce(
            lambda x, a: P.add(a, x), P.lit(0.0),
            P.Map(lambda b2: P.FullReduce("add", leaf(b2)),
                  P.Split(block, blk), level=P.GRID(0)),
            level=P.SEQ)
    return body


def mesh_dot(n: int, axis: str, shards: int, block: Optional[int] = None
             ) -> Tuple[Expr, List[P.Var]]:
    """Distributed dot: mesh-map partial dots + one mesh reduce (psum)."""
    chunk = _chunk_of(n, shards, "mesh_dot")
    xs = P.var_exp("xs", Arr(n, Num()))
    ys = P.var_exp("ys", Arr(n, Num()))
    e = P.Reduce(
        lambda x, a: P.add(a, x), P.lit(0.0),
        P.Map(_reduce_leaf("mul", block, chunk),
              P.Split(chunk, P.Zip(xs, ys)), level=P.MESH(axis)),
        level=P.MESH(axis))
    return e, [xs, ys]


def mesh_asum(n: int, axis: str, shards: int, block: Optional[int] = None
              ) -> Tuple[Expr, List[P.Var]]:
    """Distributed asum: per-shard |x| partial sums + one mesh reduce."""
    chunk = _chunk_of(n, shards, "mesh_asum")
    xs = P.var_exp("xs", Arr(n, Num()))
    e = P.Reduce(
        lambda x, a: P.add(a, x), P.lit(0.0),
        P.Map(_reduce_leaf("abs", block, chunk),
              P.Split(chunk, xs), level=P.MESH(axis)),
        level=P.MESH(axis))
    return e, [xs]


def mesh_scal(n: int, axis: str, shards: int, block: Optional[int] = None
              ) -> Tuple[Expr, List[P.Var]]:
    """Sharded scal: each shard scales its chunk; alpha is replicated."""
    chunk = _chunk_of(n, shards, "mesh_scal")
    alpha = P.var_exp("alpha", Num())
    xs = P.var_exp("xs", Arr(n, Num()))

    def body(blk):
        if block is None or block >= chunk:
            return P.mul(alpha, blk)
        return P.Join(P.Map(lambda b2: P.mul(alpha, b2),
                            P.Split(block, blk), level=P.GRID(0)))

    e = P.Join(P.Map(body, P.Split(chunk, xs), level=P.MESH(axis)))
    return e, [alpha, xs]


def _rows_body(per_row, row_block: Optional[int], chunk: int):
    def body(blk):
        if row_block is None or row_block >= chunk:
            return P.Map(per_row, blk, level=P.SEQ)
        return P.Join(P.Map(
            lambda rb: P.Map(per_row, rb, level=P.SEQ),
            P.Split(row_block, blk), level=P.GRID(0)))
    return body


def mesh_rmsnorm(rows: int, d: int, eps: float = 1e-6, *, axis: str,
                 shards: int, row_block: Optional[int] = None
                 ) -> Tuple[Expr, List[P.Var]]:
    """Row-sharded rmsnorm: rows split over the axis, weights replicated."""
    chunk = _chunk_of(rows, shards, "mesh_rmsnorm")
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    w = P.var_exp("w", Arr(d, Num()))

    def per_row(row):
        ss = P.FullReduce("add", P.mul(row, row))
        inv = P.UnOp("rsqrt", P.add(P.div(ss, P.lit(float(d))), P.lit(eps)))
        return P.mul(P.mul(row, inv), w)

    e = P.Join(P.Map(_rows_body(per_row, row_block, chunk),
                     P.Split(chunk, xs), level=P.MESH(axis)))
    return e, [xs, w]


def mesh_softmax(rows: int, d: int, *, axis: str, shards: int,
                 row_block: Optional[int] = None) -> Tuple[Expr, List[P.Var]]:
    """Row-sharded softmax (rows are independent, so no collective at all)."""
    chunk = _chunk_of(rows, shards, "mesh_softmax")
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    e = P.Join(P.Map(_rows_body(_softmax_row, row_block, chunk),
                     P.Split(chunk, xs), level=P.MESH(axis)))
    return e, [xs]


def mesh_matmul(m: int, k: int, n: int, *, axis: str, shards: int,
                bk: Optional[int] = None) -> Tuple[Expr, List[P.Var]]:
    """Row-sharded matmul: A's rows split over the axis, B replicated on
    every shard (the replicate side of replicate-vs-reduce; the contraction
    stays shard-local so no collective is emitted).  ``bk`` optionally blocks
    the contraction per shard as in ``dpia_blas.strategy_matmul``."""
    chunk = _chunk_of(m, shards, "mesh_matmul")
    a = P.var_exp("A", Arr(m, Arr(k, Num())))
    b = P.var_exp("B", Arr(k, Arr(n, Num())))

    def body(ablk):
        if bk is None or bk >= k:
            return P.DotBlock(ablk, b)
        zipped = P.Zip(P.Split(bk, P.Transpose(ablk)), P.Split(bk, b))
        return P.Reduce(
            lambda ab, acc: P.add(
                acc, P.DotBlock(P.Transpose(P.Fst(ab)), P.Snd(ab))),
            P.Lit(0.0, Arr(chunk, Arr(n, Num()))),
            zipped, level=P.SEQ)

    e = P.Join(P.Map(body, P.Split(chunk, a), level=P.MESH(axis)))
    return e, [a, b]


# kernel name -> (builder(shape..., axis=, shards=, <chunk param>), the
# logical extent the mesh axis shards) — the dispatch table mesh.space and
# kernels.ops build candidates from
MESH_KERNELS = {
    "dot": (lambda axis, shards, block=None, *, n:
            mesh_dot(n, axis, shards, block), "n"),
    "asum": (lambda axis, shards, block=None, *, n:
             mesh_asum(n, axis, shards, block), "n"),
    "scal": (lambda axis, shards, block=None, *, n:
             mesh_scal(n, axis, shards, block), "n"),
    "rmsnorm": (lambda axis, shards, row_block=None, *, rows, d, eps=1e-6:
                mesh_rmsnorm(rows, d, eps, axis=axis, shards=shards,
                             row_block=row_block), "rows"),
    "softmax": (lambda axis, shards, row_block=None, *, rows, d:
                mesh_softmax(rows, d, axis=axis, shards=shards,
                             row_block=row_block), "rows"),
    "matmul": (lambda axis, shards, bk=None, *, m, k, n:
               mesh_matmul(m, k, n, axis=axis, shards=shards, bk=bk), "m"),
}
