"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data, with checkpoint/resume and NaN guards active.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.resilience import TrainLoop
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.train.step import make_train_state, make_train_step, state_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L x 768d (GPT-2-small-ish, with GQA + SwiGLU)
    cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                      vocab=32000, dtype="float32", remat=False,
                      max_seq=args.seq)
    model = Model(cfg)
    print(f"params: {cfg.param_count()/1e6:.1f}M")

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    state = make_train_state(model, jax.random.PRNGKey(0))
    st_spec = state_specs(state, mesh, cfg)
    _, jit_with, _ = make_train_step(model, mesh, base_lr=6e-4,
                                     warmup=50, total_steps=args.steps)
    train_step = jit_with(st_spec)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}")

    def wrapped(state, batch):
        return train_step(state,
                          {k: jnp.asarray(v) for k, v in batch.items()})

    t0 = time.time()
    loop = TrainLoop(wrapped, ckpt, data, ckpt_every=100)
    loop.run(state, num_steps=args.steps, on_metrics=on_metrics)
    dt = time.time() - t0
    first = np.mean(losses[:20]) if len(losses) >= 20 else losses[0]
    last = np.mean(losses[-20:])
    print(f"\n{args.steps} steps in {dt:.0f}s; "
          f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING OK' if last < first - 0.1 else 'no movement?'})")


if __name__ == "__main__":
    main()
