"""One human-readable system report: metrics + provenance + drift + recorder.

Two modes:

* **Live** — :func:`render` summarises the *current process* (the in-memory
  metrics registry, provenance log, drift auditor, and flight-recorder
  tail).  Engines and benches can print it at shutdown.

* **Artefact** — ``python -m repro.obs.report`` renders previously exported
  files::

      python -m repro.obs.report --metrics serve-metrics.json
      python -m repro.obs.report --flight flight-dumps/           # dir or file
      python -m repro.obs.report --trace serve-trace.json --request r3
      python -m repro.obs.report --history BENCH_history.json

  ``--request`` stitches the per-request timeline out of a Chrome trace:
  every span/instant whose args carry that ``req_id`` (or list it in
  ``req_ids``), ordered by timestamp — queue wait, TTFT, chunks, faults,
  retries, and the terminal state in one view.

Everything here is read-only rendering; the heavy imports are lazy so the
CLI works on artefacts without touching jax.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

__all__ = ["render", "render_metrics", "render_drift", "render_dump",
           "render_history", "request_timeline", "main"]


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "-"
    a = abs(v)
    if a != 0 and (a < 1e-3 or a >= 1e6):
        return f"{v:.3g}"
    return f"{v:.4g}"


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def render_metrics(snap: Dict[str, dict], prefix: str = "") -> str:
    """Counters/gauges one per line; histograms with count/mean/p50/p95/p99."""
    names = [n for n in sorted(snap) if n.startswith(prefix)]
    if not names:
        return "metrics — none recorded"
    lines = [f"metrics — {len(names)} instruments"]
    w = max(len(n) for n in names)
    for n in names:
        m = snap[n]
        t = m.get("type")
        if t == "histogram":
            lines.append(
                f"  {n:<{w}}  n={m.get('count', 0):<6} "
                f"mean={_fmt(m.get('mean'))} p50={_fmt(m.get('p50'))} "
                f"p95={_fmt(m.get('p95'))} p99={_fmt(m.get('p99'))} "
                f"max={_fmt(m.get('max'))}")
        else:
            lines.append(f"  {n:<{w}}  {_fmt(m.get('value'))}")
    return "\n".join(lines)


def render_drift(doc: dict) -> str:
    """The drift auditor's snapshot() as a table of keys + findings."""
    keys = doc.get("keys") or {}
    ranking = doc.get("ranking") or {}
    if not keys and not ranking:
        return "drift audit — no observations"
    lines = [f"drift audit — {len(keys)} watched keys, "
             f"{doc.get('fired', 0)} fired "
             f"(tolerance {doc.get('tolerance')}x)"]
    for k in sorted(keys):
        st = keys[k]
        flag = " DRIFTED" if st.get("fired") else ""
        lines.append(f"  {k}: n={st.get('n')} "
                     f"drift={_fmt(st.get('drift_x'))}x{flag}")
    for k in sorted(ranking):
        f = ranking[k]
        lines.append(f"  {k}: MIS-RANKED — roofline prefers "
                     f"[{f.get('predicted_best')}] but "
                     f"[{f.get('measured_best')}] measured "
                     f"{_fmt(f.get('slowdown_x'))}x faster")
    return "\n".join(lines)


def render_dump(doc: dict) -> str:
    """One flight-recorder dump: reason, ctx, and the last ring entries."""
    ctx = doc.get("ctx") or {}
    ctx_s = " ".join(f"{k}={v}" for k, v in sorted(ctx.items()))
    events = doc.get("events") or []
    lines = [f"flight dump #{doc.get('seq', '?')} — "
             f"reason={doc.get('reason')}"
             + (f" ({ctx_s})" if ctx_s else "")
             + f" — {len(events)} ring entries"]
    for e in events[-20:]:
        kind = e.get("kind", "?")
        detail = ""
        if kind == "span":
            detail = f" dur={_fmt(e.get('dur_us'))}us"
            if e.get("error"):
                detail += f" error={e['error']}"
        elif kind == "metric":
            detail = f" +{_fmt(e.get('delta'))}"
        args = e.get("args") or {}
        if args:
            detail += " " + " ".join(f"{k}={v}"
                                     for k, v in sorted(args.items()))
        lines.append(f"  [{kind:<6}] {e.get('name')}{detail}")
    drift = doc.get("drift") or {}
    if drift.get("keys") or drift.get("ranking"):
        lines.append(render_drift(drift))
    return "\n".join(lines)


def render_history(entries: List[dict]) -> str:
    """The committed BENCH_history.json trajectory, one line per run."""
    if not entries:
        return "bench history — empty"
    lines = [f"bench history — {len(entries)} runs"]
    for e in entries:
        serve = e.get("serve") or {}
        faults = (e.get("resilience") or {}).get("faults_injected", "-")
        lines.append(
            f"  {e.get('t', '?')}: "
            f"fused={_fmt(serve.get('fused_tok_s'))} tok/s "
            f"continuous={_fmt(serve.get('continuous_tok_s'))} tok/s "
            f"recompiles={e.get('recompiles', '-')} "
            f"drift={e.get('drift', '-')} faults={faults}")
    return "\n".join(lines)


def request_timeline(events: List[dict], req_id: str) -> str:
    """Stitch one request's timeline from Chrome trace events: everything
    whose args carry ``req_id`` or list it in ``req_ids``."""
    mine = []
    for e in events:
        args = e.get("args") or {}
        rid = str(args.get("req_id", ""))
        rids = str(args.get("req_ids", ""))
        if rid == req_id or req_id in [r for r in rids.split(",") if r]:
            mine.append(e)
    if not mine:
        return f"request {req_id} — no events (was tracing enabled?)"
    mine.sort(key=lambda e: e.get("ts", 0.0))
    t0 = mine[0].get("ts", 0.0)
    lines = [f"request {req_id} — {len(mine)} events"]
    for e in mine:
        dt = (e.get("ts", 0.0) - t0) / 1e3            # us -> ms
        dur = f" ({e['dur'] / 1e3:.2f} ms)" if "dur" in e else ""
        args = e.get("args") or {}
        extra = " ".join(f"{k}={v}" for k, v in sorted(args.items())
                         if k not in ("req_id", "req_ids", "parent"))
        lines.append(f"  +{dt:9.2f} ms  {e.get('name')}{dur}"
                     + (f"  {extra}" if extra else ""))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# live mode
# ---------------------------------------------------------------------------

def render(tail: int = 12) -> str:
    """The current process: metrics, provenance, drift, recorder tail."""
    from . import audit, metrics, provenance, recorder
    parts = ["== repro system report ==",
             render_metrics(metrics.snapshot()),
             provenance.log().explain(),
             render_drift(audit.auditor().snapshot())]
    entries = recorder.tail(tail)
    lines = [f"flight recorder — {len(recorder.recorder)} entries ringed, "
             f"{len(recorder.dumps())} dumps"]
    for e in entries:
        lines.append(f"  [{e.get('kind', '?'):<6}] {e.get('name')}")
    parts.append("\n".join(lines))
    return "\n\n".join(parts)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _load(path: str):
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render observability artefacts (or the live process) "
                    "as one human-readable report.")
    p.add_argument("--metrics", help="metrics snapshot JSON")
    p.add_argument("--flight", help="flight-recorder dump file, or a "
                                    "directory of flight-*.json dumps")
    p.add_argument("--trace", help="Chrome trace JSON (for --request)")
    p.add_argument("--request", help="render one request's timeline from "
                                     "--trace")
    p.add_argument("--history", help="BENCH_history.json trajectory")
    p.add_argument("--live", action="store_true",
                   help="render the current process state")
    args = p.parse_args(argv)

    out: List[str] = []
    if args.metrics:
        out.append(render_metrics(_load(args.metrics)))
    if args.flight:
        paths = [args.flight]
        if os.path.isdir(args.flight):
            paths = sorted(
                os.path.join(args.flight, n)
                for n in os.listdir(args.flight)
                if n.startswith("flight-") and n.endswith(".json"))
        if not paths:
            out.append(f"flight dumps — none under {args.flight}")
        for path in paths:
            out.append(render_dump(_load(path)))
    if args.request:
        if not args.trace:
            p.error("--request needs --trace")
        doc = _load(args.trace)
        out.append(request_timeline(doc.get("traceEvents", []),
                                    args.request))
    if args.history:
        out.append(render_history(_load(args.history)))
    if args.live or not out:
        out.append(render())
    print("\n\n".join(out))
    return 0


if __name__ == "__main__":
    sys.exit(main())
