"""Persistent tuning cache.

On-disk format (JSON, human-editable):

    {
      "version": 1,
      "entries": {
        "dot|n=4096|float32|jnp|single": {
          "kernel": "dot",
          "params": {"block": 4096, "leaf": "vpu"},
          "source": "measured",            # or "analytic"
          "cost_s": 4.1e-06,               # analytic prediction, seconds
          "measured_us": 12.3,             # chosen candidate, if measured
          "timings": {"block=4096,leaf=vpu": 12.3, ...},
          "shape": {"n": 4096}
        }, ...
      }
    }

Keys are ``kernel|shape|dtype|backend|mesh``; every component the compiled
artefact depends on is in the key, so serving never has to re-search — a hit
is always safe to reuse.

The store is self-healing (``repro.ft.artefacts``): writes are atomic
(tmp + rename) and carry an embedded content checksum; a corrupt FILE is
quarantined to ``<path>.quarantine/`` and reported (warn-once log +
always-on ``artefact.load_failed`` counter), and a corrupt ENTRY —
well-formed file, malformed record — is quarantined individually
(``artefact.entry_quarantined``) while the healthy entries load.  Either
way the next ``tune()`` sees a miss and rebuilds the lost decisions;
nothing is ever silently dropped, and nothing aborts the load.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional

from repro.ft import artefacts

VERSION = 1

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"


def default_path() -> str:
    env = os.environ.get(_ENV_PATH)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def make_key(kernel: str, shape: Dict[str, object], dtype: str = "float32",
             backend: str = "jnp", mesh: str = "single",
             layout: str = "dense") -> str:
    """``kernel|shape|dtype|backend|mesh[|layout]`` — the serving KV layout
    joins the key like the mesh descriptor, but only when it departs from
    the default, so every pre-paged cache entry keeps its address."""
    shape_s = ",".join(f"{k}={shape[k]}" for k in sorted(shape))
    key = f"{kernel}|{shape_s}|{dtype}|{backend}|{mesh}"
    if layout and layout != "dense":
        key += f"|layout={layout}"
    return key


class TuningCache:
    """JSON-backed tuning cache with in-process memoisation."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_path()
        self._lock = threading.Lock()
        self._mem: Dict[str, dict] = {}
        self._loaded = False

    # -- disk ---------------------------------------------------------------

    @staticmethod
    def _valid_record(v) -> bool:
        """Shape check for one entry: a dict whose ``params`` (when present)
        is a dict — the contract ``kernels.ops``/``autotune.get_tuned``
        rely on.  Anything else is a corrupt record."""
        return isinstance(v, dict) and isinstance(v.get("params", {}), dict)

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        doc = artefacts.load_json(self.path, what="tuning cache")
        if doc is None:
            return  # missing (cold) or corrupt (quarantined + reported)
        if doc.get("version") != VERSION:
            return  # version skew: expected after an upgrade, start empty
        entries = doc.get("entries", {})
        if not isinstance(entries, dict):
            return
        bad = {k: v for k, v in entries.items() if not self._valid_record(v)}
        if bad:
            # entry-level self-healing: park the malformed records beside
            # the cache, keep the healthy ones, and let the next tune()
            # rebuild what was lost
            from repro import obs
            qdir = self.path + ".quarantine"
            qpath = None
            try:
                os.makedirs(qdir, exist_ok=True)
                qpath = os.path.join(
                    qdir, f"entries-{abs(hash(tuple(sorted(bad)))):x}.json")
                with open(qpath, "w") as f:
                    json.dump(bad, f, indent=1, sort_keys=True, default=str)
            except OSError:
                qpath = None
            obs.counter("artefact.entry_quarantined").inc(len(bad))
            artefacts.report_load_failure(
                self.path, "tuning cache",
                ValueError(f"{len(bad)} malformed entr"
                           f"{'y' if len(bad) == 1 else 'ies'}: "
                           f"{sorted(bad)[:4]}"), qpath)
        # disk never overrides fresher in-process results
        for k, v in entries.items():
            if k not in bad:
                self._mem.setdefault(k, v)

    def _save(self) -> None:
        doc = {"version": VERSION, "entries": self._mem}
        try:
            artefacts.save_json(self.path, doc)
        except OSError:
            pass  # persistence is best-effort; the in-process memo stands

    # -- API ----------------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            self._load()
            return self._mem.get(key)

    def put(self, key: str, record: dict) -> None:
        with self._lock:
            self._load()
            self._mem[key] = record
            self._save()

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def __len__(self) -> int:
        with self._lock:
            self._load()
            return len(self._mem)

    def keys(self):
        with self._lock:
            self._load()
            return sorted(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            self._loaded = True
            self._save()


_default: Optional[TuningCache] = None
_default_lock = threading.Lock()


def default_cache() -> TuningCache:
    """Process-wide cache at ``$REPRO_AUTOTUNE_CACHE`` or ~/.cache/repro/."""
    global _default
    with _default_lock:
        if _default is None or _default.path != default_path():
            _default = TuningCache()
        return _default
