"""Model assembly: blocks, scan-over-layers, the Model API (train fwd / loss /
prefill / decode) for all four architecture families.

Families:
  dense / moe / vlm / audio — pre-norm GQA transformer (+ MoE FFN);
    vlm (chameleon): early-fusion discrete tokens, frontend stubbed to ids;
    audio (musicgen): n_codebooks embeddings summed (EnCodec frontend stub).
  hybrid (zamba2) — mamba2 backbone with a *shared* attention block applied
    every ``attn_every`` layers (one set of attn weights, G call sites).
  ssm (rwkv6) — attention-free time-mix/channel-mix.

Layers are stacked and scanned (compact HLO at 512 devices); blocks are
rematerialised when cfg.remat.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ffn as ffn_mod
from . import mamba2 as mamba_mod
from . import rwkv6 as rwkv_mod
from .attention import AttnParams, KVCache
from .common import ModelConfig, cross_entropy, init_dense


# ---------------------------------------------------------------------------
# dense / moe block
# ---------------------------------------------------------------------------

class BlockParams(NamedTuple):
    ln1: jax.Array
    attn: AttnParams
    ln2: jax.Array
    mlp: Any  # MlpParams | MoeParams


def _init_block(key, cfg: ModelConfig) -> BlockParams:
    k1, k2 = jax.random.split(key)
    mlp = (ffn_mod.init_moe(k2, cfg) if cfg.n_experts
           else ffn_mod.init_mlp(k2, cfg))
    return BlockParams(
        ln1=jnp.ones((cfg.d_model,), cfg.dtype),
        attn=attn_mod.init_attn(k1, cfg),
        ln2=jnp.ones((cfg.d_model,), cfg.dtype),
        mlp=mlp)


def _block_fwd(p: BlockParams, cfg: ModelConfig, x, positions):
    from .common import rmsnorm
    from repro.sharding import ctx
    # sequence parallelism: residual-stream activations live seq-sharded over
    # 'model' between blocks, so the TP boundary collective is a
    # reduce-scatter instead of a full all-reduce (half the bytes; the
    # all-gather happens where heads/ff need the full sequence)
    x = ctx.constraint(x, ctx.dp_axes(), "model", None)
    h = x + attn_mod.attention(p.attn, cfg, rmsnorm(x, p.ln1, cfg.norm_eps),
                               positions)
    h = ctx.constraint(h, ctx.dp_axes(), "model", None)
    y = rmsnorm(h, p.ln2, cfg.norm_eps)
    if cfg.n_experts:
        from . import moe_ep
        if moe_ep.applicable(cfg, ctx.get_mesh()):
            # explicit all-to-all EP exchange (EXPERIMENTS.md Perf, dbrx it.5)
            out, aux = moe_ep.moe_ep(p.mlp, cfg, y)
        else:
            out, aux = ffn_mod.moe(p.mlp, cfg, y)
    else:
        out, aux = ffn_mod.mlp(p.mlp, y), jnp.zeros((), jnp.float32)
    return h + out, aux


# ---------------------------------------------------------------------------
# rwkv6 block
# ---------------------------------------------------------------------------

class RwkvBlockParams(NamedTuple):
    ln1: jax.Array
    ln2: jax.Array
    mix: rwkv_mod.Rwkv6Params


def _init_rwkv_block(key, cfg: ModelConfig) -> RwkvBlockParams:
    return RwkvBlockParams(
        ln1=jnp.ones((cfg.d_model,), cfg.dtype),
        ln2=jnp.ones((cfg.d_model,), cfg.dtype),
        mix=rwkv_mod.init_rwkv6(key, cfg))


def _rwkv_block_fwd(p: RwkvBlockParams, cfg: ModelConfig, x,
                    state: rwkv_mod.Rwkv6State, lengths=None):
    from .common import rmsnorm
    xn = rmsnorm(x, p.ln1, cfg.norm_eps)
    tm, tshift, wkv = rwkv_mod.time_mix(p.mix, cfg, xn, state,
                                        lengths=lengths)
    h = x + tm
    hn = rmsnorm(h, p.ln2, cfg.norm_eps)
    cm, cshift = rwkv_mod.channel_mix(p.mix, cfg, hn, state, lengths=lengths)
    new_state = rwkv_mod.Rwkv6State(tshift, cshift, wkv)
    return h + cm, new_state


# ---------------------------------------------------------------------------
# hybrid (zamba2) block group
# ---------------------------------------------------------------------------

class HybridParams(NamedTuple):
    mamba: Any                 # stacked (G, E, ...) Mamba2Params
    mamba_ln: jax.Array        # (G, E, d)
    shared_ln: jax.Array       # (d,)
    shared_attn: AttnParams    # ONE set of weights, applied G times
    shared_ln2: jax.Array      # (d,)
    shared_mlp: Any            # MlpParams, shared like the attention


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------------
    def init_params(self, key) -> Dict:
        cfg = self.cfg
        kE, kB, kH, kF = jax.random.split(key, 4)
        if cfg.n_codebooks:
            embed = jnp.stack([
                init_dense(k, cfg.vocab, cfg.d_model, cfg.dtype, scale=0.02)
                for k in jax.random.split(kE, cfg.n_codebooks)])
        else:
            embed = init_dense(kE, cfg.vocab, cfg.d_model, cfg.dtype,
                               scale=0.02)
        params = {
            "embed": embed,
            "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
            "head": init_dense(kH, cfg.d_model, cfg.vocab, cfg.dtype),
        }
        if cfg.family == "ssm":
            keys = jax.random.split(kB, cfg.n_layers)
            params["blocks"] = jax.vmap(
                lambda k: _init_rwkv_block(k, cfg))(keys)
        elif cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            keys = jax.random.split(kB, g * cfg.attn_every).reshape(
                g, cfg.attn_every, 2)
            mamba = jax.vmap(jax.vmap(
                lambda k: mamba_mod.init_mamba2(k, cfg)))(keys)
            kF1, kF2 = jax.random.split(kF)
            params["blocks"] = HybridParams(
                mamba=mamba,
                mamba_ln=jnp.ones((g, cfg.attn_every, cfg.d_model), cfg.dtype),
                shared_ln=jnp.ones((cfg.d_model,), cfg.dtype),
                shared_attn=attn_mod.init_attn(kF1, cfg),
                shared_ln2=jnp.ones((cfg.d_model,), cfg.dtype),
                shared_mlp=ffn_mod.init_mlp(kF2, cfg))
        else:
            keys = jax.random.split(kB, cfg.n_layers)
            params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg))(keys)
        return params

    # -- embedding ----------------------------------------------------------
    def embed(self, params, tokens):
        cfg = self.cfg
        if cfg.n_codebooks:
            # tokens: (b, s, K) — summed codebook embeddings (EnCodec stub)
            return sum(jnp.take(params["embed"][i], tokens[..., i], axis=0)
                       for i in range(cfg.n_codebooks))
        return jnp.take(params["embed"], tokens, axis=0)

    # -- forward (train / scoring) -------------------------------------------
    def forward(self, params, tokens):
        cfg = self.cfg
        x = self.embed(params, tokens)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        if cfg.family == "ssm":
            def body(carry, layer):
                x = carry
                x, _ = _rwkv_block_fwd(layer, cfg, x, None)
                return x, None
            fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(fn, x, params["blocks"])
        elif cfg.family == "hybrid":
            hp: HybridParams = params["blocks"]
            from .common import rmsnorm

            def group(carry, layer):
                x = carry
                mam, lns = layer

                def inner(c, l):
                    mp, ln = l
                    y, _ = mamba_mod.forward(mp, cfg, rmsnorm(c, ln,
                                                              cfg.norm_eps))
                    return c + y, None
                x, _ = jax.lax.scan(inner, x, (mam, lns))
                xa = rmsnorm(x, hp.shared_ln, cfg.norm_eps)
                x = x + attn_mod.attention(hp.shared_attn, cfg, xa, positions)
                xm = rmsnorm(x, hp.shared_ln2, cfg.norm_eps)
                x = x + ffn_mod.mlp(hp.shared_mlp, xm)
                return x, None
            fn = jax.checkpoint(group) if cfg.remat else group
            x, _ = jax.lax.scan(fn, x, (hp.mamba, hp.mamba_ln))
        else:
            aux0 = jnp.zeros((), jnp.float32)

            def body(carry, layer):
                x, aux = carry
                x, a = _block_fwd(layer, cfg, x, positions)
                return (x, aux + a), None
            fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux), _ = jax.lax.scan(fn, (x, aux0), params["blocks"])
            self._last_aux = aux

        from .common import rmsnorm
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
        return logits

    def loss(self, params, batch) -> jax.Array:
        logits = self.forward(params, batch["tokens"])
        loss = cross_entropy(logits, batch["labels"])
        if self.cfg.n_experts:
            loss = loss + 0.01 * getattr(self, "_last_aux", 0.0)
        return loss

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        if cfg.family == "ssm":
            states = rwkv_mod.init_state(cfg, batch)
            return jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape),
                states)
        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            ms = mamba_mod.init_state(cfg, batch)
            mstack = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(
                    l, (g, cfg.attn_every) + l.shape), ms)
            kv = attn_mod.init_cache(cfg, batch, max_seq)
            kvstack = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l, (g,) + l.shape), kv)
            return {"mamba": mstack, "kv": kvstack}
        kv = attn_mod.init_cache(cfg, batch, max_seq)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), kv)

    # -- paged serving cache -------------------------------------------------
    def init_paged_cache(self, batch: int, max_seq: int, *, n_blocks: int,
                         block_size: int):
        """The ``kv_layout="paged"`` engine cache: same pytree *structure*
        as :meth:`init_cache`, but KV leaves are page pools
        (``(n_blocks, block_size, nkv, hd)`` per layer/group) with no slot
        axis — slots map into the pool through their block tables.
        Recurrent state (ssm / the hybrid's mamba backbone) is O(1) per
        slot and stays slot-indexed; the ssm family has no KV at all, so
        its paged cache IS its dense cache."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self.init_cache(batch, max_seq)
        pool = attn_mod.init_paged_kv(cfg, n_blocks, block_size)
        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            ms = mamba_mod.init_state(cfg, batch)
            mstack = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(
                    l, (g, cfg.attn_every) + l.shape), ms)
            kvstack = jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l, (g,) + l.shape), pool)
            return {"mamba": mstack, "kv": kvstack}
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), pool)

    def split_paged_cache(self, cache):
        """(kv pools, slot-indexed recurrent state) — either may be None."""
        if self.cfg.family == "ssm":
            return None, cache
        if self.cfg.family == "hybrid":
            return cache["kv"], cache["mamba"]
        return cache, None

    def merge_paged_cache(self, kv, state):
        """Inverse of :meth:`split_paged_cache`."""
        if self.cfg.family == "ssm":
            return state
        if self.cfg.family == "hybrid":
            return {"mamba": state, "kv": kv}
        return kv

    def init_prefill_state(self, batch: int = 1):
        """Fresh batch-``batch`` recurrent staging state for a chunked
        admission (None for pure-attention families — their prefill state
        lives entirely in the page pool)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self.init_cache(batch, 1)
        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            ms = mamba_mod.init_state(cfg, batch)
            return jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(
                    l, (g, cfg.attn_every) + l.shape), ms)
        return None

    def prefill(self, params, tokens, cache, start: int = 0, lengths=None,
                attend_cache: bool = False):
        """Fill the cache with ``tokens``; returns (last_logits, cache).

        ``lengths`` ((b,) int32) marks the real prompt length per row for
        RIGHT-padded batches: logits are gathered at ``lengths - 1`` instead
        of the final position, so bucket padding on the right never leaks
        into the returned next-token distribution.  For attention families a
        right-padded prefill is bitwise the unpadded computation — causal
        masking means real tokens never attend to the padding; for the
        recurrent families (ssm/hybrid) the state updates past ``lengths``
        are masked off (rwkv6.time_mix / mamba2.forward), so the returned
        cache is ALSO the unpadded cache and padded prefill is
        padding-invariant across every family.

        ``attend_cache=True`` is the CHUNKED-prefill continuation form: the
        attention families attend against the whole (updated) cache masked
        by ``kpos <= qpos`` instead of within ``tokens`` alone, so a chunk
        at offset ``start > 0`` sees every earlier chunk's positions.
        ``start`` may be traced in that form (one executable per chunk
        shape serves every offset).  Recurrent families carry their state
        through ``cache`` either way, so the flag only changes attention."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        b, s = x.shape[:2]
        positions = start + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        from .common import rmsnorm

        if cfg.family == "ssm":
            def body(carry, layer_and_state):
                x = carry
                layer, st = layer_and_state
                x, new_st = _rwkv_block_fwd(layer, cfg, x, st,
                                            lengths=lengths)
                return x, new_st
            x, new_states = jax.lax.scan(body, x, (params["blocks"], cache))
            new_cache = new_states
        elif cfg.family == "hybrid":
            hp: HybridParams = params["blocks"]

            def group(carry, inp):
                x = carry
                (mam, lns), mstates, kv = inp

                def inner(c, l):
                    (mp, ln), st = l
                    y, nst = mamba_mod.forward(
                        mp, cfg, rmsnorm(c, ln, cfg.norm_eps), st,
                        lengths=lengths)
                    return c + y, nst
                x, new_mst = jax.lax.scan(inner, x, ((mam, lns), mstates))
                xa = rmsnorm(x, hp.shared_ln, cfg.norm_eps)
                if attend_cache:
                    y, new_kv = attn_mod.attention_prefill_cached(
                        hp.shared_attn, cfg, xa, kv, start)
                else:
                    y, new_kv = attn_mod.attention_prefill(
                        hp.shared_attn, cfg, xa, kv, start)
                x = x + y
                xm = rmsnorm(x, hp.shared_ln2, cfg.norm_eps)
                x = x + ffn_mod.mlp(hp.shared_mlp, xm)
                return x, (new_mst, new_kv)
            x, (new_mst, new_kv) = jax.lax.scan(
                group, x, ((hp.mamba, hp.mamba_ln), cache["mamba"],
                           cache["kv"]))
            new_cache = {"mamba": new_mst, "kv": new_kv}
        else:
            def body(carry, layer_and_cache):
                x, aux = carry
                layer, kv = layer_and_cache
                h_in = rmsnorm(x, layer.ln1, cfg.norm_eps)
                if attend_cache:
                    y_attn, new_kv = attn_mod.attention_prefill_cached(
                        layer.attn, cfg, h_in, kv, start)
                else:
                    y_attn, new_kv = attn_mod.attention_prefill(
                        layer.attn, cfg, h_in, kv, start)
                h = x + y_attn
                y = rmsnorm(h, layer.ln2, cfg.norm_eps)
                if cfg.n_experts:
                    out, a = ffn_mod.moe(layer.mlp, cfg, y)
                else:
                    out, a = ffn_mod.mlp(layer.mlp, y), 0.0
                return (h + out, aux + a), new_kv
            (x, _), new_cache = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["blocks"], cache))

        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        if lengths is None:
            x_last = x[:, -1]
        else:
            idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, s - 1)
            x_last = jnp.take_along_axis(
                x, idx[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", x_last, params["head"])
        return logits, new_cache

    def prefill_paged(self, params, tokens, kv, bt_row, state, start,
                      lengths, *, first: bool):
        """Prefill one prompt chunk of ONE slot into paged KV pools.

        tokens: (1, s); kv: the engine's pooled KV leaves
        (:meth:`split_paged_cache`; None for ssm); bt_row: the slot's
        (max_blocks,) block-table row; state: batch-1 recurrent staging
        state (:meth:`init_prefill_state`; None for attention-only
        families); start: chunk offset (traced ok when ``not first``);
        lengths: (1,) real token count WITHIN this chunk.  Returns
        (last_logits, kv, state).

        ``first`` (static) is the chunk-0 form: attention runs within
        ``tokens`` exactly like the dense admission prefill — bitwise the
        oracle's computation for prompts that fit one chunk; continuation
        chunks gather the slot's pages and attend ``kpos <= qpos``."""
        cfg = self.cfg
        if cfg.family == "ssm":
            logits, new_state = self.prefill(params, tokens, state,
                                             lengths=lengths)
            return logits, kv, new_state
        from .common import rmsnorm
        x = self.embed(params, tokens)
        b, s = x.shape[:2]
        start = jnp.asarray(start, jnp.int32)
        positions = start + jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        if cfg.family == "hybrid":
            hp: HybridParams = params["blocks"]

            def group(carry, inp):
                x, ck, cv, gi = carry
                (mam, lns), mstates = inp

                def inner(c, l):
                    (mp, ln), st = l
                    y, nst = mamba_mod.forward(
                        mp, cfg, rmsnorm(c, ln, cfg.norm_eps), st,
                        lengths=lengths)
                    return c + y, nst
                x, new_mst = jax.lax.scan(inner, x, ((mam, lns), mstates))
                xa = rmsnorm(x, hp.shared_ln, cfg.norm_eps)
                y, ck, cv = attn_mod.paged_attention_prefill(
                    hp.shared_attn, cfg, xa, ck, cv, gi, bt_row, start,
                    first=first)
                x = x + y
                xm = rmsnorm(x, hp.shared_ln2, cfg.norm_eps)
                x = x + ffn_mod.mlp(hp.shared_mlp, xm)
                return (x, ck, cv, gi + 1), new_mst
            (x, ck, cv, _), new_state = jax.lax.scan(
                group, (x, kv.k, kv.v, jnp.int32(0)),
                ((hp.mamba, hp.mamba_ln), state))
        else:
            def body(carry, layer):
                x, ck, cv, li = carry
                h_in = rmsnorm(x, layer.ln1, cfg.norm_eps)
                y, ck, cv = attn_mod.paged_attention_prefill(
                    layer.attn, cfg, h_in, ck, cv, li, bt_row, start,
                    first=first)
                h = x + y
                z = rmsnorm(h, layer.ln2, cfg.norm_eps)
                if cfg.n_experts:
                    out, _ = ffn_mod.moe(layer.mlp, cfg, z)
                else:
                    out = ffn_mod.mlp(layer.mlp, z)
                return (h + out, ck, cv, li + 1), None
            (x, ck, cv, _), _ = jax.lax.scan(
                body, (x, kv.k, kv.v, jnp.int32(0)), params["blocks"])
            new_state = state

        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, s - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum("bd,dv->bv", x_last, params["head"])
        return logits, KVCache(ck, cv), new_state

    def gather_paged_view(self, cache, block_tables):
        """The per-slot logical (vk, vv) view of a paged cache's pools —
        gathered once per decode chunk (None for the ssm family, which has
        no KV).  See :func:`repro.models.attention.gather_paged_view`."""
        if self.cfg.family == "ssm":
            return None
        kv, _ = self.split_paged_cache(cache)
        return attn_mod.gather_paged_view(kv.k, kv.v, block_tables)

    def decode_step(self, params, token, cache, pos, block_tables=None,
                    kv_view=None):
        """token: (b, 1[, K]) -> (logits (b, vocab), new cache).

        ``pos`` is a scalar (lock-step batch) or a (b,) per-slot position
        vector (continuous batching) — threaded through to
        ``attention_decode_inplace``; recurrent families ignore it.

        ``block_tables`` ((b, max_blocks) int32) selects the PAGED KV
        path: the cache's KV leaves are page pools and attention goes
        through :func:`repro.models.attention.paged_attention_decode_inplace`
        — same masked math over a gathered per-slot view, so the layout is
        a strategy choice, not a fork in the model.  With ``kv_view`` (the
        (vk, vv) pair from :meth:`gather_paged_view`, gathered once per
        chunk) attention runs against the view and the return value is
        ``(logits, cache, view)`` — the fused chunk's amortised-gather
        form."""
        cfg = self.cfg
        x = self.embed(params, token)
        b = x.shape[0]
        from .common import rmsnorm

        new_view = None
        if cfg.family == "ssm":
            def body(carry, layer_and_state):
                x = carry
                layer, st = layer_and_state
                x, new_st = _rwkv_block_fwd(layer, cfg, x, st)
                return x, new_st
            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        elif cfg.family == "hybrid":
            hp: HybridParams = params["blocks"]
            # KV caches ride in the scan CARRY with token-sized in-place
            # updates (attention_decode_inplace); small mamba states stay
            # as scanned xs/ys.
            ck0, cv0 = cache["kv"].k, cache["kv"].v   # (G, b, s, kv, hd)
            # the view (when given) rides in the carry NEXT TO the pools —
            # attention reads/updates the view, the pool gets the mirrored
            # token write; without a view the carry keeps its dense shape
            kv0 = kv_view if kv_view is not None else ()

            def group(carry, inp):
                (x, ck, cv, gi), view = carry[:4], carry[4:]
                (mam, lns), mstates = inp

                def inner(c, l):
                    (mp, ln), st = l
                    y, nst = mamba_mod.decode_step(
                        mp, cfg, rmsnorm(c, ln, cfg.norm_eps), st)
                    return c + y, nst
                x, new_mst = jax.lax.scan(inner, x, ((mam, lns), mstates))
                xa = rmsnorm(x, hp.shared_ln, cfg.norm_eps)
                if kv_view is not None:
                    y, ck, cv, vk, vv = attn_mod.paged_attention_decode_view(
                        hp.shared_attn, cfg, xa, ck, cv, view[0], view[1],
                        gi, pos, block_tables)
                    view = (vk, vv)
                elif block_tables is not None:
                    y, ck, cv = attn_mod.paged_attention_decode_inplace(
                        hp.shared_attn, cfg, xa, ck, cv, gi, pos,
                        block_tables)
                else:
                    y, ck, cv = attn_mod.attention_decode_inplace(
                        hp.shared_attn, cfg, xa, ck, cv, gi, pos)
                x = x + y
                xm = rmsnorm(x, hp.shared_ln2, cfg.norm_eps)
                x = x + ffn_mod.mlp(hp.shared_mlp, xm)
                return (x, ck, cv, gi + 1) + view, new_mst
            out_carry, new_mst = jax.lax.scan(
                group, (x, ck0, cv0, jnp.int32(0)) + tuple(kv0),
                ((hp.mamba, hp.mamba_ln), cache["mamba"]))
            x, ck, cv = out_carry[0], out_carry[1], out_carry[2]
            new_cache = {"mamba": new_mst, "kv": KVCache(ck, cv)}
            if kv_view is not None:
                new_view = out_carry[4:6]
        else:
            ck0, cv0 = cache.k, cache.v               # (L, b, s, kv, hd)
            kv0 = kv_view if kv_view is not None else ()

            def body(carry, layer):
                (x, ck, cv, li), view = carry[:4], carry[4:]
                h = rmsnorm(x, layer.ln1, cfg.norm_eps)
                if kv_view is not None:
                    y, ck, cv, vk, vv = attn_mod.paged_attention_decode_view(
                        layer.attn, cfg, h, ck, cv, view[0], view[1], li,
                        pos, block_tables)
                    view = (vk, vv)
                elif block_tables is not None:
                    y, ck, cv = attn_mod.paged_attention_decode_inplace(
                        layer.attn, cfg, h, ck, cv, li, pos, block_tables)
                else:
                    y, ck, cv = attn_mod.attention_decode_inplace(
                        layer.attn, cfg, h, ck, cv, li, pos)
                x = x + y
                z = rmsnorm(x, layer.ln2, cfg.norm_eps)
                if cfg.n_experts:
                    out, _ = ffn_mod.moe(layer.mlp, cfg, z)
                else:
                    out = ffn_mod.mlp(layer.mlp, z)
                return (x + out, ck, cv, li + 1) + view, None
            out_carry, _ = jax.lax.scan(
                body, (x, ck0, cv0, jnp.int32(0)) + tuple(kv0),
                params["blocks"])
            x, ck, cv = out_carry[0], out_carry[1], out_carry[2]
            new_cache = KVCache(ck, cv)
            if kv_view is not None:
                new_view = out_carry[4:6]

        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"])
        if kv_view is not None:
            return logits, new_cache, new_view
        return logits, new_cache
