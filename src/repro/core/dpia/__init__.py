"""Data Parallel Idealised Algol (DPIA) — the paper's contribution in JAX.

Public surface:
  types    — data & phrase types (Fig. 1)
  phrases  — AST + smart constructors (Fig. 4)
  check    — SCIR interference/race-freedom checking (Fig. 3)
  interp   — functional reference semantics (the oracle, section 5.2)
  stage1   — acceptor/continuation-passing translation (Fig. 5)
  stage2   — intermediate combinators -> loops (section 4.2)
  hoist    — allocation hoisting out of parallel loops (section 6.4)
  stage3_jnp      — imperative DPIA -> executable JAX (Fig. 6 analogue)
  stage3_pallas   — imperative DPIA -> pl.pallas_call (TPU kernels)
  stage3_shardmap — mesh-level strategies -> shard_map + collectives
  strategies      — semantics-preserving rewrites (Steuwer et al. 2015 style)

The Stage III modules self-register in the ``repro.compiler`` backend
registry; drive the whole pipeline through the staged API —
``repro.compiler.Program(expr, args).check().lower().compile(backend)`` —
rather than calling the stages directly (see docs/compiler.md).

Autotuning
----------
Strategy *choice* lives outside this package, in ``repro.autotune``: the
rewrite rules above define the strategy space, ``repro.autotune.space``
enumerates it per kernel/shape, ``repro.autotune.cost`` ranks candidates
with an analytical roofline model (FLOPs, HBM/VMEM bytes, grid/loop
overhead), ``repro.autotune.measure`` optionally compiles and times the
top-k through stage1 -> stage2 -> stage3, and the winner is remembered in a
persistent cache keyed by (kernel, shape, dtype, backend, mesh).  Because
every candidate is rewrite-derived, tuning can change performance but never
semantics.  ``strategies.enumerate_dot_strategies``/``strategies.search``
remain as thin compatibility shims.  See docs/autotune.md.
"""
from . import (check, hoist, interp, phrases, pretty, stage1, stage2,
               stage3_jnp, stage3_pallas, stage3_shardmap, strategies, types)  # noqa: F401
from .phrases import (  # noqa: F401
    GRID, HBM, LANES, MESH, PAR, REG, SEQ, VMEM, Par,
    add, div, fmax, lit, map_grid, map_lanes, map_mesh, map_par, map_seq, mul,
    reduce_seq, sub, to_hbm, to_reg, to_vmem, var_acc, var_exp,
)
from .types import Arr, Idx, Num, Pair, Vec, arr  # noqa: F401
