"""Pure-jnp oracles for every kernel (the reference semantics each Pallas or
DPIA-generated implementation is tested against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---- paper section 7 benchmark ops (BLAS level 1/2) ------------------------

def scal(alpha, x):
    """BLAS scal: alpha * x."""
    return alpha * x


def asum(x):
    """BLAS asum: sum of absolute values."""
    return jnp.sum(jnp.abs(x))


def dot(x, y):
    """BLAS dot: sum(x * y)."""
    return jnp.sum(x * y)


def gemv(a, x):
    """BLAS gemv: A @ x."""
    return a @ x


# ---- transformer kernels ----------------------------------------------------

def matmul(a, b, *, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def rmsnorm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def softmax(x, axis: int = -1):
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    q_offset: int = 0):
    """Reference multi-head attention with GQA.

    q: (bh, sq, d); k, v: (bkv, sk, d) with bh % bkv == 0 (GQA groups).
    ``q_offset`` positions queries within the kv sequence (decode/prefill
    continuation): query i attends to keys <= q_offset + i.
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh % bkv == 0
    group = bh // bkv
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d))
    kq = jnp.repeat(k, group, axis=0)
    vq = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
