"""musicgen-large [audio] — 48L d=2048 32H (kv=32) ff=8192 vocab=2048,
decoder-only over EnCodec tokens (4 codebooks summed; frontend stub)
[arXiv:2306.05284; hf]"""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048, n_codebooks=4)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=64,
                               n_codebooks=2, dtype="float32", max_seq=64)
