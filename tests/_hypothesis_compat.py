"""Use the real ``hypothesis`` when installed; otherwise a tiny deterministic
stand-in so the property tests still collect and run (with fixed sampling
instead of shrinking search).  Covers exactly the API surface this suite
uses: ``given``, ``settings``, ``strategies.{integers, sampled_from,
booleans, composite}``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8  # keep the dependency-free path fast

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rnd: random.Random -> value

    class _StrategiesShim:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 16):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rnd: elements[rnd.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _Strategy(
                    lambda rnd: fn(lambda s: s.sample(rnd), *args, **kwargs))
            return make

    st = _StrategiesShim()

    def settings(max_examples=_FALLBACK_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = min(int(max_examples), _FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                for i in range(n):
                    rnd = random.Random(7919 * i + 11)
                    drawn = [s.sample(rnd) for s in arg_strats]
                    drawn_kw = {k: s.sample(rnd)
                                for k, s in sorted(kw_strats.items())}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values()
                      if p.name not in kw_strats]
            if arg_strats:
                params = params[:len(params) - len(arg_strats)]
            wrapper.__signature__ = sig.replace(parameters=params)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = getattr(fn, "_max_examples",
                                            _FALLBACK_EXAMPLES)
            return wrapper
        return deco
