"""repro.strategy — strategies as composable, minable programs.

The ELEVATE layer over the DPIA rewrites: :mod:`lang` is the combinator
language (primitive rules + seq/try_/alt/repeat, failure as a value,
traces), :mod:`traverse` the HOAS-aware traversals (topdown/bottomup/
one/all_, paths, replay), :mod:`spaces` re-expresses the autotune kernel
spaces as strategy programs and adds the generic space for arbitrary
terms, and :mod:`mine` compresses winning traces into named abstractions
that seed later searches.  See docs/strategies.md.
"""
from . import lang, mine, spaces, traverse
from .lang import (
    RULES, Result, Strategy, StrategyTrace, TraceStep, alt, fail_, id_,
    is_trace_doc, named, repeat, repeat_n, rule, seq, try_,
)
from .mine import Abstraction, abstractions_path, anti_unify, matches, \
    seeded_order
from .spaces import fused_rmsnorm_matmul, generic_space, program_for, \
    spec_builder
from .traverse import all_, at, bottomup, fingerprint, one, replay, topdown

__all__ = [
    "lang", "traverse", "spaces", "mine",
    "Strategy", "StrategyTrace", "TraceStep", "Result", "RULES",
    "rule", "seq", "try_", "alt", "repeat", "repeat_n", "id_", "fail_",
    "named", "is_trace_doc",
    "one", "all_", "topdown", "bottomup", "at", "replay", "fingerprint",
    "spec_builder", "program_for", "generic_space", "fused_rmsnorm_matmul",
    "Abstraction", "anti_unify", "matches", "seeded_order",
    "abstractions_path",
]
