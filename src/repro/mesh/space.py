"""Mesh-axis strategy space: which named axis (if any) a kernel's top level
binds to, and the per-shard chunk blocking underneath it.

Candidates reuse :class:`repro.autotune.space.Candidate`; their params extend
the single-device vocabulary with one key:

  ``mesh_axis``   named mesh axis of the distributed map/reduce

plus the per-shard chunk factor in the kernel's existing vocabulary
(``block`` / ``row_block`` / ``bk``).  Enumeration needs only the axis->size
dict of a mesh *descriptor* (:func:`repro.mesh.parse_descriptor`) — no
devices, no Mesh object — so the tuner can rank mesh placements offline and
the ranking is keyed by the descriptor in the persistent cache.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .kernels import MESH_KERNELS
from .strategy import MeshStrategy

__all__ = ["mesh_space", "default_mesh_params", "mesh_candidate_from_params",
           "mesh_extent"]

# per-shard chunk menus (subset of the single-device menus: a shard is small)
_CHUNK_BLOCKS = (256, 1024, 4096)
_CHUNK_PARAM = {"dot": "block", "asum": "block", "scal": "block",
                "rmsnorm": "row_block", "softmax": "row_block",
                "matmul": "bk"}
_CHUNK_EXTENT = {"matmul": "k"}   # bk blocks the contraction, not the shard


def mesh_extent(kernel: str, shape: Dict[str, int]) -> int:
    """The logical extent the mesh axis shards for this kernel."""
    _, dim = MESH_KERNELS[kernel]
    return int(shape[dim])


def _eligible_axes(kernel: str, axes: Dict[str, int],
                   shape: Dict[str, int]) -> List[str]:
    ext = mesh_extent(kernel, shape)
    return [a for a, s in axes.items() if s > 1 and ext % int(s) == 0]


def _chunk_menu(kernel: str, axes: Dict[str, int], axis: str,
                shape: Dict[str, int]) -> List[Optional[int]]:
    ext_name = _CHUNK_EXTENT.get(kernel)
    if ext_name is None:
        local = mesh_extent(kernel, shape) // int(axes[axis])
    else:
        local = int(shape[ext_name])
    menu: List[Optional[int]] = [None]   # whole-shard leaf op
    menu += [b for b in _CHUNK_BLOCKS if 0 < b < local and local % b == 0]
    return menu


def _builder(kernel: str, axis: str, shards: int, chunk: Optional[int],
             shape: Dict[str, int]):
    build_fn, _ = MESH_KERNELS[kernel]

    def build():
        kw = {} if chunk is None else {_CHUNK_PARAM[kernel]: chunk}
        return build_fn(axis, shards, **kw, **shape)
    return build


def mesh_space(kernel: str, axes: Dict[str, int], **shape):
    """All mesh-placement candidates for ``kernel`` on a mesh with the given
    axis sizes.  Empty when no axis divides the sharded extent (the caller
    then falls back to the single-device space)."""
    from repro.autotune.space import _cand
    if kernel not in MESH_KERNELS:
        return []
    out = []
    for ax in _eligible_axes(kernel, axes, shape):
        shards = int(axes[ax])
        for chunk in _chunk_menu(kernel, axes, ax, shape):
            params: Dict[str, object] = {"mesh_axis": ax,
                                         _CHUNK_PARAM[kernel]: chunk}
            out.append(_cand(kernel, params,
                             _builder(kernel, ax, shards, chunk, shape)))
    return out


def default_mesh_params(kernel: str, axes: Dict[str, int],
                        **shape) -> Dict[str, object]:
    """The un-tuned mesh placement: the first eligible axis (mesh order),
    whole-shard leaf ops.  Raises ValueError when nothing is shardable."""
    eligible = _eligible_axes(kernel, axes, shape)
    if not eligible:
        raise ValueError(
            f"default_mesh_params: no mesh axis in {dict(axes)} divides the "
            f"{kernel!r} extent {mesh_extent(kernel, shape)}")
    return {"mesh_axis": eligible[0], _CHUNK_PARAM[kernel]: None}


def mesh_candidate_from_params(kernel: str, params: Dict[str, object],
                               axes: Dict[str, int], **shape):
    """Rebuild the mesh Candidate a tuned params dict describes (validated
    against the axis sizes)."""
    from repro.autotune.space import _cand
    strat = MeshStrategy.from_params(
        params, extent=mesh_extent(kernel, shape))
    if strat is None:
        raise ValueError(f"mesh_candidate_from_params: params {params!r} "
                         f"carry no mesh_axis")
    strat.validate(axes)
    shards = int(axes[strat.axis])
    chunk = params.get(_CHUNK_PARAM[kernel])
    chunk = None if chunk is None else int(chunk)
    return _cand(kernel, dict(params),
                 _builder(kernel, strat.axis, shards, chunk, shape))
