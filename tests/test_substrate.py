"""Substrate: data determinism, checkpoint roundtrip/retention/async,
optimizer (incl. 8-bit moments), fault-tolerance pieces."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataState, SyntheticLM
from repro.ft import compress
from repro.ft.resilience import Watchdog, elastic_remesh, guard_update
from repro.optim import adamw


class TestData:
    def test_determinism_across_restart(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
        d = SyntheticLM(cfg)
        a = d.global_batch_at(7)
        b = d.global_batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_batch(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
        d = SyntheticLM(cfg)
        full = d.global_batch_at(0)["tokens"]
        parts = [d.shard_at(0, i, 4)["tokens"] for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
        b = SyntheticLM(cfg).global_batch_at(0)
        assert b["tokens"].shape == (2, 16)
        assert b["labels"].shape == (2, 16)

    def test_iterator_state_resumes(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
        d = SyntheticLM(cfg)
        it = d.iterator()
        b0, st = next(it)
        b1, st = next(it)
        it2 = d.iterator(DataState(step=1))
        b1b, _ = next(it2)
        np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])


class TestCheckpoint:
    def _state(self, v=0.0):
        return {"w": jnp.full((4, 4), v), "step": jnp.int32(v),
                "nested": {"b": jnp.arange(3.0)}}

    def test_roundtrip(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        st = self._state(2.0)
        m.save(10, st, extra={"data_state": {"step": 10}})
        step, rest, extra = m.restore_latest(self._state())
        assert step == 10 and extra["data_state"]["step"] == 10
        np.testing.assert_array_equal(rest["w"], st["w"])

    def test_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            m.save(s, self._state(s))
        assert m.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=True)
        m.save(5, self._state(5.0))
        m.wait()
        assert m.latest_step() == 5

    def test_atomicity_no_partial_dirs(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, self._state())
        names = os.listdir(tmp_path)
        assert all(not n.startswith(".tmp") for n in names)

    def test_shape_mismatch_rejected(self, tmp_path):
        m = CheckpointManager(str(tmp_path), async_save=False)
        m.save(1, self._state())
        bad_template = {"w": jnp.zeros((2, 2)), "step": jnp.int32(0),
                        "nested": {"b": jnp.zeros(3)}}
        with pytest.raises(ValueError):
            m.restore(1, bad_template)


class TestOptimizer:
    def _converges(self, use_8bit):
        w = {"x": jnp.array([5.0, -3.0])}
        st = adamw.init(w, use_8bit=use_8bit)
        for _ in range(200):
            g = jax.tree_util.tree_map(lambda p: 2 * p, w)  # grad of x^2
            w, st, _ = adamw.update(w, g, st, lr=0.05, weight_decay=0.0,
                                    use_8bit=use_8bit)
        return float(jnp.abs(w["x"]).max())

    def test_adamw_converges(self):
        assert self._converges(False) < 0.15

    def test_adamw_8bit_converges(self):
        assert self._converges(True) < 0.3

    def test_grad_clipping(self):
        w = {"x": jnp.ones(4)}
        st = adamw.init(w)
        g = {"x": jnp.full(4, 1e6)}
        _, _, m = adamw.update(w, g, st, lr=0.1)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_cosine_schedule(self):
        lr0 = adamw.cosine_schedule(jnp.int32(0), base_lr=1.0, warmup=10,
                                    total=100)
        lr_w = adamw.cosine_schedule(jnp.int32(10), base_lr=1.0, warmup=10,
                                     total=100)
        lr_end = adamw.cosine_schedule(jnp.int32(100), base_lr=1.0, warmup=10,
                                       total=100)
        assert float(lr0) == 0.0
        assert abs(float(lr_w) - 1.0) < 1e-5
        assert float(lr_end) < 0.11


class TestFaultTolerance:
    def test_guard_update(self):
        assert guard_update({"loss": 1.0, "grad_norm": 2.0})
        assert not guard_update({"loss": float("nan"), "grad_norm": 1.0})
        assert not guard_update({"loss": 1.0, "grad_norm": float("inf")})

    def test_watchdog_fires(self):
        events = []
        w = Watchdog(deadline_s=0.05,
                     on_straggler=lambda s, dt: events.append(s))
        w.arm(step=7)
        time.sleep(0.15)
        w.disarm()
        assert events == [7]

    def test_watchdog_disarm_in_time(self):
        events = []
        w = Watchdog(deadline_s=0.5,
                     on_straggler=lambda s, dt: events.append(s))
        w.arm(step=1)
        w.disarm()
        time.sleep(0.1)
        assert events == []

    def test_elastic_remesh_shrinks_data_axis(self):
        mesh = elastic_remesh((4, 1), ("data", "model"))
        assert mesh.shape["data"] == 1  # only 1 CPU device available

    def test_int8_error_feedback_quantisation(self):
        """EF residual keeps the quantised stream unbiased over steps."""
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(256), "float32") * 1e-3
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(50):
            q, scale = compress._q8(g + err)
            deq = q.astype(jnp.float32) * scale
            err = (g + err) - deq
            acc = acc + deq
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                                   atol=5e-5)
