"""Compile-and-time refinement of strategy candidates.

The analytic model (cost.py) ranks the whole space for free; this module
takes the top-k and actually pushes each through the formal pipeline
(Stage I -> II -> III, jnp or pallas-interpret backend), times it, and
reports microseconds per call.  Candidates that fail to compile or run
(e.g. a rewrite the chosen backend cannot lower) are skipped, not fatal —
the tuner falls back to the analytic ranking among survivors.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.dpia import phrases as P
from repro.core.dpia.types import dtype_of, shape_of

from .space import Candidate


def args_for(arg_vars: Sequence[P.Var], seed: int = 0) -> Tuple:
    """Deterministic random inputs matching the argument Vars' data types."""
    import jax.numpy as jnp
    rng = np.random.RandomState(seed)
    out = []
    for v in arg_vars:
        d = v.t.d
        shp = shape_of(d)
        dt = dtype_of(d)
        if dt.startswith("int"):
            a = rng.randint(0, 7, size=shp)
        else:
            a = rng.randn(*shp)
        out.append(jnp.asarray(a, dt))
    return tuple(out)


def compile_candidate(cand: Candidate, backend: str = "jnp",
                      compile_kw: Optional[dict] = None):
    """(jitted callable, concrete args) for a candidate, via the staged
    pipeline: the candidate becomes a ``repro.compiler.Program`` and runs
    ``check() -> lower() -> compile(backend)``.

    ``compile_kw`` carries backend compile arguments (the shardmap
    backend's ``mesh=``); mesh-level terms go straight to Stage III —
    shard_map consumes the functional term, and the per-shard bodies are
    checked by the inner backend."""
    prog = cand.program()
    kw = dict(compile_kw or {})
    if kw.get("mesh") is not None or backend == "shardmap":
        fn = prog.compile(backend, jit=True, **kw)
    else:
        fn = prog.check().lower().compile(backend, jit=True, **kw)
    return fn, args_for(prog.arg_vars)


def time_callable(fn, args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time in microseconds per call (after warmup/compile)."""
    import jax
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def measure_candidates(cands: Sequence[Candidate], *, backend: str = "jnp",
                       iters: int = 5, seed: int = 0,
                       verify_against: Optional[Candidate] = None,
                       compile_kw: Optional[dict] = None
                       ) -> Dict[str, float]:
    """Time each candidate; returns {params_key: us}.  Failures are dropped.

    When ``verify_against`` is given, every candidate's output is checked
    against that reference candidate's output (strategy preservation as a
    runtime assertion) and mismatching candidates are discarded.
    ``compile_kw`` is threaded to every compile (e.g. shardmap's mesh).
    """
    import jax

    ref_out = None
    if verify_against is not None:
        try:
            rfn, rargs = compile_candidate(verify_against, backend,
                                           compile_kw)
            ref_out = np.asarray(jax.block_until_ready(rfn(*rargs)))
        except Exception:
            ref_out = None

    out: Dict[str, float] = {}
    for c in cands:
        with obs.span("autotune.measure_candidate", backend=backend,
                      params=c.params_key()):
            try:
                fn, args = compile_candidate(c, backend, compile_kw)
                if ref_out is not None:
                    got = np.asarray(jax.block_until_ready(fn(*args)))
                    np.testing.assert_allclose(got, ref_out, rtol=1e-3,
                                               atol=1e-4)
                out[c.params_key()] = time_callable(fn, args, iters=iters)
            except Exception:
                obs.event("autotune.candidate_failed", backend=backend,
                          params=c.params_key())
                continue
    return out


def rank_by_cost(cands: Sequence[Candidate], hw=None
                 ) -> List[Tuple[Candidate, float]]:
    """(candidate, predicted seconds) sorted best-first; unbuildable or
    un-costable candidates sort last with +inf.

    ``hw`` is the roofline HwModel; None resolves the per-platform preset
    (``cost.hw_model()``), so analytic rankings use the hardware actually
    under the process instead of the single TPU-shaped default."""
    from . import cost as cost_mod
    if hw is None:
        hw = cost_mod.hw_model()
    scored = []
    for c in cands:
        try:
            expr, _ = c.build()
            s = cost_mod.predicted_seconds(expr, hw)
        except Exception:
            s = float("inf")
        scored.append((c, s))
    scored.sort(key=lambda cs: (cs[1], cs[0].params_key()))
    return scored
