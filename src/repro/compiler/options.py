"""Compilation options — explicit, immutable, thread-locally scoped.

:class:`CompileOptions` is the record the seed hid in process-wide globals
(``kernels.ops._DEFAULT_IMPL`` / ``_AUTOTUNE``): which kernel impl to use,
whether the strategy autotuner may pick params, which tuning cache it reads,
and Pallas interpret mode.  It is threaded *explicitly* — every op takes an
``options=`` argument — with a thread-local context-manager stack for
scoping:

    with compiler.options(backend="dpia-pallas", autotune=False):
        y = ops.matmul(a, b)          # sees the scoped options

Scopes nest (inner scopes inherit unset fields from the enclosing scope) and
are per-thread, so concurrent serving threads can run different backends
without racing on a global.  The process-wide *default* (what
``current_options()`` returns outside any scope) exists for the deprecated
``set_default_impl``/``set_autotune`` shims and for program start-up
configuration via :func:`set_default_options`.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, fields, replace as _dc_replace
from typing import Optional

from .backends import ops_impls

__all__ = ["CompileOptions", "options", "current_options",
           "set_default_options", "default_options", "default_interpret"]


def _env_autotune() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def default_interpret() -> bool:
    """Whether Pallas kernels should default to interpret mode here: True
    only when the host platform is CPU (no Mosaic compiler), False on real
    accelerators.  ``REPRO_INTERPRET=0|1`` overrides the probe."""
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        return env != "0"
    import jax
    return jax.default_backend() == "cpu"


@dataclass(frozen=True)
class CompileOptions:
    """Everything a kernel compilation depends on besides the term itself.

    backend       kernel-layer impl name: 'xla' | 'pallas' | 'dpia-<stage3>'
                  (validated against the backend registry)
    autotune      let repro.autotune choose strategy params (default: the
                  REPRO_AUTOTUNE env var, read at import)
    tuning_cache  None (process default cache), a path, or a TuningCache
    interpret     run Pallas kernels in interpret mode (default: auto from
                  the platform — True only on CPU; see default_interpret)
    jit           wrap compiled programs in jax.jit
    mesh          jax.sharding.Mesh for mesh-level backends (dpia-shardmap)
                  and mesh-keyed tuning; None defers to the process mesh
                  context (repro.sharding.ctx.get_mesh()), so single-device
                  runs stay single-device without ever naming a mesh
    kv_layout     the serving KV-memory strategy this compilation scope
                  belongs to ('dense' | 'paged'); a cache-key dimension
                  (executor + tuning caches) like the mesh descriptor, so
                  artefacts staged for one memory layout never serve the
                  other
    """
    backend: str = "xla"
    autotune: bool = field(default_factory=_env_autotune)
    tuning_cache: object = None
    interpret: bool = field(default_factory=default_interpret)
    jit: bool = True
    mesh: object = None
    kv_layout: str = "dense"

    def __post_init__(self):
        valid = ops_impls()
        if self.backend not in valid:
            raise ValueError(
                f"unknown backend {self.backend!r}; valid backends: "
                f"{list(valid)}")
        if self.kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', got "
                             f"{self.kv_layout!r}")

    def replace(self, **kw) -> "CompileOptions":
        """A copy with the given fields replaced (validates like __init__)."""
        return _dc_replace(self, **kw)

    @property
    def dpia_backend(self) -> str:
        """The Stage III backend name this impl choice maps to."""
        if self.backend.startswith("dpia-"):
            return self.backend[len("dpia-"):]
        # native impls validate DPIA programs on the reference backend
        return "jnp"

    def resolved_mesh(self):
        """The concrete Mesh mesh-level compilation runs against: the
        explicit ``mesh`` field, else the process mesh context
        (``repro.sharding.ctx``).  None means single-device."""
        if self.mesh is not None:
            return self.mesh
        from repro.sharding import ctx
        return ctx.get_mesh()

    def mesh_descriptor(self) -> str:
        """Canonical descriptor of :meth:`resolved_mesh` — the mesh
        component every tuning/executor cache key carries (``"single"``
        when no mesh is in scope)."""
        from repro.mesh import descriptor
        return descriptor(self.resolved_mesh())


class _Scope(threading.local):
    def __init__(self):
        self.stack = []


_SCOPE = _Scope()
_DEFAULT_LOCK = threading.Lock()
_DEFAULT: Optional[CompileOptions] = None


def default_options() -> CompileOptions:
    """The process-wide default options (outside any ``options()`` scope)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = CompileOptions()
    return _DEFAULT


def set_default_options(**kw) -> CompileOptions:
    """Replace fields of the process-wide default options.

    This is start-up configuration (and the target the deprecated
    ``ops.set_default_impl``/``set_autotune`` shims delegate to) — inside an
    active ``with options(...)`` scope the scoped options still win."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        base = _DEFAULT if _DEFAULT is not None else CompileOptions()
        _DEFAULT = base.replace(**kw) if kw else base
    return _DEFAULT


def current_options() -> CompileOptions:
    """The innermost active options scope on this thread, else the default."""
    stack = _SCOPE.stack
    return stack[-1] if stack else default_options()


@contextmanager
def options(opts: Optional[CompileOptions] = None, **kw):
    """Scope compile options for the current thread.

    Either pass a full :class:`CompileOptions`, or keyword overrides which
    are applied on top of the *current* options (so scopes nest/inherit)::

        with compiler.options(backend="dpia-jnp"):
            with compiler.options(autotune=False):   # backend still dpia-jnp
                ...
    """
    if opts is not None and kw:
        raise TypeError("options(): pass either a CompileOptions or field "
                        "overrides, not both")
    if opts is None:
        opts = current_options().replace(**kw) if kw else current_options()
    elif not isinstance(opts, CompileOptions):
        raise TypeError(f"options() expects CompileOptions, got "
                        f"{type(opts).__name__}")
    _SCOPE.stack.append(opts)
    try:
        yield opts
    finally:
        _SCOPE.stack.pop()


# keep the field list discoverable for docs/tests
OPTION_FIELDS = tuple(f.name for f in fields(CompileOptions))
