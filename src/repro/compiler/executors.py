"""Process-wide executor cache: compiled Stage III callables, reused across
calls and persistable ahead-of-time.

The op layer (``repro.kernels.ops``) used to keep a private dict of compiled
Programs; this module promotes that dict to a compiler-level service with

  * canonical keys — ``(kernel, shape, dtype, backend, params, options bits)``
    rendered as one stable string, so the same executor is found no matter
    which layer asks for it;
  * hit/build statistics — ``benchmarks/serve_bench.py`` and the serving
    tests read these to assert "zero recompiles after warm-up";
  * an AOT store — ``save_aot(dir)`` exports every cached entry's *lowered*
    program (via ``Program.export``) next to the tuning cache, and
    ``load_aot(dir)`` rebuilds the executors in a fresh process without
    redoing Stage I->II translation or the SCIR check.

Stage III code generation itself stays lazy: a rebuilt executor is a
``jax.jit``-wrapped closure whose XLA compilation happens on first call,
exactly as for a freshly staged Program.
"""
from __future__ import annotations

import hashlib
import os
import threading
from typing import Callable, Dict, Optional, Tuple

from repro import obs
from repro.ft import artefacts
from repro.testing import faults

__all__ = ["ExecutorCache", "make_key", "default_cache"]

# v2: make_key gained the mesh-descriptor component; v3: the kv_layout
# component — older artefacts' keys can never hit again, so they must not
# be parsed/compiled on load
AOT_VERSION = 3


def _fmt_params(params: Optional[Dict[str, object]]) -> str:
    if params is None:
        return "default"
    return ",".join(f"{k}={params[k]}" for k in sorted(params)) or "default"


def make_key(kernel: str, shape: Dict[str, object], backend: str, *,
             params: Optional[Dict[str, object]] = None,
             dtype: str = "float32", mesh: str = "single",
             layout: str = "dense",
             interpret: bool = True, jit: bool = True) -> str:
    """Canonical executor key.  Every component the compiled artefact depends
    on is in the key (same discipline as the tuning cache) — including the
    mesh descriptor (``repro.mesh.descriptor``) and the serving KV layout
    (``CompileOptions.kv_layout``), so an executor compiled for one mesh or
    memory strategy can never serve another — and a hit is always safe to
    reuse."""
    shape_s = ",".join(f"{k}={shape[k]}" for k in sorted(shape))
    return (f"{kernel}|{shape_s}|{dtype}|{backend}|{mesh or 'single'}"
            f"|{layout or 'dense'}|{_fmt_params(params)}"
            f"|interpret={int(bool(interpret))}|jit={int(bool(jit))}")


class ExecutorCache:
    """Memoised compiled kernels + AOT persistence.

    ``get_or_compile`` is the one dispatch entry: steady state is a dict
    lookup; a cold key runs the supplied builder (typically
    ``Program.check().lower().compile(backend)``) exactly once per process
    (two racing threads may both build; ``setdefault`` keeps one result).
    """

    def __init__(self):
        self._mem: Dict[str, object] = {}
        self._meta: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self._hits = 0
        self._builds = 0
        self._aot_loads = 0

    # -- dispatch -----------------------------------------------------------

    def get(self, key: str):
        return self._mem.get(key)

    def get_or_compile(self, key: str, build: Callable[[], object], *,
                       meta: Optional[dict] = None):
        fn = self._mem.get(key)
        if fn is not None:
            with self._lock:
                self._hits += 1
            obs.event("executor_cache.hit", key=key)
            return fn
        # deterministic build-failure drill (``executor.build``, ctx: key):
        # raises here so the op layer's degradation ladder handles it the
        # same way as a real staging/compile failure
        faults.raise_if("executor.build", key=key)
        with obs.span("executor_cache.build", key=key):
            fn = build()
        with self._lock:
            self._builds += 1
            if meta:
                self._meta.setdefault(key, dict(meta))
        obs.counter("executor_cache.builds").inc()
        return self._mem.setdefault(key, fn)

    def put(self, key: str, fn, *, meta: Optional[dict] = None) -> None:
        with self._lock:
            self._mem[key] = fn
            if meta:
                self._meta[key] = dict(meta)

    def __contains__(self, key: str) -> bool:
        return key in self._mem

    def __len__(self) -> int:
        return len(self._mem)

    def keys(self):
        return list(self._mem)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._mem), "hits": self._hits,
                    "builds": self._builds, "aot_loads": self._aot_loads}

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._meta.clear()
            self._hits = self._builds = self._aot_loads = 0

    # -- AOT store ----------------------------------------------------------

    @staticmethod
    def _aot_path(directory: str, key: str) -> str:
        h = hashlib.sha1(key.encode()).hexdigest()[:16]
        return os.path.join(directory, f"prog-{h}.json")

    def save_aot(self, directory: str, keys=None) -> int:
        """Export cached executors whose provenance is a Program.

        ``keys`` restricts the export to those cache keys — callers that
        warmed a specific set (a serving engine) pass it so a shared
        process cache never leaks another model's programs into their AOT
        directory.  Files already present are left alone (the export is
        content-addressed by key), so repeated warm-ups are cheap.  The
        directory is append-only: a key retired by e.g. new tuned params
        leaves its file behind, costing one JSON parse on later loads.
        Returns the number of programs written."""
        from .backends import get_backend
        from .program import CompiledKernel
        os.makedirs(directory, exist_ok=True)
        keyset = None if keys is None else set(keys)
        written = 0
        for key, fn in list(self._mem.items()):
            if not isinstance(fn, CompiledKernel):
                continue
            if keyset is not None and key not in keyset:
                continue
            try:
                if get_backend(fn.backend).requires:
                    # backends with compile-time requirements (shardmap's
                    # mesh) cannot be rebuilt from a doc in a later process
                    # — those executors re-stage on restart, never export
                    continue
            except ValueError:
                continue  # backend no longer registered
            path = self._aot_path(directory, key)
            if os.path.exists(path):
                continue
            meta = self._meta.get(key, {})
            try:
                prog_doc = fn.program.to_doc()
            except Exception:
                continue  # no persistable lowering: skip, don't crash
            doc = {
                "version": AOT_VERSION,
                "key": key,
                "backend": fn.backend,
                "interpret": bool(meta.get("interpret", True)),
                "jit": bool(meta.get("jit", True)),
                "program": prog_doc,
            }
            # checksummed + atomic (repro.ft.artefacts): a torn or
            # bit-flipped program file is detected and quarantined at load
            # instead of silently skipped
            artefacts.save_json(path, doc)
            written += 1
        return written

    def load_aot(self, directory: str) -> int:
        """Populate the cache from an AOT directory (idempotent).

        Each artefact is rebuilt as an imperative-only Program and compiled
        through the backend registry with its persisted options bits —
        Stage I->II and the SCIR check are skipped entirely.  Version skew
        is a silent skip (expected after an upgrade); a CORRUPT file —
        unparseable, or failing its embedded checksum — is quarantined to
        ``<directory>/.quarantine/`` and reported through the always-on
        ``artefact.load_failed`` counter (repro.ft.artefacts), never
        silently dropped.  A file whose program fails to REBUILD (e.g. its
        backend grew unmet requirements) is reported but left in place —
        the file is intact; the environment changed.  Returns the number
        of executors loaded."""
        from .backends import get_backend
        from .program import Program
        if not os.path.isdir(directory):
            return 0
        qdir = os.path.join(directory, ".quarantine")
        loaded = 0
        for name in sorted(os.listdir(directory)):
            if not name.endswith(".json") or name.startswith("."):
                continue
            path = os.path.join(directory, name)
            doc = artefacts.load_json(path, what="AOT program", qdir=qdir)
            if doc is None:
                continue  # corrupt (quarantined + reported) or vanished
            try:
                if doc.get("version") != AOT_VERSION:
                    continue
                key = doc["key"]
                if key in self._mem:
                    continue
                prog = Program.from_doc(doc["program"])
                b = get_backend(doc["backend"])
                kw = {}
                if "interpret" in b.accepts:
                    kw["interpret"] = bool(doc.get("interpret", True))
                with obs.span("executor_cache.aot_load", key=key,
                              backend=doc["backend"]):
                    fn = prog.compile(b, jit=bool(doc.get("jit", True)),
                                      **kw)
                self.put(key, fn, meta={"interpret": doc.get("interpret"),
                                        "jit": doc.get("jit")})
                with self._lock:
                    self._aot_loads += 1
                obs.counter("executor_cache.aot_loads").inc()
                # the staged strategy arrived via the AOT store: record it
                # (the params component is the 7th field of the canonical
                # key — see make_key)
                parts = key.split("|")
                obs.record("executor", prog.kernel or prog.name, key,
                           {"params": parts[6] if len(parts) > 6 else "?"},
                           "aot-loaded", shape=dict(prog.shape),
                           backend=doc["backend"],
                           strategy_trace=prog.strategy_trace,
                           note=f"program {prog.name!r} rebuilt from "
                                f"{directory}")
                loaded += 1
            except (OSError, ValueError, KeyError, TypeError) as e:
                # a well-formed file that cannot be rebuilt here (e.g.
                # TypeError: its backend now has unmet compile
                # requirements) — report, skip, never poison the whole
                # load; the file stays for a process that CAN rebuild it
                artefacts.report_load_failure(path, "AOT program", e)
                continue
        return loaded


_default: Optional[ExecutorCache] = None
_default_lock = threading.Lock()


def default_cache() -> ExecutorCache:
    """The process-wide executor cache (what ``kernels.ops`` dispatches on)."""
    global _default
    with _default_lock:
        if _default is None:
            _default = ExecutorCache()
        return _default
