"""Serving example: batched requests against a small dense LM — prefill once,
lock-step decode with greedy/temperature sampling.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import BatchedEngine, Request


def main():
    cfg = ModelConfig(name="lm-serve", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=2, d_ff=768,
                      vocab=1024, dtype="float32", remat=False, max_seq=256)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)

    prompts = [jax.random.randint(jax.random.fold_in(key, i), (8 + 2 * i,),
                                  0, cfg.vocab) for i in range(6)]
    reqs = [Request(prompt=p, max_new_tokens=24, temperature=0.8)
            for p in prompts]

    engine = BatchedEngine(model, params, max_seq=128)
    t0 = time.time()
    outs = engine.run(reqs, key=jax.random.PRNGKey(7))
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"batch={len(reqs)}  {n} tokens in {dt:.2f}s  ({n/dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"request[{i}] ({len(prompts[i])} prompt toks) -> {o[:16]}")


if __name__ == "__main__":
    main()
