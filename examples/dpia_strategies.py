"""Fourth example: strategy exploration — the same gemv computed under
several strategies, compiled through the formal pipeline, costs compared
(the miniature of ICFP'15's search, paper section 2.1).

Run:  PYTHONPATH=src python examples/dpia_strategies.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpia import phrases as P
from repro.core.dpia import interp
from repro.core.dpia.types import Arr, Num
from repro.kernels import dpia_blas

M, N = 512, 1024
rng = np.random.RandomState(0)
A = jnp.asarray(rng.randn(M, N), "float32")
x = jnp.asarray(rng.randn(N), "float32")


def naive():
    return dpia_blas.naive_gemv(M, N)


def blocked(rb):
    return lambda: dpia_blas.strategy_gemv(M, N, row_block=rb)


candidates = {
    "naive (per-row reduce)": naive,
    "row-block 64 + MXU dot": blocked(64),
    "row-block 128 + MXU dot": blocked(128),
    "row-block 256 + MXU dot": blocked(256),
}

expr0, argv0 = naive()
oracle = interp.interp(expr0, {argv0[0].name: A, argv0[1].name: x})

print(f"gemv {M}x{N}: strategy comparison (jnp backend, jit wall time)")
for name, builder in candidates.items():
    from repro import compiler
    fn = compiler.Program.from_builder(builder, name=name).check().lower().compile("jnp")
    got = fn(A, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-3, atol=1e-3)
    fn(A, x).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        fn(A, x).block_until_ready()
    dt = (time.time() - t0) / 20
    print(f"  {name:28s} {dt*1e6:9.1f} us/call   (allclose OK)")
print("fastest strategy wins — the term IS the schedule.")
