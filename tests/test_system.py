"""End-to-end behaviour tests for the paper's system: sharded lowering on a
multi-device mesh (subprocess) and the dry-run machinery itself."""
import json
import os
import subprocess
import sys

import pytest

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}  # host-platform test: skip TPU probing

SHARDED_LOWER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import numpy as np, jax
from jax.sharding import Mesh
from repro.launch import dryrun

def mini_mesh(multi_pod):
    if multi_pod:
        return Mesh(np.array(jax.devices()[:16]).reshape(2, 2, 4),
                    ("pod", "data", "model"))
    return Mesh(np.array(jax.devices()[:16]).reshape(4, 4),
                ("data", "model"))

dryrun._mesh = mini_mesh
rec = dryrun.lower_cell("stablelm_1_6b", "train_4k", False)
assert rec["status"] == "ok", rec
r = rec["roofline"]
assert r["flops"] > 1e15, r                 # scan-aware count (24 layers)
assert r["coll_bytes"] > 0, r               # TP/DP collectives present
rec2 = dryrun.lower_cell("stablelm_1_6b", "train_4k", True)
assert rec2["status"] == "ok", rec2         # the pod axis shards
print("SYSTEM_OK")
"""


@pytest.mark.slow
def test_sharded_lowering_subprocess():
    r = subprocess.run([sys.executable, "-c", SHARDED_LOWER],
                       capture_output=True, text=True, timeout=900, env=ENV)
    assert "SYSTEM_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_dryrun_results_if_present():
    """Validate the committed dry-run results: every non-skipped cell ok."""
    path = "experiments/dryrun.json"
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    with open(path) as f:
        results = json.load(f)
    bad = {k: v.get("error", "") for k, v in results.items()
           if v.get("status") == "error"}
    assert not bad, bad
    ok = [k for k, v in results.items() if v.get("status") == "ok"]
    assert len(ok) >= 30, f"only {len(ok)} cells compiled"


def test_examples_quickstart():
    r = subprocess.run([sys.executable, "examples/quickstart.py"],
                       capture_output=True, text=True, timeout=600, env=ENV)
    assert "== oracle OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
