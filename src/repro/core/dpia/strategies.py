"""Semantics-preserving strategy rewrites (Steuwer et al. 2015 layer).

The paper assumes parallelisation strategies are *derived* at the functional
level by semantics-preserving rewriting and only then compiled.  These are the
rewrite rules we use, each a function Expr -> Expr whose oracle-equality is
property-tested (tests/test_dpia_strategies.py):

  split_join   map f xs            = join (map (map f) (split b xs))
  blocked_reduce (assoc f, unit z)
               reduce f z xs       = reduce f z (map (reduce f z) (split b xs))
  fuse_map_into_reduce
               reduce f z (map g xs) = reduce (λx a. f (g x) a) z xs
  vectorize    map (scalar op) xs  = asScalar (map (vector op) (asVector w xs))
  distribute   assign mesh/grid/seq levels to maps/reduces
  stage_vmem   wrap an expression so its materialisation lands in VMEM
  vpu_reduce   reduce (λx a. a ⊕ g x) 1⊕ xs = fullReduce ⊕ (g* xs)
  lift_lanes   map (elementwise g) xs = g* xs  (one whole-block VPU op)
  tile_matmul  naive row×col matmul = grid-blocked MXU k-chunk accumulation

plus a tiny exhaustive strategy search used by the benchmarks (the analogue
of the ICFP'15 stochastic search, feasible here because our kernels have a
small, structured strategy space).
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional

from . import phrases as P
from .types import Arr, Num, Pair, Vec


def split_join(m: P.Map, b: int) -> P.Phrase:
    """map f xs  ->  join (map[level] (map f) (split b xs))."""
    d = P.exp_data(m.e)
    assert isinstance(d, Arr) and d.n % b == 0
    return P.Join(P.Map(
        lambda blk: P.Map(m.f, blk, level=P.SEQ, space=m.space),
        P.Split(b, m.e),
        level=m.level))


def blocked_reduce(r: P.Reduce, b: int, *,
                   partial_level: Optional[P.Par] = None,
                   combine=None) -> P.Phrase:
    """reduce f z xs -> reduce g z (map (reduce f z) (split b xs)).

    ``g`` (``combine``) merges per-block partials; it defaults to ``f`` when
    the reducer is homogeneous (d1 == d2).  Caller asserts associativity of
    the combine with unit z (the rewrite system's semantic side condition,
    as in the paper's provenance)."""
    d = P.exp_data(r.e)
    assert isinstance(d, Arr) and d.n % b == 0
    g = combine or r.f
    return P.Reduce(
        g, r.init,
        P.Map(lambda blk: P.Reduce(r.f, r.init, blk, level=P.SEQ),
              P.Split(b, r.e),
              level=partial_level or P.PAR),
        level=r.level)


def fuse_map_into_reduce(r: P.Reduce) -> P.Phrase:
    """reduce f z (map g xs) -> reduce (λx a. f (g x) a) z xs."""
    m = r.e
    assert isinstance(m, P.Map), "reduce input is not a map"
    return P.Reduce(lambda x, a: r.f(m.f(x), a), r.init, m.e, level=r.level)


def vectorize(m: P.Map, w: int) -> P.Phrase:
    """map f xs -> asScalar (map f_vec (asVector w xs)) for pointwise f.

    Our UnOp/BinOp are already elementwise at vector types, so ``f`` applied
    to a vector element *is* f_vec — the paper's asVector story (section 6.2),
    with w = TPU lane width rather than OpenCL's float4."""
    d = P.exp_data(m.e)
    assert isinstance(d, Arr) and isinstance(d.elem, Num) and d.n % w == 0
    return P.AsScalar(P.Map(m.f, P.AsVector(w, m.e), level=m.level))


def with_level(e: P.Phrase, level: P.Par) -> P.Phrase:
    """Assign an execution level to the outermost map/reduce."""
    if isinstance(e, P.Map):
        return P.Map(e.f, e.e, level=level, space=e.space)
    if isinstance(e, P.Reduce):
        return P.Reduce(e.f, e.init, e.e, level=level)
    raise TypeError("with_level: not a map/reduce")


def stage_vmem(e: P.Phrase) -> P.Phrase:
    """toVMEM wrapper: materialise the value in VMEM (paper's toLocal)."""
    return P.ToMem(P.VMEM, e)


# ---------------------------------------------------------------------------
# leaf-lowering rewrites (the "lanes" reading of an inner loop): these turn
# derived sequential leaves into the whole-block VPU/MXU forms the
# hand-written strategy_* builders use, so a full TPU schedule is derivable
# from the naive spec by rewriting alone.
# ---------------------------------------------------------------------------

def _subst(e: P.Phrase, name: str, repl: P.Phrase) -> P.Phrase:
    """Capture-avoiding substitution of the free Var ``name`` in a
    functional term (fresh() names are globally unique, so HOAS binder
    arguments can never shadow it)."""
    import dataclasses
    if isinstance(e, P.Var):
        return repl if e.name == name else e
    if isinstance(e, P.Lit):
        return e
    if isinstance(e, P.Map):
        return P.Map(lambda *a: _subst(e.f(*a), name, repl),
                     _subst(e.e, name, repl), level=e.level, space=e.space)
    if isinstance(e, P.Reduce):
        return P.Reduce(lambda *a: _subst(e.f(*a), name, repl),
                        _subst(e.init, name, repl),
                        _subst(e.e, name, repl), level=e.level)
    kw, changed = {}, False
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if isinstance(v, P.Phrase):
            v2 = _subst(v, name, repl)
            changed |= v2 is not v
            kw[f.name] = v2
        else:
            kw[f.name] = v
    return type(e)(**kw) if changed else e


_ELEMWISE_NODES = (P.Var, P.Lit, P.UnOp, P.BinOp, P.Fst, P.Snd)


def _elementwise_over(e: P.Phrase, bound: str,
                      forbid: Optional[str] = None) -> bool:
    """Is ``e`` an elementwise (VPU-liftable) expression over Var ``bound``?

    Returns whether ``bound`` actually occurs; raises AssertionError on any
    non-elementwise node or on an occurrence of ``forbid`` (the accumulator
    in vpu_reduce's side condition)."""
    assert isinstance(e, _ELEMWISE_NODES), \
        f"not elementwise: {type(e).__name__}"
    if isinstance(e, P.Var):
        assert forbid is None or e.name != forbid, \
            "accumulator occurs inside the mapped expression"
        return e.name == bound
    occurs = False
    for fname in ("e", "a", "b"):
        sub = getattr(e, fname, None)
        if isinstance(sub, P.Phrase):
            occurs |= _elementwise_over(sub, bound, forbid)
    return occurs


def vpu_reduce(r: P.Reduce) -> P.Phrase:
    """reduce (λx a. a ⊕ g x) z xs  ->  fullReduce ⊕ (g* xs).

    Side conditions: ⊕ is add/max with z its unit literal, g is elementwise
    in x and free of the accumulator — then the whole reduction is one
    whole-block VPU op over the lifted g (UnOp/BinOp are elementwise at
    array types already, so substituting xs for x *is* the lift g*)."""
    assert isinstance(r, P.Reduce), "vpu_reduce: not a reduce"
    d = P.exp_data(r.e)
    assert isinstance(d, Arr), "vpu_reduce: input is not an array"
    x = P.Var(P.fresh("_vx"), P.ExpT(d.elem))
    a = P.Var(P.fresh("_va"), P.ExpT(P.exp_data(r.init)))
    body = r.f(x, a)
    assert isinstance(body, P.BinOp) and body.op in ("add", "max"), \
        "vpu_reduce: reducer is not acc ⊕ g(x) for ⊕ in {add, max}"
    if isinstance(body.a, P.Var) and body.a.name == a.name:
        g = body.b
    elif isinstance(body.b, P.Var) and body.b.name == a.name:
        g = body.a
    else:
        raise AssertionError("vpu_reduce: accumulator is not a bare operand")
    assert _elementwise_over(g, x.name, forbid=a.name), \
        "vpu_reduce: mapped expression must be elementwise in x"
    assert isinstance(r.init, P.Lit) and (
        (body.op == "add" and float(r.init.value) == 0.0)
        or (body.op == "max" and float(r.init.value) == float("-inf"))), \
        "vpu_reduce: init is not the unit of ⊕"
    return P.FullReduce(body.op, _subst(g, x.name, r.e))


def lift_lanes(m: P.Map) -> P.Phrase:
    """map (λx. g x) xs  ->  g* xs — one whole-block VPU op (lanes level).

    g must be elementwise in x (and mention it); broadcasting scalar frees
    like ``alpha`` are fine, which is exactly how ``strategy_scal``'s
    per-block body arises from the naive spec."""
    assert isinstance(m, P.Map), "lift_lanes: not a map"
    d = P.exp_data(m.e)
    assert isinstance(d, Arr) and isinstance(d.elem, (Num, Vec)), \
        "lift_lanes: input is not an array of scalars/vectors"
    x = P.Var(P.fresh("_lx"), P.ExpT(d.elem))
    body = m.f(x)
    assert _elementwise_over(body, x.name), \
        "lift_lanes: body must be elementwise in x (and mention it)"
    return _subst(body, x.name, m.e)


def tiled_matmul_expr(a: P.Phrase, b: P.Phrase, n: int, bm: int, bk: int
                      ) -> P.Phrase:
    """The canonical TPU matmul shape over operands ``a : (m,k)`` and
    ``b : (k,n)``: grid over bm row blocks of A, sequential MXU
    accumulation over bk-wide k chunks.  Shared by the ``strategy_matmul``
    builder and the ``tile_matmul`` rewrite, so the derived and the
    hand-written schedules are the same term."""
    def per_block(ablk):
        # k-chunks of the A block as pure re-views (no materialisation):
        # Split(bk, Transpose(ablk)) : (k/bk, bk, bm) — chunk^T per step.
        zipped = P.Zip(P.Split(bk, P.Transpose(ablk)), P.Split(bk, b))
        return P.Reduce(
            lambda ab, acc: P.add(
                acc, P.DotBlock(P.Transpose(P.Fst(ab)), P.Snd(ab))),
            P.Lit(0.0, Arr(bm, Arr(n, Num()))),
            zipped, level=P.SEQ)

    return P.Join(P.Map(per_block, P.Split(bm, a), level=P.GRID(0)))


def tile_matmul(e: P.Phrase, bm: int, bk: int) -> P.Phrase:
    """naive matmul (map over A rows of a map over B^T columns of a dot)
    ->  grid-blocked MXU accumulation (``tiled_matmul_expr``)."""
    assert isinstance(e, P.Map), "tile_matmul: not a map"
    da = P.exp_data(e.e)
    assert isinstance(da, Arr) and isinstance(da.elem, Arr), \
        "tile_matmul: lhs is not a matrix"
    m, k = da.n, da.elem.n
    row = P.Var(P.fresh("_row"), P.ExpT(da.elem))
    body = e.f(row)
    assert isinstance(body, P.Map) and isinstance(body.e, P.Transpose), \
        "tile_matmul: body is not a map over a transposed rhs"
    bexpr = body.e.e
    db = P.exp_data(bexpr)
    assert isinstance(db, Arr) and isinstance(db.elem, Arr) and db.n == k, \
        "tile_matmul: rhs contraction extent mismatch"
    col = P.Var(P.fresh("_col"), P.ExpT(Arr(k, db.elem.elem)))
    assert isinstance(body.f(col), P.Reduce), \
        "tile_matmul: inner body is not a dot-style reduction"
    assert m % bm == 0 and k % bk == 0, "tile_matmul: tiles must divide"
    return tiled_matmul_expr(e.e, bexpr, db.elem.n, bm, bk)


# ---------------------------------------------------------------------------
# strategy enumeration / search (the ICFP'15 search, miniaturised).
# The real autotuner lives in repro.autotune (generalised spaces, analytic
# cost model, measured refinement, persistent cache); these entry points are
# kept as thin compatibility shims over it.
# ---------------------------------------------------------------------------

def enumerate_dot_strategies(n: int, blocks: Iterable[int] = (256, 1024, 2048),
                             lanes: Iterable[int] = (128,)) -> List[dict]:
    """Strategy space for dot-product-like reductions of length n.

    Compatibility shim: delegates to ``repro.autotune.space`` (which holds
    the generalised per-kernel spaces) and preserves the seed's output
    format of ``{"block": b, "vector": w|None}`` dicts."""
    from repro.autotune import space as _space
    return _space.dot_param_grid(n, blocks=blocks, lanes=lanes)


def search(candidates: List[P.Phrase], cost_fn: Callable[[P.Phrase], float]
           ) -> P.Phrase:
    """Pick the candidate strategy minimising ``cost_fn`` (compiled cost).

    Deterministic: NaN costs are treated as +inf, and ties (including the
    all-infinite case) are broken by earliest position in ``candidates``,
    so a fixed candidate order always yields the same winner.

    A ``cost_fn`` that *raises* on some candidate (a cost model that cannot
    price an exotic term) skips that candidate — warned once per process,
    with an obs event per occurrence — instead of aborting the search; if
    every candidate raises, the first is returned like the all-infinite
    case."""
    if not candidates:
        raise ValueError(
            "strategies.search: empty candidate list — enumerate a "
            "non-empty strategy space first (see repro.autotune.space; "
            "e.g. no block size divides the input extent)")
    best, best_c = candidates[0], float("inf")
    for i, c in enumerate(candidates):
        try:
            cost = cost_fn(c)
        except Exception as e:  # noqa: BLE001 — cost failure skips, not aborts
            _warn_cost_failure(i, e)
            continue
        if cost == cost and cost < best_c:  # NaN-safe strict improvement
            best, best_c = c, cost
    return best


_warned_cost_failure = False


def _warn_cost_failure(index: int, exc: Exception) -> None:
    global _warned_cost_failure
    try:
        from repro import obs
        obs.event("strategies.search.cost_error", candidate=index,
                  error=f"{type(exc).__name__}: {exc}")
    except Exception:
        pass  # observability must never break the search
    if not _warned_cost_failure:
        _warned_cost_failure = True
        import warnings
        warnings.warn(
            f"strategies.search: cost_fn raised on candidate {index} "
            f"({type(exc).__name__}: {exc}); skipping it (warned once "
            f"per process, every occurrence emits an obs event)",
            RuntimeWarning, stacklevel=3)
