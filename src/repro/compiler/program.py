"""The staged compilation pipeline as a first-class object.

A :class:`Program` wraps a DPIA functional term plus its argument Vars and
exposes the paper's pipeline as explicit stages:

    prog = compiler.Program(expr, arg_vars)        # functional term
    prog = compiler.Program.from_kernel("dot", n=4096)   # or a named kernel

    fn = prog.check()           # SCIR: well-typed + data-race free
               .lower()         # strategy rewrites + Stage I -> II
               .compile("pallas")   # Stage III via the backend registry

``lower`` optionally takes a *strategy*: a ``repro.strategy.Strategy``
program (combinator language over the rewrites — the application's trace is
kept on ``Program.strategy_trace``), a serialised trace doc (deterministic
replay of an earlier derivation), a rewrite callable (``expr -> expr``), a
tuned-params dict (the ``repro.autotune`` vocabulary, for named kernels),
or the string ``"autotune"`` to resolve params through the tuner's cost
model + persistent cache.  ``compile`` resolves its backend
through :mod:`repro.compiler.backends` and threads
:class:`~repro.compiler.options.CompileOptions` explicitly — no globals.

``Program.from_imperative`` wraps an already-imperative SCIR command (e.g. a
hand-written kernel) so it can be race-checked and compiled through the same
staged interface; :meth:`Program.check` raises
:class:`repro.core.dpia.check.RaceError` on racy terms like the paper's
section 3.3 example.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro import obs
from repro.core.dpia import check as check_mod
from repro.core.dpia import phrases as P
from repro.core.dpia import stage1, stage2
from repro.core.dpia.types import AccT

from .backends import Backend, get_backend
from .options import CompileOptions, current_options

__all__ = ["Program", "CompiledKernel", "program"]

EXPORT_VERSION = 1

Strategy = Union[None, str, Dict[str, object], Callable[[P.Phrase], P.Phrase]]

OUT_NAME = "out#"


class CompiledKernel:
    """Callable produced by :meth:`Program.compile`, with its provenance."""

    def __init__(self, fn: Callable, program: "Program", backend: str):
        self._fn = fn
        self.program = program
        self.backend = backend

    def __call__(self, *args):
        return self._fn(*args)

    def __repr__(self):
        return (f"<CompiledKernel {self.program.name!r} "
                f"backend={self.backend!r}>")


class Program:
    """A DPIA term + argument specs, compiled in explicit stages.

    ``kernel``/``shape`` are optional metadata identifying one of the named
    benchmark kernels at a concrete shape; they enable params-dict and
    ``"autotune"`` strategies in :meth:`lower`.
    """

    def __init__(self, expr: Optional[P.Phrase], arg_vars: Sequence[P.Var],
                 *, name: Optional[str] = None, kernel: Optional[str] = None,
                 shape: Optional[Dict[str, int]] = None):
        self.expr = expr
        self.arg_vars: List[P.Var] = list(arg_vars)
        self.kernel = kernel
        self.shape: Dict[str, int] = dict(shape or {})
        self.name = name or kernel or "program"
        self.strategy_trace: Optional[dict] = None  # how the term was derived
        self._cmd: Optional[P.Phrase] = None
        self._out: Optional[P.Var] = None
        self._checked = False

    # ---- constructors ------------------------------------------------------

    @classmethod
    def from_builder(cls, builder: Callable, **meta) -> "Program":
        """From a ``() -> (expr, arg_vars)`` builder (the dpia_blas idiom)."""
        expr, arg_vars = builder()
        return cls(expr, arg_vars, **meta)

    @classmethod
    def from_kernel(cls, kernel: str, *, params: Optional[dict] = None,
                    **shape) -> "Program":
        """A named benchmark kernel at a concrete shape.

        ``params`` picks a point of the kernel's strategy space (the
        ``repro.autotune`` vocabulary); None means the un-tuned default."""
        from repro.autotune import space as space_mod
        if params is None:
            params = space_mod.default_params(kernel, **shape)
        cand = space_mod.candidate_from_params(kernel, dict(params), **shape)
        expr, arg_vars = cand.build()
        prog = cls(expr, arg_vars, kernel=kernel, shape=shape, name=kernel)
        try:
            prog.strategy_trace = cand.trace_doc()
        except Exception:
            prog.strategy_trace = None
        return prog

    @classmethod
    def from_imperative(cls, cmd: P.Phrase, arg_vars: Sequence[P.Var],
                        out: P.Var, *, name: Optional[str] = None
                        ) -> "Program":
        """Wrap an already-imperative SCIR command (out is its acceptor Var).

        The program is born lowered; ``check()`` runs the SCIR discipline on
        the command as given, ``compile()`` hands it straight to Stage III."""
        if not isinstance(out.t, AccT):
            raise TypeError(f"from_imperative: out must be acc-typed, got "
                            f"{out.t}")
        prog = cls(None, arg_vars, name=name or "imperative")
        prog._cmd, prog._out = cmd, out
        return prog

    # ---- stage I-II --------------------------------------------------------

    def _translated(self):
        """(imperative command, out Var) for the current term, cached."""
        if self._cmd is None:
            if self.expr is None:
                raise ValueError("program has neither a functional term nor "
                                 "an imperative command")
            with obs.span("compiler.lower", program=self.name):
                d = P.exp_data(self.expr)
                out = P.Var(OUT_NAME, AccT(d))
                self._cmd = stage2.expand(stage1.translate(self.expr, out))
                self._out = out
        return self._cmd, self._out

    @property
    def imperative(self) -> P.Phrase:
        """The Stage I->II translation (imperative DPIA) of this program."""
        return self._translated()[0]

    # ---- staged API --------------------------------------------------------

    def check(self) -> "Program":
        """SCIR check: well-typed + data-race free.  Fluent (returns self).

        Raises ``DpiaTypeError`` / ``RaceError`` on violation."""
        cmd, _ = self._translated()
        with obs.span("compiler.check", program=self.name):
            check_mod.check(cmd)
        self._checked = True
        return self

    def lower(self, strategy: Strategy = None, *,
              options: Optional[CompileOptions] = None) -> "Program":
        """Fix the strategy and translate to imperative DPIA (Stage I->II).

        strategy:
          None            — the term already *is* the strategy (default);
          Strategy        — a ``repro.strategy`` program; applied to the
                            term, failure raises, the trace is recorded on
                            the result's ``strategy_trace``;
          trace doc       — a serialised ``StrategyTrace`` (dict with
                            "steps"); deterministic replay of a derivation;
          callable        — a rewrite ``expr -> expr`` (semantics-preserving
                            by the caller's obligation; re-check after);
          params dict     — a point of this kernel's strategy space
                            (requires kernel/shape metadata);
          "autotune"      — resolve params via repro.autotune (cost model +
                            persistent cache; backend/cache from options).

        Returns self when the term is unchanged, else a new Program (whose
        ``check()`` state starts fresh — rewrites must be re-checked)."""
        if strategy is None:
            self._translated()
            return self
        if self.expr is None:
            raise ValueError("lower(strategy): an imperative-only Program "
                             "has no functional term to rewrite")
        from repro import strategy as strategy_mod
        if isinstance(strategy, strategy_mod.Strategy):
            res = strategy.apply(self.expr)
            if not res.ok:
                raise ValueError(f"lower(strategy): strategy program failed "
                                 f"on {self.name!r}: {res.reason}")
            prog = Program(res.phrase, self.arg_vars, name=self.name,
                           kernel=self.kernel, shape=self.shape)
            prog.strategy_trace = res.trace.to_doc()
            prog._translated()
            return prog
        if isinstance(strategy, dict) and strategy_mod.is_trace_doc(strategy):
            res = strategy_mod.replay(strategy, self.expr)
            if not res.ok:
                raise ValueError(f"lower(trace): replay failed on "
                                 f"{self.name!r}: {res.reason}")
            prog = Program(res.phrase, self.arg_vars, name=self.name,
                           kernel=self.kernel, shape=self.shape)
            prog.strategy_trace = res.trace.to_doc()
            prog._translated()
            return prog
        if callable(strategy):
            expr2 = strategy(self.expr)
            prog = Program(expr2, self.arg_vars, name=self.name,
                           kernel=self.kernel, shape=self.shape)
            prog._translated()
            return prog
        if strategy == "autotune":
            if self.kernel is None:
                raise ValueError(
                    'lower("autotune") needs kernel/shape metadata — build '
                    'the Program with from_kernel(...) or pass a params dict')
            from repro import autotune
            opts = options or current_options()
            params = autotune.get_tuned(
                self.kernel, backend=opts.dpia_backend,
                cache=opts.tuning_cache, **self.shape)
            return self.lower(params)
        if isinstance(strategy, dict):
            if self.kernel is None:
                raise ValueError(
                    "lower(params) needs kernel/shape metadata — build the "
                    "Program with from_kernel(...)")
            prog = Program.from_kernel(self.kernel, params=strategy,
                                       **self.shape)
            prog._translated()
            return prog
        raise TypeError(f"lower: bad strategy {strategy!r}; expected None, a "
                        f"rewrite callable, a params dict, or 'autotune'")

    def compile(self, backend: Union[None, str, Backend] = None, *,
                options: Optional[CompileOptions] = None,
                jit: Optional[bool] = None, **backend_kw) -> CompiledKernel:
        """Stage III: emit an executable callable via the backend registry.

        ``backend`` is a registered backend name/alias or Backend instance
        (default: the one implied by the active options).  Extra keyword
        arguments go to the backend's code generator when it accepts them."""
        opts = options or current_options()
        b = get_backend(backend if backend is not None else opts.dpia_backend)
        missing = [r for r in b.requires if r not in backend_kw]
        if "mesh" in missing:
            # a mesh requirement is satisfiable from the options / the
            # process mesh context — explicit backend_kw still wins
            mesh = opts.resolved_mesh()
            if mesh is not None:
                backend_kw["mesh"] = mesh
                missing.remove("mesh")
        if missing:
            raise TypeError(f"backend {b.name!r} requires keyword "
                            f"argument(s) {missing} (e.g. the mesh for "
                            f"shard_map — pass mesh=, set "
                            f"compiler.options(mesh=...), or "
                            f"sharding.ctx.set_mesh)")
        if self.expr is None and "lowered" not in b.accepts:
            raise ValueError(
                f"backend {b.name!r} consumes functional terms only and "
                f"this Program is imperative-only (from_imperative); use a "
                f"backend that accepts 'lowered'")
        call_kw = dict(backend_kw)
        if "interpret" in b.accepts:
            call_kw.setdefault("interpret", opts.interpret)
        if "lowered" in b.accepts and self._cmd is not None:
            call_kw.setdefault("lowered", (self._cmd, self._out))
        if "check" in b.accepts:
            # an already-checked program need not be re-checked in Stage III
            call_kw.setdefault("check", not self._checked)
        with obs.span("compiler.compile", program=self.name, backend=b.name):
            fn = b.compile(self.expr, self.arg_vars, **call_kw)
        if jit if jit is not None else opts.jit:
            import jax
            fn = jax.jit(fn)
        return CompiledKernel(fn, self, b.name)

    # ---- AOT persistence ---------------------------------------------------

    def to_doc(self) -> dict:
        """JSON-able document of this program's *lowered* form.

        Triggers Stage I->II if the program has not been lowered yet.  The
        document persists the imperative command (serialised through
        :mod:`repro.compiler.serialize`), the argument/out Vars, and the
        kernel/shape metadata — everything a later process needs to jump
        straight to Stage III."""
        from . import serialize
        cmd, out = self._translated()
        return {
            "version": EXPORT_VERSION,
            "name": self.name,
            "kernel": self.kernel,
            "shape": dict(self.shape),
            "args": [serialize.var_to_doc(v) for v in self.arg_vars],
            "out": serialize.var_to_doc(out),
            "checked": bool(self._checked),
            "strategy_trace": self.strategy_trace,
            "cmd": serialize.phrase_to_doc(cmd),
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "Program":
        """Rebuild a lowered Program from :meth:`to_doc` output.

        The result is imperative-only (its functional term is gone — the
        strategy was already fixed before export), so ``compile`` requires a
        backend that accepts lowered commands (jnp/pallas do).  The persisted
        ``checked`` bit is trusted: an artefact exported after ``check()``
        does not re-run the SCIR discipline on load."""
        from . import serialize
        if doc.get("version") != EXPORT_VERSION:
            raise ValueError(f"Program.from_doc: unsupported export version "
                             f"{doc.get('version')!r}")
        args = [serialize.var_from_doc(a) for a in doc["args"]]
        prog = cls(None, args, name=doc.get("name"),
                   kernel=doc.get("kernel"), shape=doc.get("shape") or {})
        prog._cmd = serialize.phrase_from_doc(doc["cmd"])
        prog._out = serialize.var_from_doc(doc["out"])
        prog._checked = bool(doc.get("checked"))
        prog.strategy_trace = doc.get("strategy_trace")
        return prog

    def export(self, path: str) -> str:
        """Write the lowered program to ``path`` (atomic tmp+rename)."""
        doc = self.to_doc()
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".program-", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    @classmethod
    def load(cls, path: str) -> "Program":
        """Read a program exported with :meth:`export` (skips Stage I->II)."""
        with open(path) as f:
            return cls.from_doc(json.load(f))

    # ---- sugar -------------------------------------------------------------

    def show(self) -> str:
        """Pretty-printed imperative form (for inspection/teaching)."""
        from repro.core.dpia.pretty import show
        return show(self.imperative)

    def __repr__(self):
        stage = ("imperative" if self.expr is None else
                 "lowered" if self._cmd is not None else "functional")
        chk = "+checked" if self._checked else ""
        return (f"<Program {self.name!r} args="
                f"{[v.name for v in self.arg_vars]} {stage}{chk}>")


def program(expr: P.Phrase, arg_vars: Sequence[P.Var], **meta) -> Program:
    """Convenience constructor: ``compiler.program(expr, args)``."""
    return Program(expr, arg_vars, **meta)
