"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests see the real
single CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count before importing jax."""
import os
import tempfile

import numpy as np
import pytest

# the suite is written against the host CPU platform (see note above); on
# images that ship libtpu, keep jax from probing/initialising a TPU backend
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# keep the strategy autotuner's persistent cache out of the user's home dir
# (repro.autotune reads this env var lazily, so setting it here is enough)
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-autotune-"), "autotune.json"))


@pytest.fixture
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def forced_devices(tmp_path):
    """Run a Python snippet on a forced N-device CPU platform, subprocess-
    safe: jax in THIS process is already initialised single-device, and
    ``--xla_force_host_platform_device_count`` only works if set before jax
    initialises — so multi-device tests run the snippet in a fresh
    interpreter with the flag in its environment.  Each run gets an
    isolated autotune cache under tmp_path.

    Usage::

        r = forced_devices(SCRIPT)            # 8 devices, 600 s timeout
        assert "OK" in r.stdout, r.stdout + r.stderr
    """
    import subprocess
    import sys

    def run(script: str, n: int = 8, timeout: int = 600, extra_env=None):
        env = {
            "PYTHONPATH": "src",
            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
            "HOME": os.environ.get("HOME", "/root"),
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
            "REPRO_AUTOTUNE_CACHE": str(tmp_path / "autotune.json"),
        }
        env.update(extra_env or {})
        return subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True,
                              timeout=timeout, env=env)
    return run


@pytest.fixture
def tuning_cache(tmp_path):
    """A fresh, isolated persistent tuning cache."""
    from repro.autotune import TuningCache
    return TuningCache(str(tmp_path / "autotune.json"))
