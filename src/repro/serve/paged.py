"""Paged KV-cache: the host-side block-pool allocator behind
``kv_layout="paged"``.

The dense serving cache allocates ``(slots, max_seq)`` KV positions per
layer up front — every slot pays for the longest request the engine might
ever see.  The paged layout stores KV as a pool of fixed-size pages,
``(n_blocks, block_size, kv_heads, head_dim)`` per layer, and gives each
slot a *block table*: a ``(max_blocks,)`` int32 row mapping the slot's
logical position ``p`` to physical page ``table[p // block_size]`` at
offset ``p % block_size``.  Peak KV memory is then a *policy* (the pool
size), sized for the traffic actually served instead of the worst case —
the strategy-preservation reading: memory layout is an explicit, tunable
choice (``repro.autotune.pick_kv_layout``), not a by-product of lowering.

This module owns the HOST side: block accounting (allocate on admission,
free on retire), table-row construction, and byte accounting for the
benchmark/tuner.  The DEVICE side — page gather/scatter and the paged
attention variants — lives in ``repro.models.attention``
(``paged_attention_prefill`` / ``paged_attention_decode_inplace``); the
shared convention is the **sentinel**: table entries ``>= n_blocks`` mean
"no page here", scatters through them drop (``mode='drop'``, the same
out-of-range discipline as the dense cache past ``max_seq``) and gathers
through them are masked by the attention's ``kpos <= pos`` validity test.
"""
from __future__ import annotations

from typing import Dict, List

__all__ = ["BlockPool", "blocks_for", "table_row", "dtype_bytes",
           "dense_kv_bytes", "paged_kv_bytes"]


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache positions (>= 1)."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    return max(1, -(-int(n_tokens) // int(block_size)))


class BlockPool:
    """Free-list allocator over ``n_blocks`` KV pages of ``block_size``
    positions each.

    Deterministic: blocks are handed out in ascending id order from a
    LIFO free list, so a given admission sequence always produces the same
    tables (the paged engine's token-identity tests rely on runs being
    reproducible).  Owners are opaque keys (the engine uses slot indices);
    ``free(owner)`` returns every page the owner holds, so retirement can
    never leak."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._owned: Dict[object, List[int]] = {}

    # -- accounting ----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def owned(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    # -- allocate / free -----------------------------------------------------

    def alloc(self, owner, n: int) -> List[int]:
        """Take ``n`` pages for ``owner`` (appends to its existing pages)."""
        if n < 0:
            raise ValueError(f"alloc: n must be >= 0, got {n}")
        if n > len(self._free):
            raise ValueError(
                f"block pool exhausted: owner {owner!r} asked for {n} "
                f"blocks, {len(self._free)} free of {self.n_blocks}")
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(got)
        return got

    def free(self, owner) -> int:
        """Return every page ``owner`` holds; returns how many were freed."""
        got = self._owned.pop(owner, [])
        self._free.extend(reversed(got))  # LIFO: freed pages are reused first
        return len(got)

    def stats(self) -> Dict[str, int]:
        return {"n_blocks": self.n_blocks, "block_size": self.block_size,
                "free": self.free_blocks, "used": self.used_blocks,
                "owners": len(self._owned)}

    # -- integrity -----------------------------------------------------------

    def validate(self) -> List[str]:
        """Check the allocator invariants; returns the violations (empty =
        healthy).  A violated pool means block tables may alias KV pages
        across requests — the serving engine treats any violation as
        corruption and degrades paged -> dense rather than keep writing
        through a damaged mapping.

        Invariants: every block id in range; no id both free and owned; no
        id owned twice or free twice; free + owned covers exactly the pool.
        """
        problems: List[str] = []
        seen: Dict[int, str] = {}
        for b in self._free:
            if not 0 <= b < self.n_blocks:
                problems.append(f"free list holds out-of-range block {b}")
            elif b in seen:
                problems.append(f"block {b} on the free list twice")
            else:
                seen[b] = "free"
        for owner, blocks in self._owned.items():
            for b in blocks:
                if not 0 <= b < self.n_blocks:
                    problems.append(f"owner {owner!r} holds out-of-range "
                                    f"block {b}")
                elif b in seen:
                    problems.append(f"block {b} double-booked "
                                    f"({seen[b]} and owner {owner!r})")
                else:
                    seen[b] = f"owner {owner!r}"
        if not problems and len(seen) != self.n_blocks:
            missing = [b for b in range(self.n_blocks) if b not in seen]
            problems.append(f"blocks neither free nor owned: {missing[:8]}")
        return problems


def table_row(blocks: List[int], max_blocks: int, sentinel: int) -> List[int]:
    """A slot's full ``(max_blocks,)`` table row: its pages in logical
    order, sentinel-padded.  The whole row is written on admission so a
    previous occupant's mapping can never leak into a reused slot."""
    if len(blocks) > max_blocks:
        raise ValueError(f"{len(blocks)} blocks exceed the table width "
                         f"{max_blocks}")
    return list(blocks) + [int(sentinel)] * (max_blocks - len(blocks))


# ---------------------------------------------------------------------------
# byte accounting (benchmark / tuner)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
                "float8_e4m3fn": 1, "float8_e5m2": 1, "int8": 1}


def dtype_bytes(dtype) -> int:
    """Bytes per KV element — the one table the byte accounting AND the
    layout planner (:func:`repro.autotune.pick_kv_layout`) share, so a new
    cache dtype cannot be priced differently in the two places."""
    return _DTYPE_BYTES.get(str(dtype), 4)


def _kv_layers(cfg) -> int:
    """KV-carrying layers: none for ssm, one shared block per group for
    hybrid, every layer otherwise."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def dense_kv_bytes(cfg, slots: int, max_seq: int) -> int:
    """Resident bytes of the dense ``(slots, max_seq)`` KV cache."""
    db = dtype_bytes(cfg.dtype)
    return 2 * _kv_layers(cfg) * slots * max_seq * cfg.n_kv_heads * cfg.hd * db


def paged_kv_bytes(cfg, n_blocks: int, block_size: int) -> int:
    """Resident bytes of the paged pool (tables are int32 noise on top)."""
    db = dtype_bytes(cfg.dtype)
    return (2 * _kv_layers(cfg) * n_blocks * block_size
            * cfg.n_kv_heads * cfg.hd * db)
