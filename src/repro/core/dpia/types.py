"""DPIA types: data types and phrase types (paper Fig. 1).

Data types classify *data* (what lives in buffers); phrase types classify
*program parts* (expressions, acceptors, commands, functions) — the defining
split of Idealised Algol.

Adaptations for TPU (DESIGN.md section 2):
  * ``Num`` carries a dtype (the paper has a single ``num``).
  * ``Vec`` is the paper's OpenCL vector type ``num<n>`` (section 6.2); on TPU we
    use it for lane-aligned blocks (width 128 rather than 4).
  * Sizes are concrete Python ints.  JAX shapes are static, so the paper's
    symbolic nat-indexed types specialise to concrete indices at compile time;
    the type-equality rule (Fig. 1c) becomes integer equality.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple, Union

import numpy as np


# ---------------------------------------------------------------------------
# Data types  (Fig. 1e)
# ---------------------------------------------------------------------------

class DataType:
    """Base class of DPIA data types."""

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return show_data(self)


@dataclass(frozen=True)
class Num(DataType):
    """Scalar numeric data; ``dtype`` is a jnp dtype name."""
    dtype: str = "float32"


@dataclass(frozen=True)
class Idx(DataType):
    """Array index bounded by ``n`` (the paper's ``idx(n)``)."""
    n: int


@dataclass(frozen=True)
class Arr(DataType):
    """Homogeneous array ``n.elem`` of size ``n``."""
    n: int
    elem: DataType


@dataclass(frozen=True)
class Pair(DataType):
    """Heterogeneous pair ``fst x snd`` (struct-of-arrays in buffers)."""
    fst: DataType
    snd: DataType


@dataclass(frozen=True)
class Vec(DataType):
    """Vector type ``num<n>`` (paper section 6.2).  TPU: a lane-aligned block."""
    n: int
    dtype: str = "float32"


def arr(*dims: int, elem: DataType = None, dtype: str = "float32") -> DataType:
    """``arr(4, 8)`` == ``Arr(4, Arr(8, Num()))``."""
    e = elem if elem is not None else Num(dtype)
    for d in reversed(dims):
        e = Arr(d, e)
    return e


def show_data(d: DataType) -> str:
    if isinstance(d, Num):
        return f"num[{d.dtype}]" if d.dtype != "float32" else "num"
    if isinstance(d, Idx):
        return f"idx({d.n})"
    if isinstance(d, Arr):
        return f"{d.n}.{show_data(d.elem)}"
    if isinstance(d, Pair):
        return f"({show_data(d.fst)} x {show_data(d.snd)})"
    if isinstance(d, Vec):
        return f"num<{d.n}>[{d.dtype}]"
    raise TypeError(f"not a data type: {d!r}")


def data_eq(a: DataType, b: DataType) -> bool:
    """Type equality (Fig. 1c); sizes are concrete so this is structural."""
    return a == b


def shape_of(d: DataType) -> Tuple[int, ...]:
    """Leading array shape of a data type, stopping at Pair boundaries."""
    if isinstance(d, Arr):
        return (d.n,) + shape_of(d.elem)
    if isinstance(d, Vec):
        return (d.n,)
    return ()


def scalar_of(d: DataType) -> DataType:
    """The non-array core reached by stripping Arr/Vec nesting."""
    if isinstance(d, Arr):
        return scalar_of(d.elem)
    if isinstance(d, Vec):
        return Num(d.dtype)
    return d


def dtype_of(d: DataType) -> str:
    """dtype of a (possibly nested-array) numeric data type."""
    core = scalar_of(d)
    if isinstance(core, Num):
        return core.dtype
    if isinstance(core, Idx):
        return "int32"
    raise TypeError(f"no single dtype for {show_data(d)}")


def is_numeric(d: DataType) -> bool:
    return isinstance(scalar_of(d), (Num, Idx))


def size_in_elems(d: DataType) -> int:
    if isinstance(d, (Num, Idx)):
        return 1
    if isinstance(d, Vec):
        return d.n
    if isinstance(d, Arr):
        return d.n * size_in_elems(d.elem)
    if isinstance(d, Pair):
        return size_in_elems(d.fst) + size_in_elems(d.snd)
    raise TypeError(d)


def zero_value(d: DataType):
    """Zero-initialised buffer pytree for a data type (paper: ``new`` zero-init).

    Buffers are pytrees: Arr adds a leading axis, Pair becomes a python tuple
    (struct-of-arrays), Vec adds a trailing lane axis.
    """
    import jax.numpy as jnp

    if isinstance(d, Num):
        return jnp.zeros((), dtype=d.dtype)
    if isinstance(d, Idx):
        return jnp.zeros((), dtype="int32")
    if isinstance(d, Vec):
        return jnp.zeros((d.n,), dtype=d.dtype)
    if isinstance(d, Arr):
        inner = zero_value(d.elem)
        import jax

        return jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (d.n,) + leaf.shape), inner
        )
    if isinstance(d, Pair):
        return (zero_value(d.fst), zero_value(d.snd))
    raise TypeError(d)


def value_matches(d: DataType, v) -> bool:
    """Does a buffer pytree ``v`` inhabit data type ``d``?"""
    if isinstance(d, (Num, Idx)):
        return hasattr(v, "shape") and v.shape == ()
    if isinstance(d, Vec):
        return hasattr(v, "shape") and v.shape == (d.n,)
    if isinstance(d, Arr):
        if isinstance(v, tuple):
            return all(
                value_matches(Arr(d.n, sub), piece)
                for sub, piece in zip(_pair_parts(d.elem), v)
            )
        return hasattr(v, "shape") and len(v.shape) >= 1 and v.shape[0] == d.n
    if isinstance(d, Pair):
        return isinstance(v, tuple) and len(v) == 2
    return False


def _pair_parts(d: DataType):
    if isinstance(d, Pair):
        return (d.fst, d.snd)
    return (d,)


# ---------------------------------------------------------------------------
# Phrase types  (Fig. 1f) and passivity (Fig. 2)
# ---------------------------------------------------------------------------

class PhraseType:
    def __repr__(self) -> str:  # pragma: no cover - trivial
        return show_phrase_type(self)


@dataclass(frozen=True)
class ExpT(PhraseType):
    """Expression phrases: read the store, produce data of type ``d``."""
    d: DataType


@dataclass(frozen=True)
class AccT(PhraseType):
    """Acceptor phrases: writable l-values for data of type ``d``."""
    d: DataType


@dataclass(frozen=True)
class CommT(PhraseType):
    """Command phrases: modify the store."""


@dataclass(frozen=True)
class VarT(PhraseType):
    """``var[d] = acc[d] x exp[d]`` — the phrase pair introduced by ``new``."""
    d: DataType


@dataclass(frozen=True)
class FnT(PhraseType):
    """Phrase functions; ``passive=True`` is the paper's ``->p`` arrow."""
    arg: PhraseType
    ret: PhraseType
    passive: bool = False


def show_phrase_type(t: PhraseType) -> str:
    if isinstance(t, ExpT):
        return f"exp[{show_data(t.d)}]"
    if isinstance(t, AccT):
        return f"acc[{show_data(t.d)}]"
    if isinstance(t, CommT):
        return "comm"
    if isinstance(t, VarT):
        return f"var[{show_data(t.d)}]"
    if isinstance(t, FnT):
        arrow = "->p" if t.passive else "->"
        return f"({show_phrase_type(t.arg)} {arrow} {show_phrase_type(t.ret)})"
    raise TypeError(f"not a phrase type: {t!r}")


def is_passive(t: PhraseType) -> bool:
    """Fig. 2: exp types are passive; functions are passive if their return
    type is; ``->p`` functions are passive outright; acc/comm/var are active.
    """
    if isinstance(t, ExpT):
        return True
    if isinstance(t, (AccT, CommT, VarT)):
        return False
    if isinstance(t, FnT):
        return t.passive or is_passive(t.ret)
    raise TypeError(t)


def promote_dtype(a: str, b: str) -> str:
    return str(np.promote_types(a, b))
