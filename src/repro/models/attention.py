"""GQA multi-head attention: train/prefill + cached decode, qk_norm, bias.

Sharding intent (enforced by sharding/rules.py): head dims are split over the
'model' mesh axis (TP); with few KV heads (GQA) the KV cache shards batch over
'data' and heads over 'model' up to n_kv_heads, falling back to sequence
sharding for decode (flash-decode style partial-attention + LSE merge is in
serve/decode.py)."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import ModelConfig, apply_rope, init_dense, rmsnorm, rope_freqs


class AttnParams(NamedTuple):
    wq: jax.Array          # (d, nh*hd)
    wk: jax.Array          # (d, nkv*hd)
    wv: jax.Array          # (d, nkv*hd)
    wo: jax.Array          # (nh*hd, d)
    bq: Optional[jax.Array]
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]
    q_norm: Optional[jax.Array]   # (hd,) qk_norm scales
    k_norm: Optional[jax.Array]


def init_attn(key, cfg: ModelConfig) -> AttnParams:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    zeros = lambda n: jnp.zeros((n,), cfg.dtype)  # noqa: E731
    return AttnParams(
        wq=init_dense(ks[0], d, nh * hd, cfg.dtype),
        wk=init_dense(ks[1], d, nkv * hd, cfg.dtype),
        wv=init_dense(ks[2], d, nkv * hd, cfg.dtype),
        wo=init_dense(ks[3], nh * hd, d, cfg.dtype),
        bq=zeros(nh * hd) if cfg.qkv_bias else None,
        bk=zeros(nkv * hd) if cfg.qkv_bias else None,
        bv=zeros(nkv * hd) if cfg.qkv_bias else None,
        q_norm=jnp.ones((hd,), cfg.dtype) if cfg.qk_norm else None,
        k_norm=jnp.ones((hd,), cfg.dtype) if cfg.qk_norm else None,
    )


def _project_qkv(p: AttnParams, cfg: ModelConfig, x, positions):
    """x: (b, s, d) -> q (b, s, nh, hd), k/v (b, s, nkv, hd), roped."""
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p.wq)
    k = jnp.einsum("bsd,dh->bsh", x, p.wk)
    v = jnp.einsum("bsd,dh->bsh", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if p.q_norm is not None:
        q = rmsnorm(q, p.q_norm, cfg.norm_eps)
        k = rmsnorm(k, p.k_norm, cfg.norm_eps)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


NEG_INF = -1e30

# sequences above this use the chunked online-softmax path (flash-equivalent
# memory behaviour in pure XLA: no S x S score tensor is ever materialised)
CHUNKED_THRESHOLD = 1024
KV_CHUNK = 1024


def chunked_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                      kv_chunk: int = KV_CHUNK):
    """Online-softmax attention over KV chunks (lax.scan) — the XLA-lowerable
    flash attention used for training/prefill roofline paths; the Pallas
    kernel (kernels/flash_attention.py) is the TPU in-kernel version of the
    same recurrence.

    q: (b, sq, nh, hd); k/v: (b, sk, nkv, hd); GQA via nh % nkv == 0.
    """
    b, sq, nh, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    scale = 1.0 / jnp.sqrt(float(hd))
    # keep q/k/v in their storage dtype; accumulate dots in f32 on the MXU
    # (f32-converting the inputs materialises f32 copies of the whole k/v)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, sq, nkv, group, hd)
    n_chunks = max(sk // kv_chunk, 1)
    kc = k.reshape(b, n_chunks, kv_chunk, nkv, hd)
    vc = v.reshape(b, n_chunks, kv_chunk, nkv, hd)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        acc, m_i, l_i = carry
        j, k_j, v_j = inp                    # (b, kv_chunk, nkv, hd)
        s = jnp.einsum("bsngh,btnh->bsngt", qg, k_j,
                       preferred_element_type=jnp.float32)
        if causal:
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] <= qpos[:, None]       # (sq, kv_chunk)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p_, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bsngt,btnh->bsngh", p_.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    # carry inits derived from qg so the scan carry INHERITS q's sharding —
    # plain jnp.zeros is replicated and makes GSPMD unshard the whole chain
    # (measured: full-batch attention intermediates per partition; see
    # EXPERIMENTS.md section Perf, dbrx iteration 1)
    acc0 = (qg * 0.0).astype(jnp.float32)
    m0 = jnp.max(acc0, axis=-1) + NEG_INF
    l0 = jnp.max(acc0, axis=-1)
    idx = jnp.arange(n_chunks)
    (acc, m_i, l_i), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (idx, kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4)))
    l_safe = jnp.where(l_i == 0.0, 1.0, l_i)
    out = (acc / l_safe[..., None]).reshape(b, sq, nh, hd)
    return out


def attention(p: AttnParams, cfg: ModelConfig, x, positions):
    """Full self-attention over x (training / prefill without cache)."""
    b, s, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.use_flash:
        qf = q.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
        of = ops.flash_attention(qf, kf, vf, causal=True, impl="pallas")
        out = of.reshape(b, nh, s, hd).transpose(0, 2, 1, 3)
    elif s > CHUNKED_THRESHOLD and s % KV_CHUNK == 0:
        out = chunked_attention(q, k, v, causal=True)
    else:
        group = nh // nkv
        qg = q.reshape(b, s, nkv, group, hd)
        scores = jnp.einsum("bsngh,btnh->bngst", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) / jnp.sqrt(float(hd))
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bngst,btnh->bsngh", probs,
                         v.astype(jnp.float32)).reshape(b, s, nh, hd)
    out = out.astype(x.dtype).reshape(b, s, nh * hd)
    return jnp.einsum("bsh,hd->bsd", out, p.wo)


class KVCache(NamedTuple):
    k: jax.Array  # (b, max_seq, nkv, hd)
    v: jax.Array
    # position is tracked by the caller (same for the whole batch slice)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> KVCache:
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))


def attention_prefill(p: AttnParams, cfg: ModelConfig, x, cache: KVCache,
                      start: int = 0):
    """Prefill: run full attention AND fill the cache at [start, start+s)."""
    b, s, _ = x.shape
    positions = start + jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    new_cache = KVCache(
        jax.lax.dynamic_update_slice(cache.k, k, (0, start, 0, 0)),
        jax.lax.dynamic_update_slice(cache.v, v, (0, start, 0, 0)))
    out = attention(p, cfg, x, positions)
    return out, new_cache


def _attend_token(cfg: ModelConfig, q, k_l, v_l, pos, per_slot: bool,
                  x_dtype, wo):
    """The one-token masked-attention tail shared by every decode variant
    (dense, paged, paged-view): q (b, 1, nh, hd) against k_l/v_l
    (b, t, nkv, hd), valid where ``kpos <= pos`` — operation-for-operation
    identical across callers, which is what makes 'paged decode is bitwise
    the dense computation' a property of ONE code path."""
    b = q.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    group = nh // nkv
    qg = q.reshape(b, nkv, group, hd)
    scores = jnp.einsum("bngh,btnh->bngt", qg, k_l,
                        preferred_element_type=jnp.float32) \
        / jnp.sqrt(float(hd))
    t = k_l.shape[1]
    kpos = jnp.arange(t)[None, None, None, :]
    valid = kpos <= (pos[:, None, None, None] if per_slot else pos)
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnh->bngh", probs.astype(v_l.dtype), v_l,
                     preferred_element_type=jnp.float32)
    out = out.astype(x_dtype).reshape(b, 1, nh * hd)
    return jnp.einsum("bsh,hd->bsd", out, wo)


def _page_slots(pos, bt, block_size: int, n_blocks: int):
    """(page, offset) for per-slot positions against per-slot table rows
    (bt: (b, max_blocks)); sentinel/overflow map to page ``n_blocks`` —
    one past the pool, so scatters through them drop."""
    b, mb = bt.shape
    idx = pos // block_size
    safe = jnp.clip(idx, 0, mb - 1)
    page = jnp.where(idx < mb, bt[jnp.arange(b), safe], n_blocks)
    return page, pos % block_size


def attention_decode_inplace(p: AttnParams, cfg: ModelConfig, x, ck, cv,
                             li, pos):
    """One-token decode against LAYER-STACKED caches carried through the
    layer scan: the cache update is a single token-sized dynamic-update-slice
    on the stacked buffer (aliased in-place by XLA), instead of re-writing
    the whole layer cache through scan outputs — 60 GB/token -> ~100 KB/token
    of cache-write traffic at 500k context (EXPERIMENTS.md Perf, zamba2).

    ck/cv: (L, b, max_seq, nkv, hd); li: layer index; returns (out, ck, cv).

    ``pos`` is a scalar (lock-step batch, every slot at the same position)
    or a (b,) vector (continuous batching: each slot decodes at its own
    position).  The vector path writes the token via a per-slot scatter
    (mode='drop': a slot whose position has run past max_seq writes nothing
    instead of corrupting a neighbour) and masks attention per slot.
    """
    b, _, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else pos + jnp.zeros((b, 1),
                                                              jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    if per_slot:
        slots = jnp.arange(b)
        ck = ck.at[li, slots, pos].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[li, slots, pos].set(v[:, 0].astype(cv.dtype), mode="drop")
    else:
        zero = jnp.zeros((), jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k[None].astype(ck.dtype),
                                          (li, zero, pos, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v[None].astype(cv.dtype),
                                          (li, zero, pos, zero, zero))
    k_l = jax.lax.dynamic_index_in_dim(ck, li, axis=0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(cv, li, axis=0, keepdims=False)
    return _attend_token(cfg, q, k_l, v_l, pos, per_slot, x.dtype,
                         p.wo), ck, cv


# ---------------------------------------------------------------------------
# paged KV: page-mapped variants of prefill/decode (serve.paged owns the
# host-side block pool; the sentinel convention is shared: table entries
# >= n_blocks mean "no page", writes through them drop, reads are masked)
# ---------------------------------------------------------------------------


def init_paged_kv(cfg: ModelConfig, n_blocks: int, block_size: int
                  ) -> KVCache:
    """A paged KV pool: ``(n_blocks, block_size, nkv, hd)`` pages."""
    shape = (n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))


def _pages_for_positions(pos, bt_row, block_size: int, n_blocks: int):
    """(page, offset) for a vector of positions against ONE table row.

    Out-of-table positions and sentinel entries both map to page
    ``n_blocks`` — one past the pool — so ``.at[...].set(mode='drop')``
    discards the write, exactly like the dense cache drops writes past
    ``max_seq``."""
    pos = jnp.asarray(pos, jnp.int32)
    max_blocks = bt_row.shape[0]
    idx = pos // block_size
    safe = jnp.clip(idx, 0, max_blocks - 1)
    page = jnp.where(idx < max_blocks, bt_row[safe], n_blocks)
    return page, pos % block_size


def _gather_pages(pool, bt):
    """Gather a per-slot logical view from a page pool.

    pool: (n_blocks, block_size, nkv, hd); bt: (b, max_blocks) int32.
    Returns (b, max_blocks * block_size, nkv, hd).  Sentinel entries clip
    to an arbitrary real page — their positions are beyond every valid
    ``kpos <= pos`` mask, so the values are never attended; clipping keeps
    the gather maskless on the hot path."""
    nb = pool.shape[0]
    g = pool[jnp.clip(bt, 0, nb - 1)]          # (b, mb, bs, nkv, hd)
    return g.reshape(bt.shape[0], -1, *pool.shape[2:])


def _masked_attend(cfg: ModelConfig, q, k_all, v_all, qpos, kpos):
    """f32 masked attention of q (b, sq, nh, hd) against a gathered KV view
    (b, t, nkv, hd); valid where kpos (b|1, t) <= qpos (b, sq).  The same
    einsum/softmax discipline as :func:`attention`'s unchunked path, so a
    paged/cached prefill stays numerically in-family with the dense one."""
    b, sq, nh, hd = q.shape
    nkv = k_all.shape[2]
    group = nh // nkv
    qg = q.reshape(b, sq, nkv, group, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) / jnp.sqrt(float(hd))
    valid = kpos[:, None, None, None, :] <= qpos[:, None, None, :, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v_all.astype(jnp.float32))
    return out.reshape(b, sq, nh, hd)


def attention_prefill_cached(p: AttnParams, cfg: ModelConfig, x,
                             cache: KVCache, start):
    """Continuation prefill for a CHUNKED prompt against a dense cache:
    write this chunk's k/v at [start, start+s) and attend q against the
    whole cache masked by ``kpos <= qpos`` — earlier chunks' positions are
    already cached, so a prompt split across chunk boundaries sees exactly
    the attention a single-call prefill would.  ``start`` may be traced
    (one executable serves every chunk offset)."""
    b, s, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # scatter (mode='drop'), not dynamic_update_slice: a tail chunk whose
    # padded bucket overruns max_seq must DROP the out-of-range rows —
    # dynamic_update_slice would clamp the start and silently clobber
    # earlier positions (the same discipline as the paged sentinel)
    new_cache = KVCache(
        cache.k.at[:, positions[0]].set(k.astype(cache.k.dtype),
                                        mode="drop"),
        cache.v.at[:, positions[0]].set(v.astype(cache.v.dtype),
                                        mode="drop"))
    kpos = jnp.arange(new_cache.k.shape[1])[None, :]
    out = _masked_attend(cfg, q, new_cache.k, new_cache.v, positions, kpos)
    out = out.astype(x.dtype).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p.wo), new_cache


def paged_attention_prefill(p: AttnParams, cfg: ModelConfig, x, ck, cv, li,
                            bt_row, start, *, first: bool):
    """Prefill one prompt chunk into LAYER-STACKED page pools.

    x: (1, s, d); ck/cv: (L, n_blocks, block_size, nkv, hd); bt_row: the
    slot's (max_blocks,) block-table row; start: chunk offset (traced ok).
    k/v are scattered page-by-page (writes through sentinel/overflow
    entries drop — ``mode='drop'``, the dense out-of-range discipline).

    ``first`` (static) selects the attention path: the first chunk attends
    within x exactly like the dense :func:`attention_prefill` (bitwise the
    same computation, which is what keeps a paged engine token-identical to
    the dense oracle for prompts that fit one chunk); continuation chunks
    gather the slot's pages and attend masked by ``kpos <= qpos``."""
    b, s, _ = x.shape
    nb, bs = ck.shape[1], ck.shape[2]
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    page, off = _pages_for_positions(positions[0], bt_row, bs, nb)
    ck = ck.at[li, page, off].set(k[0].astype(ck.dtype), mode="drop")
    cv = cv.at[li, page, off].set(v[0].astype(cv.dtype), mode="drop")
    if first:
        out = attention(p, cfg, x, positions)
        return out, ck, cv
    k_l = jax.lax.dynamic_index_in_dim(ck, li, axis=0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(cv, li, axis=0, keepdims=False)
    k_all = _gather_pages(k_l, bt_row[None])
    v_all = _gather_pages(v_l, bt_row[None])
    kpos = jnp.arange(k_all.shape[1])[None, :]
    out = _masked_attend(cfg, q, k_all, v_all, positions, kpos)
    out = out.astype(x.dtype).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p.wo), ck, cv


def paged_attention_decode_inplace(p: AttnParams, cfg: ModelConfig, x, ck,
                                   cv, li, pos, bt):
    """One-token decode against layer-stacked page pools — the paged twin
    of :func:`attention_decode_inplace`'s per-slot path.

    ck/cv: (L, n_blocks, block_size, nkv, hd); pos: (b,) per-slot
    positions; bt: (b, max_blocks) block tables.  The new token is written
    through the table (drop on sentinel/overflow — a retired or
    mid-prefill lane whose position was parked at ``max_seq`` writes
    nothing); the read gathers the slot's pages into a
    ``(b, max_blocks * block_size, nkv, hd)`` view and runs the *identical*
    masked-attention math as the dense path, so paged decode is bitwise
    the dense computation whenever ``max_blocks * block_size == max_seq``.
    """
    b = x.shape[0]
    nb, bs = ck.shape[1], ck.shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    q, k, v = _project_qkv(p, cfg, x, pos[:, None])
    page, off = _page_slots(pos, bt, bs, nb)
    ck = ck.at[li, page, off].set(k[:, 0].astype(ck.dtype), mode="drop")
    cv = cv.at[li, page, off].set(v[:, 0].astype(cv.dtype), mode="drop")
    k_l = jax.lax.dynamic_index_in_dim(ck, li, axis=0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(cv, li, axis=0, keepdims=False)
    k_all = _gather_pages(k_l, bt)
    v_all = _gather_pages(v_l, bt)
    return _attend_token(cfg, q, k_all, v_all, pos, True, x.dtype,
                         p.wo), ck, cv


def gather_paged_view(ck, cv, bt) -> Tuple[jax.Array, jax.Array]:
    """Materialise the per-slot logical view of layer-stacked page pools.

    ck/cv: (L, n_blocks, block_size, nkv, hd); bt: (b, max_blocks).
    Returns (L, b, max_blocks * block_size, nkv, hd) pairs — shaped exactly
    like the dense layer-stacked cache, holding each slot's pages in
    logical order.  The decode chunk gathers this ONCE per chunk and
    updates it incrementally per token (:func:`paged_attention_decode_view`)
    instead of re-gathering every step/layer — the page indirection is paid
    per chunk, not per token."""
    nb = ck.shape[1]
    safe = jnp.clip(bt, 0, nb - 1)
    L = ck.shape[0]
    vk = ck[:, safe].reshape(L, bt.shape[0], -1, *ck.shape[3:])
    vv = cv[:, safe].reshape(L, bt.shape[0], -1, *cv.shape[3:])
    return vk, vv


def paged_attention_decode_view(p: AttnParams, cfg: ModelConfig, x, ck, cv,
                                vk, vv, li, pos, bt):
    """One-token decode against a pre-gathered per-slot view.

    The attention + view update are operation-for-operation the dense
    :func:`attention_decode_inplace` per-slot path on (vk, vv) — bitwise
    the dense computation — and the new token is ALSO scattered into the
    page pool (ck, cv) through the block table, so the pool stays the
    source of truth across chunk boundaries.  Writes drop both ways for a
    parked lane (pos past the view / sentinel page)."""
    b = x.shape[0]
    nb, bs = ck.shape[1], ck.shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    q, k, v = _project_qkv(p, cfg, x, pos[:, None])
    slots = jnp.arange(b)
    vk = vk.at[li, slots, pos].set(k[:, 0].astype(vk.dtype), mode="drop")
    vv = vv.at[li, slots, pos].set(v[:, 0].astype(vv.dtype), mode="drop")
    page, off = _page_slots(pos, bt, bs, nb)
    ck = ck.at[li, page, off].set(k[:, 0].astype(ck.dtype), mode="drop")
    cv = cv.at[li, page, off].set(v[:, 0].astype(cv.dtype), mode="drop")
    k_l = jax.lax.dynamic_index_in_dim(vk, li, axis=0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(vv, li, axis=0, keepdims=False)
    return _attend_token(cfg, q, k_l, v_l, pos, True, x.dtype,
                         p.wo), ck, cv, vk, vv


def attention_decode(p: AttnParams, cfg: ModelConfig, x, cache: KVCache,
                     pos):
    """One-token decode: x (b, 1, d); attends to cache[:pos+1]."""
    b, _, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = pos + jnp.zeros((b, 1), jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, pos, 0, 0))
    new_cache = KVCache(ck, cv)
    group = nh // nkv
    qg = q.reshape(b, nkv, group, hd)  # s=1 squeezed
    # bf16-in / f32-accumulate einsums: converting the whole cache to f32
    # materialised seq_len x hd x f32 copies per step (EXPERIMENTS.md Perf,
    # zamba2 iteration 1); preferred_element_type keeps accuracy on the MXU.
    scores = jnp.einsum("bngh,btnh->bngt", qg, ck,
                        preferred_element_type=jnp.float32) \
        / jnp.sqrt(float(hd))
    t = ck.shape[1]
    valid = jnp.arange(t)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnh->bngh", probs.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, 1, nh * hd)
    return jnp.einsum("bsh,hd->bsd", out, p.wo), new_cache
