"""repro.compiler — backend registry, options scoping, the staged Program
API, and the deprecation shims it replaces.

Covers the acceptance gate: all six benchmark ops (scal/asum/dot/matmul/
rmsnorm/softmax) run through ``Program.check().lower().compile(backend)``
for both jnp and pallas backends and match the interpreter oracle.
"""
import threading
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.core.dpia import interp, phrases as P
from repro.core.dpia.check import RaceError
from repro.core.dpia.types import AccT, Arr, Num
from repro.kernels import dpia_blas, ops, ref


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

class TestBackendRegistry:
    def test_builtins_registered(self):
        names = compiler.backend_names()
        assert {"jnp", "pallas", "shardmap"} <= set(names)

    def test_lookup_and_aliases(self):
        assert compiler.get_backend("jnp").name == "jnp"
        # the seed's impl-string spellings resolve as aliases
        assert compiler.get_backend("dpia-pallas").name == "pallas"
        b = compiler.get_backend("pallas")
        assert compiler.get_backend(b) is b  # pass-through

    def test_unknown_name_lists_registry(self):
        with pytest.raises(ValueError, match="jnp"):
            compiler.get_backend("not-a-backend")

    def test_ops_impls_derived_from_registry(self):
        impls = compiler.ops_impls()
        assert impls == ("xla", "pallas", "dpia-jnp", "dpia-pallas",
                         "dpia-shardmap")
        # shardmap's mesh requirement is satisfiable from the options /
        # process mesh context, so it IS an op-layer impl (repro.mesh)

    def test_register_custom_backend(self):
        def compile_interp(expr, arg_vars, **kw):
            names = [v.name for v in arg_vars]

            def fn(*args):
                return interp.interp(expr, dict(zip(names, args)))
            return fn

        backend = compiler.Backend(
            name="interp-test", compile=compile_interp,
            description="oracle semantics as a backend")
        compiler.register_backend(backend)
        try:
            # duplicate registration is refused without overwrite=True
            with pytest.raises(ValueError, match="already registered"):
                compiler.register_backend(backend)
            prog = compiler.Program.from_kernel("dot", n=64)
            fn = prog.check().lower().compile("interp-test", jit=False)
            rng = np.random.RandomState(0)
            x = jnp.asarray(rng.randn(64), "float32")
            y = jnp.asarray(rng.randn(64), "float32")
            np.testing.assert_allclose(np.asarray(fn(x, y)),
                                       np.asarray(ref.dot(x, y)), rtol=1e-4)
        finally:
            compiler.unregister_backend("interp-test")
        with pytest.raises(ValueError):
            compiler.get_backend("interp-test")


# ---------------------------------------------------------------------------
# options: explicit, scoped, thread-local
# ---------------------------------------------------------------------------

class TestOptions:
    def test_defaults(self):
        opts = compiler.current_options()
        assert opts.backend == "xla"
        assert opts.interpret is True

    def test_validation(self):
        with pytest.raises(ValueError, match="valid backends"):
            compiler.CompileOptions(backend="garbage")
        with pytest.raises(ValueError, match="valid backends"):
            with compiler.options(backend="garbage"):
                pass  # pragma: no cover

    def test_scoping_and_nesting(self):
        assert compiler.current_options().backend == "xla"
        with compiler.options(backend="dpia-jnp"):
            assert compiler.current_options().backend == "dpia-jnp"
            with compiler.options(autotune=False):
                inner = compiler.current_options()
                # inner scope inherits the outer backend
                assert inner.backend == "dpia-jnp"
                assert inner.autotune is False
            assert compiler.current_options().backend == "dpia-jnp"
        assert compiler.current_options().backend == "xla"

    def test_thread_locality(self):
        seen = {}

        def probe():
            seen["other"] = compiler.current_options().backend
            with compiler.options(backend="dpia-pallas"):
                seen["scoped"] = compiler.current_options().backend

        with compiler.options(backend="dpia-jnp"):
            t = threading.Thread(target=probe)
            t.start()
            t.join()
            # this thread's scope survives the other thread's scope
            assert compiler.current_options().backend == "dpia-jnp"
        # the other thread saw the process default, not our scope...
        assert seen["other"] == "xla"
        # ...and its own scope worked
        assert seen["scoped"] == "dpia-pallas"

    def test_dpia_backend_mapping(self):
        assert compiler.CompileOptions(backend="dpia-pallas").dpia_backend \
            == "pallas"
        assert compiler.CompileOptions(backend="xla").dpia_backend == "jnp"


# ---------------------------------------------------------------------------
# the staged Program pipeline
# ---------------------------------------------------------------------------

# (kernel, shape kwargs, args builder, oracle)
_SIX_OPS = [
    ("scal", dict(n=256),
     lambda r: (jnp.float32(1.7), jnp.asarray(r.randn(256), "float32")),
     lambda alpha, x: ref.scal(alpha, x)),
    ("asum", dict(n=256),
     lambda r: (jnp.asarray(r.randn(256), "float32"),),
     lambda x: ref.asum(x)),
    ("dot", dict(n=256),
     lambda r: (jnp.asarray(r.randn(256), "float32"),
                jnp.asarray(r.randn(256), "float32")),
     lambda x, y: ref.dot(x, y)),
    ("matmul", dict(m=32, k=64, n=16),
     lambda r: (jnp.asarray(r.randn(32, 64), "float32"),
                jnp.asarray(r.randn(64, 16), "float32")),
     lambda a, b: ref.matmul(a, b)),
    ("rmsnorm", dict(rows=16, d=64),
     lambda r: (jnp.asarray(r.randn(16, 64), "float32"),
                jnp.asarray(r.randn(64), "float32")),
     lambda x, w: ref.rmsnorm(x, w)),
    ("softmax", dict(rows=16, d=64),
     lambda r: (jnp.asarray(r.randn(16, 64), "float32"),),
     lambda x: ref.softmax(x)),
]


class TestProgram:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize(
        "kernel,shape,mkargs,oracle", _SIX_OPS,
        ids=[k for k, _, _, _ in _SIX_OPS])
    def test_six_ops_staged_pipeline(self, rng, backend, kernel, shape,
                                     mkargs, oracle):
        """Acceptance: every benchmark op through check->lower->compile on
        both backends, numerics matching the reference oracle."""
        prog = compiler.Program.from_kernel(kernel, **shape)
        fn = prog.check().lower().compile(backend)
        args = mkargs(rng)
        np.testing.assert_allclose(
            np.asarray(fn(*args), "float32"),
            np.asarray(oracle(*args), "float32"), rtol=1e-4, atol=1e-4)

    def test_staged_pipeline_matches_interpreter_oracle(self, rng):
        """The compiled strategy equals the *functional reading* (interp)."""
        prog = compiler.Program.from_kernel("dot", n=128)
        x = jnp.asarray(rng.randn(128), "float32")
        y = jnp.asarray(rng.randn(128), "float32")
        want = interp.interp(prog.expr, {"xs": x, "ys": y})
        for backend in ("jnp", "pallas"):
            got = prog.check().lower().compile(backend)(x, y)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4)

    def test_lower_with_rewrite_callable(self, rng):
        from repro.core.dpia import strategies
        expr, argv = dpia_blas.naive_dot(256)
        prog = compiler.Program(expr, argv, name="dot-naive")

        def strategy(e):
            fused = strategies.fuse_map_into_reduce(e)
            return strategies.blocked_reduce(
                fused, 64, partial_level=P.GRID(0),
                combine=lambda x, a: P.add(a, x))

        lowered = prog.lower(strategy)
        assert lowered is not prog  # rewrites produce a new Program
        fn = lowered.check().compile("jnp")
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        np.testing.assert_allclose(np.asarray(fn(x, y)),
                                   np.asarray(ref.dot(x, y)), rtol=1e-4)

    def test_lower_with_params_dict(self, rng):
        prog = compiler.Program.from_kernel("dot", n=256)
        tuned = prog.lower({"block": 64, "leaf": "vpu"})
        fn = tuned.check().compile("jnp")
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        np.testing.assert_allclose(np.asarray(fn(x, y)),
                                   np.asarray(ref.dot(x, y)), rtol=1e-4)

    def test_lower_autotune_strategy(self, rng, tuning_cache):
        prog = compiler.Program.from_kernel("dot", n=256)
        with compiler.options(tuning_cache=tuning_cache):
            tuned = prog.lower("autotune")
        fn = tuned.check().compile("jnp")
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        np.testing.assert_allclose(np.asarray(fn(x, y)),
                                   np.asarray(ref.dot(x, y)), rtol=1e-4)

    def test_check_rejects_racy_term(self):
        """The paper's section 3.3 example: every parfor iteration writes
        the same acceptor — Program.check() must reject it."""
        b = P.var_acc("b", Num())
        es = P.var_exp("es", Arr(8, Num()))
        out = P.Var("out#", AccT(Arr(8, Num())))
        racy = P.ParFor(8, Num(), out,
                        lambda i, o: P.Assign(b, P.IdxE(es, i)))
        prog = compiler.Program.from_imperative(racy, [es], out)
        with pytest.raises(RaceError):
            prog.check()

    def test_imperative_only_program_guards(self):
        """Imperative-only Programs reject rewrites and lowered-blind
        backends with clear errors instead of crashing on expr=None."""
        es = P.var_exp("es", Arr(8, Num()))
        out = P.Var("out#", AccT(Arr(8, Num())))
        ok = P.ParFor(8, Num(), out,
                      lambda i, o: P.Assign(o, P.IdxE(es, i)))
        prog = compiler.Program.from_imperative(ok, [es], out)
        with pytest.raises(ValueError, match="imperative-only"):
            prog.lower(lambda e: e)
        with pytest.raises(ValueError, match="imperative-only"):
            prog.compile("shardmap", mesh=object())
        # backends that accept the staged translation still work
        fn = prog.check().compile("jnp", jit=False)
        x = jnp.asarray(np.arange(8), "float32")
        np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(x))

    def test_imperative_view_and_show(self):
        prog = compiler.Program.from_kernel("dot", n=128)
        cmd = prog.imperative
        assert cmd is not None
        assert "parfor" in prog.show()

    def test_shardmap_backend_requires_mesh(self):
        prog = compiler.Program.from_kernel("dot", n=64)
        with pytest.raises(TypeError, match="mesh"):
            prog.compile("shardmap")

    def test_tune_accepts_program(self, tuning_cache):
        from repro import autotune
        prog = compiler.Program.from_kernel("dot", n=256)
        res = autotune.tune(prog, cache=tuning_cache, measure=False)
        assert res.kernel == "dot"
        assert res.params  # a concrete strategy was chosen
        res2 = autotune.tune(prog, cache=tuning_cache, measure=False)
        assert res2.source == "cache"


# ---------------------------------------------------------------------------
# ops dispatch through the table + options
# ---------------------------------------------------------------------------

class TestOpsDispatch:
    def test_scoped_backend_drives_ops(self, rng):
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        with compiler.options(backend="dpia-jnp", autotune=False):
            got = ops.dot(x, y)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot(x, y)), rtol=1e-4)

    def test_explicit_options_object(self, rng):
        x = jnp.asarray(rng.randn(16, 64), "float32")
        w = jnp.asarray(rng.randn(64), "float32")
        opts = compiler.CompileOptions(backend="dpia-jnp", autotune=False)
        got = ops.rmsnorm(x, w, options=opts)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.rmsnorm(x, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_unknown_impl_raises_value_error(self, rng):
        x = jnp.asarray(rng.randn(8), "float32")
        with pytest.raises(ValueError, match="valid backends"):
            ops.dot(x, x, impl="bogus")

    def test_softmax_dpia_path(self, rng):
        x = jnp.asarray(rng.randn(16, 64), "float32")
        got = ops.softmax(x, impl="dpia-jnp",
                          options=compiler.CompileOptions(autotune=False))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.softmax(x)),
                                   rtol=1e-4, atol=1e-5)

    def test_user_registered_backend_drives_ops(self, rng):
        """A registered Stage III backend is usable as a dpia-<name> impl
        end to end, exactly as the registry contract advertises."""
        def compile_interp(expr, arg_vars, **kw):
            names = [v.name for v in arg_vars]

            def fn(*args):
                return interp.interp(expr, dict(zip(names, args)))
            return fn

        compiler.register_backend(compiler.Backend(
            name="interp-ops-test", compile=compile_interp))
        try:
            assert "dpia-interp-ops-test" in compiler.ops_impls()
            opts = compiler.CompileOptions(
                backend="dpia-interp-ops-test", autotune=False, jit=False)
            x = jnp.asarray(rng.randn(128), "float32")
            y = jnp.asarray(rng.randn(128), "float32")
            got = ops.dot(x, y, options=opts)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(ref.dot(x, y)), rtol=1e-4)
        finally:
            compiler.unregister_backend("interp-ops-test")
            ops.clear_caches()

    def test_program_cache_keyed_by_jit(self, rng):
        """options(jit=False) must not be served a cached jitted kernel."""
        ops.clear_caches()
        x = jnp.asarray(rng.randn(128), "float32")
        y = jnp.asarray(rng.randn(128), "float32")
        base = compiler.CompileOptions(backend="dpia-jnp", autotune=False)
        ops.dot(x, y, options=base)                       # jit=True entry
        n_jitted = len(compiler.executor_cache())
        ops.dot(x, y, options=base.replace(jit=False))    # must not collide
        assert len(compiler.executor_cache()) == 2 * n_jitted
        ops.clear_caches()

    def test_tuned_lookup_failure_warns_once(self, rng, monkeypatch):
        import repro.autotune as autotune
        ops.clear_caches()

        def boom(*a, **kw):
            raise RuntimeError("synthetic tuner failure")
        monkeypatch.setattr(autotune, "get_tuned", boom)
        x = jnp.asarray(rng.randn(128), "float32")
        y = jnp.asarray(rng.randn(128), "float32")
        opts = compiler.CompileOptions(backend="dpia-jnp", autotune=True)
        with pytest.warns(RuntimeWarning, match="synthetic tuner failure"):
            got = ops.dot(x, y, options=opts)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.dot(x, y)), rtol=1e-4)
        # one-shot: the second call must not warn again
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            ops.dot(x, y, options=opts)
        ops.clear_caches()


# ---------------------------------------------------------------------------
# deprecation shims: warn, validate, and match the new path bit-for-bit
# ---------------------------------------------------------------------------

class TestDeprecationShims:
    def test_set_default_impl_warns_and_works(self, rng):
        x = jnp.asarray(rng.randn(128), "float32")
        y = jnp.asarray(rng.randn(128), "float32")
        with pytest.warns(DeprecationWarning, match="set_default_impl"):
            ops.set_default_impl("dpia-jnp")
        try:
            via_shim = ops.dot(x, y)
        finally:
            with pytest.warns(DeprecationWarning):
                ops.set_default_impl("xla")
        with compiler.options(backend="dpia-jnp"):
            via_options = ops.dot(x, y)
        np.testing.assert_array_equal(np.asarray(via_shim),
                                      np.asarray(via_options))

    def test_set_default_impl_rejects_bad_impl(self):
        # ValueError (not assert): survives python -O and names the registry
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="valid backends"):
                ops.set_default_impl("garbage")
        # the bad call must not have clobbered the default
        assert compiler.current_options().backend == "xla"

    def test_set_autotune_warns_and_scopes(self, tuning_cache):
        with pytest.warns(DeprecationWarning, match="set_autotune"):
            ops.set_autotune(False, cache=tuning_cache)
        try:
            assert ops.autotune_enabled() is False
            assert compiler.current_options().tuning_cache is tuning_cache
        finally:
            with pytest.warns(DeprecationWarning):
                ops.set_autotune(True, cache=None)
        assert ops.autotune_enabled() is True

    def test_compile_op_warns_and_matches_program(self, rng):
        expr, argv = dpia_blas.strategy_dot(256, 64)
        with pytest.warns(DeprecationWarning, match="compile_op"):
            shim_fn = dpia_blas.compile_op(expr, argv, backend="jnp")
        prog_fn = (compiler.Program(expr, argv).check().lower()
                   .compile("jnp", jit=False))
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        np.testing.assert_array_equal(np.asarray(shim_fn(x, y)),
                                      np.asarray(prog_fn(x, y)))

    def test_compile_op_unknown_backend(self):
        expr, argv = dpia_blas.strategy_dot(64, 64)
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="registered backends"):
                dpia_blas.compile_op(expr, argv, backend="opencl")
