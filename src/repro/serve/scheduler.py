"""Continuous-batching scheduler: host-side bookkeeping for the serving
engine's fixed device slots.

The engine owns a device-resident batch of ``n_slots`` decode lanes; this
module owns the *policy*: which pending request enters which free slot, which
sequence-length bucket its prompt is padded to, and when a slot retires.  All
decisions happen at chunk boundaries — inside a chunk the device runs a fused
``lax.scan`` with no host involvement, so the scheduler never sees (or
blocks) individual tokens.

Shape discipline: prompts are RIGHT-padded to a bucket from
:func:`seq_buckets` and the decode batch is always exactly ``n_slots`` wide,
so the jitted prefill/decode functions see a small closed set of shapes —
after one pass over the buckets there are zero recompiles, whatever traffic
arrives.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

__all__ = ["seq_buckets", "pick_bucket", "Scheduler"]


def seq_buckets(max_seq: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Power-of-two prompt buckets up to ``max_seq`` (always included)."""
    if max_seq < 1:
        raise ValueError(f"max_seq must be >= 1, got {max_seq}")
    out = []
    b = min_bucket
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(sorted(set(out)))


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """The smallest bucket that fits ``n`` tokens."""
    for b in sorted(buckets):
        if n <= b:
            return b
    raise ValueError(f"prompt of {n} tokens exceeds the largest bucket "
                     f"{max(buckets)}")


@dataclasses.dataclass
class _Slot:
    """Host mirror of one device decode lane."""
    req_id: int = -1          # -1: free
    remaining: int = 0        # tokens still owed to the request

    @property
    def free(self) -> bool:
        return self.req_id < 0


class Scheduler:
    """Admission/retirement bookkeeping over ``n_slots`` decode lanes.

    The engine drives it:

      * ``submit(req_id, prompt_len, max_new)`` queues a request;
      * ``admissions()`` (at a chunk boundary) pops pending requests into
        free slots, FIFO — the engine then prefills each admitted request;
      * ``record_first(slot, token)`` accounts the token sampled from the
        prefill logits;
      * ``record_chunk(tokens)`` accounts one decoded chunk for every busy
        slot (``tokens``: (n_slots, chunk) host array) and retires slots
        whose requests are complete.

    Outputs accumulate in ``outputs[req_id]``; tokens a slot decodes past
    its request's ``max_new_tokens`` (chunks are fixed-length; requests are
    not) are discarded here and never reach the caller.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self.pending: Deque[int] = deque()
        self.meta: Dict[int, dict] = {}
        self.outputs: Dict[int, List[int]] = {}

    # -- intake --------------------------------------------------------------

    def submit(self, req_id: int, prompt_len: int, max_new: int) -> None:
        if req_id in self.meta:
            raise ValueError(f"request id {req_id} already submitted")
        self.meta[req_id] = {"prompt_len": prompt_len, "max_new": max_new}
        self.outputs[req_id] = []
        self.pending.append(req_id)

    # -- chunk-boundary decisions -------------------------------------------

    def admissions(self) -> List[Tuple[int, int]]:
        """(slot index, req_id) pairs to admit now — free slots, FIFO."""
        out = []
        for i, slot in enumerate(self.slots):
            if not self.pending:
                break
            if slot.free:
                rid = self.pending.popleft()
                slot.req_id = rid
                slot.remaining = self.meta[rid]["max_new"]
                out.append((i, rid))
        return out

    def record_first(self, slot_idx: int, token: int) -> bool:
        """Account the prefill-sampled token; True if the request is already
        complete (max_new_tokens == 1) and the slot retired."""
        slot = self.slots[slot_idx]
        if slot.remaining > 0:
            self.outputs[slot.req_id].append(int(token))
            slot.remaining -= 1
        if slot.remaining == 0:
            self._retire(slot)
            return True
        return False

    def record_chunk(self, tokens) -> List[int]:
        """Account one decoded chunk; returns req_ids retired this boundary.

        ``tokens`` is a (n_slots, chunk) host int array — the single
        device->host transfer of the chunk."""
        finished = []
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            take = min(slot.remaining, tokens.shape[1])
            self.outputs[slot.req_id].extend(int(t) for t in tokens[i, :take])
            slot.remaining -= take
            if slot.remaining == 0:
                finished.append(slot.req_id)
                self._retire(slot)
        return finished

    @staticmethod
    def _retire(slot: _Slot) -> None:
        slot.req_id = -1
        slot.remaining = 0

    def pop_output(self, req_id: int) -> List[int]:
        """Collect a request's tokens and drop its records — memory stays
        bounded by in-flight + uncollected work, not total traffic."""
        out = self.outputs.pop(req_id)
        self.meta.pop(req_id, None)
        return out

    # -- state ---------------------------------------------------------------

    def busy_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    @property
    def idle(self) -> bool:
        return not self.pending and all(s.free for s in self.slots)
