"""Model configuration + shared components (embeddings, norms, RoPE, init)."""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None      # defaults to d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0                 # hybrid: shared attn every k blocks
    # audio (musicgen): codebooks summed at the embedding (frontend stub)
    n_codebooks: int = 0
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # runtime / distribution knobs
    remat: bool = True
    fsdp: bool = False                  # ZeRO-style param+opt sharding on data
    opt_8bit: bool = False              # 8-bit Adam moments (100B+ configs)
    use_flash: bool = False             # pallas flash attention (TPU target)
    max_seq: int = 4096

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        ffn = 3 * d * f  # SwiGLU
        if self.n_experts:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts  # + router
        per_layer = attn + ffn + 2 * d
        if self.family == "ssm":        # rwkv6: time-mix + channel-mix
            tm = d * d * 4 + d * 64 * 2 + d * 6  # r,k,v,o + lora decay + mixes
            cm = d * f + f * d + d * d
            per_layer = tm + cm + 2 * d
        if self.family == "hybrid":
            # mamba2-only layers; the SHARED block (attn + MLP) counts once
            din = 2 * d
            nheads_m = din // 64
            mamba = (d * (2 * din + 2 * self.ssm_state + nheads_m)
                     + din * d + 2 * din)
            per_layer = mamba + d
            shared = attn + 3 * d * f + 2 * d
            return self.n_layers * per_layer + shared + v * d + v * d
        emb = v * d
        head = v * d
        return self.n_layers * per_layer + emb + head

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.n_experts * 3 * d * f
        active_moe = self.top_k * 3 * d * f
        return self.param_count() - self.n_layers * (dense_moe - active_moe)


def init_dense(key, d_in: int, d_out: int, dtype: str, scale: float = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_tokens(emb, tokens):
    return jnp.take(emb, tokens, axis=0)


def rmsnorm(x, w, eps: float = 1e-6):
    return ops.rmsnorm(x, w, eps=eps)


def rope_freqs(hd: int, theta: float, positions):
    """positions: (... ,seq) int32 -> (..., seq, hd//2) cos/sin."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, hd); cos/sin: (..., seq, hd//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """Next-token CE in float32 with z-loss regulariser; labels -100 masked."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    labels_safe = jnp.where(mask, labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_safe[..., None],
                             axis=-1)[..., 0] - lse
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
    zl = z_loss * ((lse * mask) ** 2).sum() / jnp.maximum(mask.sum(), 1)
    return loss + zl
