"""Strategy-space enumeration over the DPIA rewrites.

Every candidate is the *same mathematical function* as the naive spec —
derived by the semantics-preserving rewrites of
``repro.core.dpia.strategies`` (split_join, blocked_reduce,
fuse_map_into_reduce, vectorize) plus level assignment — so the tuner can
only ever trade performance, never correctness.  Candidates are described
by a small JSON-able ``params`` dict so tuning decisions survive in the
persistent cache (see cache.py) and can be rebuilt later with
``candidate_from_params``.

Since the ``repro.strategy`` subsystem landed, each params dict denotes a
strategy *program* (``repro.strategy.spaces.program_for``) applied to the
kernel's naive spec, and every candidate can report the derivation it took
(:meth:`Candidate.trace_doc`) — the legacy hand-built builders survive as
``legacy_candidate``, the oracle the strategy-program path is equality-
tested against.

Parameter vocabulary per kernel family:

  dot / reduce   {"block": int|None, "leaf": "vpu"|"seq"}
                 block=None is the unrewritten spec; leaf picks whether a
                 block is reduced by a whole-block VPU FullReduce or by a
                 sequential (rewrite-derived) inner reduce.
  map / scal     {"block": int|None, "vector": int|None}
                 split_join grid blocking, optionally vectorize(w) inside.
  matmul         {"bm": int, "bk": int}   MXU row/contraction tiles.
  rmsnorm        {"row_block": int}
  softmax        {"row_block": int}
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.dpia import phrases as P
from repro.core.dpia import strategies
from repro.core.dpia.types import Arr, Num, show_data

Expr = P.Phrase
Builder = Callable[[], Tuple[Expr, List[P.Var]]]

# candidate tile/block menus (filtered by divisibility per shape)
SPLIT_BLOCKS: Tuple[int, ...] = (128, 256, 512, 1024, 2048, 4096, 8192)
ROW_BLOCKS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
MXU_TILES: Tuple[int, ...] = (32, 64, 128, 256)
LANE_WIDTHS: Tuple[int, ...] = (128,)


@dataclass(frozen=True)
class Candidate:
    """One point of the strategy space: params + a builder for its expr.

    ``strategy``/``spec`` (a ``repro.strategy`` program + the naive-spec
    builder it applies to) are present on strategy-derived candidates and
    power :meth:`trace_doc`; builder-only candidates (legacy oracles,
    hand-edited params) simply have no derivation to report."""
    kernel: str
    params: Tuple[Tuple[str, object], ...]
    build: Builder = field(compare=False, repr=False)
    strategy: object = field(default=None, compare=False, repr=False)
    spec: object = field(default=None, compare=False, repr=False)

    @property
    def params_dict(self) -> Dict[str, object]:
        return dict(self.params)

    def params_key(self) -> str:
        return params_key(self.params_dict)

    def trace_doc(self) -> Optional[dict]:
        """The serialised StrategyTrace of this candidate's derivation, or
        None when the candidate was not built by a strategy program."""
        if self.strategy is None or self.spec is None:
            return None
        expr, _ = self.spec()
        res = self.strategy.apply(expr)
        return res.trace.to_doc() if res.ok else None

    def program(self):
        """This candidate as a ``repro.compiler.Program`` — the staged entry
        the tuner's measure/compile paths consume."""
        from repro.compiler import Program
        expr, arg_vars = self.build()
        prog = Program(expr, arg_vars,
                       name=f"{self.kernel}[{self.params_key()}]")
        try:
            prog.strategy_trace = self.trace_doc()
        except Exception:
            prog.strategy_trace = None
        return prog


def params_key(params: Dict[str, object]) -> str:
    """Canonical string form of a params dict (cache / timing-table key)."""
    return ",".join(f"{k}={params[k]}" for k in sorted(params))


def _cand(kernel: str, params: Dict[str, object], build: Builder) -> Candidate:
    return Candidate(kernel, tuple(sorted(params.items())), build)


def _strategy_cand(kernel: str, params: Dict[str, object],
                   shape: Dict[str, object]) -> Candidate:
    """A candidate whose expr is derived by the strategy program its params
    denote, applied to the kernel's naive spec."""
    from repro import strategy as strategy_mod
    spec = strategy_mod.spec_builder(kernel, **shape)
    program = strategy_mod.program_for(kernel, params)

    def build():
        expr, argv = spec()
        res = program.apply(expr)
        if not res.ok:
            raise ValueError(
                f"strategy program for {kernel} {params_key(params)} "
                f"failed: {res.reason}")
        return res.phrase, argv

    return Candidate(kernel, tuple(sorted(params.items())), build,
                     strategy=program, spec=spec)


def _divides(blocks: Iterable[int], n: int) -> List[int]:
    return [b for b in blocks if 0 < b <= n and n % b == 0]


# ---------------------------------------------------------------------------
# per-kernel spaces (params grids; every candidate built by its strategy
# program via _strategy_cand)
# ---------------------------------------------------------------------------

def _reduce_builder(kernel: str, n: int, block: Optional[int],
                    leaf: str) -> Builder:
    """Legacy hand-built builder for the reduce-shaped kernels (dot, asum) —
    kept as the oracle ``legacy_candidate`` exposes; enumeration goes
    through the strategy programs."""
    def build():
        from repro.kernels import dpia_blas
        naive = getattr(dpia_blas, f"naive_{kernel}")
        strat = getattr(dpia_blas, f"strategy_{kernel}")
        if block is None:
            return naive(n)
        if leaf == "vpu":
            return strat(n, block)
        # leaf == "seq": derive by the rewrites themselves (quickstart's path)
        expr, argv = naive(n)
        fused = strategies.fuse_map_into_reduce(expr)
        blocked = strategies.blocked_reduce(
            fused, block, partial_level=P.GRID(0),
            combine=lambda x, a: P.add(a, x))
        return blocked, argv
    return build


def _reduce_space(kernel: str, n: int,
                  blocks: Sequence[int]) -> List[Candidate]:
    shape = {"n": n}
    out = [_strategy_cand(kernel, {"block": None, "leaf": "seq"}, shape)]
    for b in _divides(tuple(blocks) + (n,), n):
        for leaf in ("vpu", "seq"):
            out.append(_strategy_cand(kernel, {"block": b, "leaf": leaf},
                                      shape))
    return _dedup(out)


def dot_space(n: int, blocks: Sequence[int] = SPLIT_BLOCKS) -> List[Candidate]:
    return _reduce_space("dot", n, blocks)


def asum_space(n: int, blocks: Sequence[int] = SPLIT_BLOCKS) -> List[Candidate]:
    return _reduce_space("asum", n, blocks)


def _scal_builder(n: int, block: Optional[int],
                  vector: Optional[int]) -> Builder:
    """Legacy hand-built scal builder (oracle for the strategy programs)."""
    from repro.kernels import dpia_blas

    def build():
        if block is None:
            return dpia_blas.naive_scal(n)
        if vector is None:
            # split_join at the grid level with the block handled as one
            # lifted VPU op (the lanes reading of the inner map)
            return dpia_blas.strategy_scal(n, block)
        # grid-blocked, with each block's map vectorize(w)-rewritten
        expr, argv = dpia_blas.naive_scal(n)
        alpha, xs = argv

        def per_block(blk):
            return strategies.vectorize(
                P.Map(lambda x: P.mul(alpha, x), blk, level=P.SEQ), vector)
        return P.Join(P.Map(per_block, P.Split(block, xs),
                            level=P.GRID(0))), argv
    return build


def scal_space(n: int, blocks: Sequence[int] = SPLIT_BLOCKS,
               lanes: Sequence[int] = LANE_WIDTHS) -> List[Candidate]:
    shape = {"n": n}
    out = [_strategy_cand("scal", {"block": None, "vector": None}, shape)]
    for b in _divides(tuple(blocks) + (n,), n):
        out.append(_strategy_cand("scal", {"block": b, "vector": None},
                                  shape))
        for w in lanes:
            if b % w == 0:
                out.append(_strategy_cand("scal",
                                          {"block": b, "vector": w}, shape))
    return _dedup(out)


def matmul_space(m: int, k: int, n: int,
                 tiles: Sequence[int] = MXU_TILES) -> List[Candidate]:
    shape = {"m": m, "k": k, "n": n}
    out = []
    bms = _divides(tuple(tiles) + (min(128, m),), m)
    bks = _divides(tuple(tiles) + (min(128, k),), k)
    for bm in bms:
        for bk in bks:
            out.append(_strategy_cand("matmul", {"bm": bm, "bk": bk}, shape))
    return _dedup(out)


def rmsnorm_space(rows: int, d: int, eps: float = 1e-6,
                  row_blocks: Sequence[int] = ROW_BLOCKS) -> List[Candidate]:
    shape = {"rows": rows, "d": d, "eps": eps}
    return _dedup([
        _strategy_cand("rmsnorm", {"row_block": rb}, shape)
        for rb in _divides(tuple(row_blocks) + (rows,), rows)])


def softmax_space(rows: int, d: int,
                  row_blocks: Sequence[int] = ROW_BLOCKS) -> List[Candidate]:
    shape = {"rows": rows, "d": d}
    return _dedup([
        _strategy_cand("softmax", {"row_block": rb}, shape)
        for rb in _divides(tuple(row_blocks) + (rows,), rows)])


def legacy_candidate(kernel: str, params: Dict[str, object],
                     **shape) -> Candidate:
    """The pre-strategy-language hand-built candidate for a params dict —
    the oracle ``tests/test_strategy.py`` pins the strategy programs
    against (phrase-identical by structural fingerprint)."""
    from repro.kernels import dpia_blas
    if kernel in ("dot", "asum"):
        return _cand(kernel, params, _reduce_builder(
            kernel, shape["n"], params.get("block"),
            params.get("leaf", "vpu")))
    if kernel == "scal":
        return _cand(kernel, params, _scal_builder(
            shape["n"], params.get("block"), params.get("vector")))
    if kernel == "matmul":
        m, k, n = shape["m"], shape["k"], shape["n"]
        bm, bk = int(params["bm"]), int(params["bk"])
        return _cand(kernel, params,
                     lambda: dpia_blas.strategy_matmul(m, k, n, bm=bm, bk=bk))
    if kernel == "rmsnorm":
        rows, d = shape["rows"], shape["d"]
        eps = shape.get("eps", 1e-6)
        rb = int(params["row_block"])
        return _cand(kernel, params,
                     lambda: dpia_blas.strategy_rmsnorm(rows, d, eps, rb))
    if kernel == "softmax":
        rows, d = shape["rows"], shape["d"]
        rb = int(params["row_block"])
        return _cand(kernel, params,
                     lambda: dpia_blas.strategy_softmax(rows, d, rb))
    raise ValueError(f"legacy_candidate: unknown kernel {kernel!r}")


def _dedup(cands: List[Candidate]) -> List[Candidate]:
    seen, out = set(), []
    for c in cands:
        if c.params not in seen:
            seen.add(c.params)
            out.append(c)
    return out


_SPACES = {
    "dot": lambda n: dot_space(n),
    "asum": lambda n: asum_space(n),
    "scal": lambda n: scal_space(n),
    "matmul": lambda m, k, n: matmul_space(m, k, n),
    "rmsnorm": lambda rows, d, eps=1e-6: rmsnorm_space(rows, d, eps),
    "softmax": lambda rows, d: softmax_space(rows, d),
}


def enumerate_space(kernel: str, **shape) -> List[Candidate]:
    """All strategy candidates for a named kernel at a concrete shape."""
    try:
        mk = _SPACES[kernel]
    except KeyError:
        raise ValueError(
            f"enumerate_space: unknown kernel {kernel!r}; "
            f"known: {sorted(_SPACES)}") from None
    return mk(**shape)


def default_params(kernel: str, **shape) -> Dict[str, object]:
    """The un-tuned strategy each kernel ships with (repro.kernels defaults)."""
    if kernel in ("dot", "asum"):
        n = shape["n"]
        b = 2048 if n % 2048 == 0 else max(_divides(SPLIT_BLOCKS + (n,), n))
        return {"block": b, "leaf": "vpu"}
    if kernel == "scal":
        n = shape["n"]
        b = 2048 if n % 2048 == 0 else max(_divides(SPLIT_BLOCKS + (n,), n))
        return {"block": b, "vector": None}
    if kernel == "matmul":
        m, k = shape["m"], shape["k"]
        return {"bm": min(128, m), "bk": min(128, k)}
    if kernel == "rmsnorm":
        rows = shape["rows"]
        return {"row_block": 8 if rows % 8 == 0 else 1}
    if kernel == "softmax":
        rows = shape["rows"]
        return {"row_block": 8 if rows % 8 == 0 else 1}
    raise ValueError(f"default_params: unknown kernel {kernel!r}")


def candidate_from_params(kernel: str, params: Dict[str, object],
                          **shape) -> Candidate:
    """Rebuild the Candidate a cached/tuned params dict describes."""
    for c in enumerate_space(kernel, **shape):
        if c.params_dict == params:
            return c
    # params outside the enumerated menu (e.g. hand-edited cache): build
    # directly where the vocabulary allows it.
    if kernel in ("dot", "asum"):
        return _cand(kernel, params, _reduce_builder(
            kernel, shape["n"], params.get("block"),
            params.get("leaf", "vpu")))
    if kernel == "scal":
        return _cand(kernel, params, _scal_builder(
            shape["n"], params.get("block"), params.get("vector")))
    if kernel in ("matmul", "rmsnorm", "softmax"):
        # the strategy programs are shape-independent: side conditions are
        # checked at apply time, so off-menu (hand-edited) params still build
        return _strategy_cand(kernel, dict(params), dict(shape))
    raise ValueError(
        f"candidate_from_params: {kernel} has no candidate {params!r}")


def strategy_candidates(kernel: str, strategies, *,
                        expr: Optional[Expr] = None,
                        arg_vars: Optional[List[P.Var]] = None,
                        **shape) -> List[Candidate]:
    """Candidates from explicit ``repro.strategy`` programs (tune's
    ``strategies=`` path).

    Each program is applied to the kernel's naive spec (or to ``expr`` when
    given); programs that fail on the term are dropped.  The identity is
    prepended so the spec itself is always in the race.  Params are
    ``{"strategy": name}`` — such tuned records replay via their recorded
    ``strategy_trace`` rather than through ``candidate_from_params``."""
    from repro import strategy as strategy_mod
    if expr is not None:
        if arg_vars is None:
            raise ValueError("strategy_candidates: arg_vars required with "
                             "an explicit expr")
        spec = lambda: (expr, arg_vars)  # noqa: E731
    else:
        spec = strategy_mod.spec_builder(kernel, **shape)
    progs = [("id", strategy_mod.id_())]
    for i, s in enumerate(strategies):
        if not isinstance(s, strategy_mod.Strategy):
            raise TypeError(f"strategy_candidates: candidate {i} is not a "
                            f"Strategy: {type(s).__name__}")
        progs.append((s.name, s))
    out, seen = [], set()
    for name, prog in progs:
        e0, argv = spec()
        res = prog.apply(e0)
        if not res.ok:
            continue
        from repro.strategy import traverse as traverse_mod
        fp = traverse_mod.fingerprint(res.phrase)
        if fp in seen:
            continue
        seen.add(fp)
        out.append(Candidate(
            kernel, (("strategy", name),),
            (lambda prog=prog: _apply_or_raise(prog, spec)),
            strategy=prog, spec=spec))
    return out


def _apply_or_raise(prog, spec):
    e0, argv = spec()
    res = prog.apply(e0)
    if not res.ok:
        raise ValueError(f"strategy {prog.name} failed: {res.reason}")
    return res.phrase, argv


# ---------------------------------------------------------------------------
# generic, expression-driven enumeration (tune(expr, ...) path)
# ---------------------------------------------------------------------------

def rewrite_candidates(expr: Expr, arg_vars: List[P.Var],
                       blocks: Sequence[int] = SPLIT_BLOCKS
                       ) -> List[Candidate]:
    """Candidates for an arbitrary functional expression, derived by applying
    the rewrite rules to ``expr`` itself.  Ill-typed rewrites (a side
    condition not met) are dropped via the DPIA type checker."""
    def const(e):
        return lambda: (e, arg_vars)

    out = [_cand("expr", {"rewrite": "id"}, const(expr))]

    def admit(params: Dict[str, object], e: Expr) -> None:
        try:
            P.type_of(e)
        except P.DpiaTypeError:
            return
        out.append(_cand("expr", params, const(e)))

    if isinstance(expr, P.Reduce):
        d = P.exp_data(expr.e)
        if isinstance(d, Arr):
            fused = None
            if isinstance(expr.e, P.Map):
                try:
                    fused = strategies.fuse_map_into_reduce(expr)
                except AssertionError:            # pragma: no cover
                    fused = None
            base = fused if fused is not None else expr
            combine = (lambda x, a: expr.f(x, a)) if fused is not None else None
            for b in _divides(tuple(blocks) + (d.n,), d.n):
                try:
                    blocked = strategies.blocked_reduce(
                        base, b, partial_level=P.GRID(0), combine=combine)
                except AssertionError:
                    continue
                admit({"rewrite": "blocked_reduce", "block": b,
                       "fused": fused is not None}, blocked)
    elif isinstance(expr, P.Map):
        d = P.exp_data(expr.e)
        if isinstance(d, Arr):
            for b in _divides(tuple(blocks) + (d.n,), d.n):
                blocked = strategies.split_join(expr, b)
                assert isinstance(blocked, P.Join)
                inner = blocked.e
                assert isinstance(inner, P.Map)
                grid = P.Join(P.Map(inner.f, inner.e, level=P.GRID(0)))
                admit({"rewrite": "split_join", "block": b}, grid)
            if isinstance(d.elem, Num):
                for w in LANE_WIDTHS:
                    if d.n % w == 0:
                        try:
                            vec = strategies.vectorize(expr, w)
                        except AssertionError:
                            continue
                        admit({"rewrite": "vectorize", "vector": w}, vec)
    return _dedup(out)


def expr_signature(expr: Expr) -> str:
    """Stable structural signature of an expression (persistent-cache key for
    the tune(expr) path).  Binders are instantiated with depth-indexed names
    so the signature is identical across processes."""
    parts: List[str] = []

    def go(p: Expr, depth: int) -> None:
        name = type(p).__name__
        if isinstance(p, P.Var):
            parts.append(f"var:{p.name}:{p.t}")
            return
        if isinstance(p, P.Lit):
            parts.append(f"lit:{p.value}:{show_data(p.d)}")
            return
        if isinstance(p, P.Map):
            parts.append(f"map:{p.level}:{p.space}")
            d = P.exp_data(p.e)
            elem = d.elem if isinstance(d, Arr) else d
            go(p.f(P.Var(f"_b{depth}", P.ExpT(elem))), depth + 1)
            go(p.e, depth)
            return
        if isinstance(p, P.Reduce):
            parts.append(f"reduce:{p.level}")
            d = P.exp_data(p.e)
            elem = d.elem if isinstance(d, Arr) else d
            x = P.Var(f"_b{depth}", P.ExpT(elem))
            a = P.Var(f"_a{depth}", P.ExpT(P.exp_data(p.init)))
            go(p.f(x, a), depth + 2)
            go(p.init, depth)
            go(p.e, depth)
            return
        if isinstance(p, (P.UnOp, P.BinOp, P.FullReduce)):
            parts.append(f"{name}:{p.op}")
        elif isinstance(p, (P.Split, P.AsVector)):
            parts.append(f"{name}:{getattr(p, 'n', None) or getattr(p, 'w', '')}")
        else:
            parts.append(name)
        for fname in ("e", "a", "b", "i"):
            sub = getattr(p, fname, None)
            if isinstance(sub, P.Phrase):
                go(sub, depth)

    go(expr, 0)
    sig = ";".join(parts)
    return hashlib.sha1(sig.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# compat: the seed's dot-only parameter grid (repro.core.dpia.strategies)
# ---------------------------------------------------------------------------

def dot_param_grid(n: int, blocks: Iterable[int] = (256, 1024, 2048),
                   lanes: Iterable[int] = (128,)) -> List[dict]:
    """The seed's ``enumerate_dot_strategies`` output format, preserved."""
    out = []
    for b in blocks:
        if n % b:
            continue
        out.append({"block": b, "vector": None})
        for w in lanes:
            if b % w == 0:
                out.append({"block": b, "vector": w})
    return out
