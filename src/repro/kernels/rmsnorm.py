"""Hand-written Pallas fused RMSNorm (one pass over HBM: read, normalise,
scale, write — memory-bound and fusion-profitable, which is why it earns a
kernel).  Grid over row blocks; weight replicated per block."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype) * w_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    # interpret=None auto-selects: interpret mode only on CPU hosts
    if interpret is None:
        from repro.compiler.options import default_interpret
        interpret = default_interpret()
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    br = min(block_rows, rows)
    # pad rows to a multiple of the block
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x2.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
