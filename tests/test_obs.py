"""Observability tests: tracer semantics + overhead, metrics registry,
Chrome-JSON export, strategy provenance, the unified ``Engine.stats()``
dict, the serving recompile detector, the always-on flight recorder,
request-scoped traces, and the roofline drift auditor."""
import json
import logging
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import ContinuousEngine, Request


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts disabled with empty buffers and ends the same."""
    obs.disable()
    obs.clear_trace()
    yield
    obs.disable()
    obs.clear_trace()


def tiny_cfg(**kw):
    base = dict(name="obs-t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, dtype="float32",
                remat=False, max_seq=64)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_model():
    cfg = tiny_cfg()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_disabled_records_nothing(self):
        with obs.span("a", x=1):
            obs.event("b")
        assert obs.trace_events() == []

    def test_span_event_shape(self):
        obs.enable()
        with obs.span("outer", label="L"):
            with obs.span("inner"):
                pass
            obs.event("point", n=3)
        evs = obs.trace_events()
        by_name = {e["name"]: e for e in evs}
        assert set(by_name) == {"outer", "inner", "point"}
        inner, outer = by_name["inner"], by_name["outer"]
        assert inner["ph"] == outer["ph"] == "X"
        assert inner["args"]["parent"] == "outer"
        assert outer["args"]["label"] == "L"
        # the child interval nests inside the parent interval
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
        point = by_name["point"]
        assert point["ph"] == "i" and point["s"] == "t"
        assert point["args"]["n"] == 3

    def test_span_records_error_and_unwinds(self):
        obs.enable()
        with pytest.raises(RuntimeError):
            with obs.span("boom"):
                raise RuntimeError("x")
        (ev,) = obs.trace_events()
        assert ev["args"]["error"] == "RuntimeError"
        assert obs.tracer.depth() == 0

    def test_traced_decorator(self):
        calls = []

        @obs.traced("deco.fn")
        def fn(x):
            calls.append(x)
            return x + 1

        assert fn(1) == 2                       # disabled: calls through
        assert obs.trace_events() == []
        obs.enable()
        assert fn(2) == 3
        assert [e["name"] for e in obs.trace_events()] == ["deco.fn"]

    def test_thread_safety(self):
        """8 threads x 50 nested span pairs: every event lands, each
        thread's parent links are its own (no cross-thread stack bleed)."""
        obs.enable()
        n_threads, n_spans = 8, 50

        def work(tid):
            for i in range(n_spans):
                with obs.span(f"outer-{tid}"):
                    with obs.span(f"inner-{tid}"):
                        pass

        threads = [threading.Thread(target=work, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        evs = obs.trace_events()
        assert len(evs) == n_threads * n_spans * 2
        for e in evs:
            if e["name"].startswith("inner-"):
                tid = e["name"].split("-")[1]
                assert e["args"]["parent"] == f"outer-{tid}"

    def test_chrome_json_round_trip(self, tmp_path):
        obs.enable()
        with obs.span("a", arr=jnp.zeros(2)):    # exotic arg -> repr'd
            obs.event("b")
        path = tmp_path / "trace.json"
        obs.export_trace(str(path))
        doc = json.loads(path.read_text())
        assert isinstance(doc["traceEvents"], list)
        names = {e["name"] for e in doc["traceEvents"]}
        assert names == {"a", "b"}
        for ev in doc["traceEvents"]:
            assert isinstance(ev["ts"], (int, float))
            assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
            assert ev["ph"] in ("X", "i")
            if ev["ph"] == "X":
                assert ev["dur"] >= 0

    def test_disabled_overhead_under_5_percent(self, dense_model):
        """The acceptance bound: tracing disabled, the per-span cost must
        be < 5% of one jitted-kernel call — measured directly (100k no-op
        spans) against the median of repeated kernel calls, so the test is
        robust to CI timing noise."""
        cfg, model, params = dense_model
        tok = jnp.zeros((4, 1), jnp.int32)
        cache = model.init_cache(4, 32)
        step = jax.jit(lambda p, t, c: model.decode_step(p, t, c,
                                                         jnp.int32(1)))
        jax.block_until_ready(step(params, tok, cache)[0])   # compile

        ts = []
        for _ in range(9):
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, tok, cache)[0])
            ts.append(time.perf_counter() - t0)
        kernel_t = sorted(ts)[len(ts) // 2]

        n = 100_000
        assert not obs.enabled()
        t0 = time.perf_counter()
        for _ in range(n):
            with obs.span("x"):
                pass
        per_span = (time.perf_counter() - t0) / n
        assert per_span < 0.05 * kernel_t, (
            f"disabled span costs {per_span * 1e9:.0f} ns, kernel call "
            f"{kernel_t * 1e6:.1f} us — overhead {per_span / kernel_t:.2%}")


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram(self):
        reg = obs.MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(2)
        assert reg.counter("c").value == 3
        reg.gauge("g").set(7)
        assert reg.gauge("g").value == 7
        h = reg.histogram("h")
        for v in (0.5, 1.5, 3.0, 0.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["c"] == {"type": "counter", "value": 3}
        assert snap["h"]["count"] == 4
        assert snap["h"]["min"] == 0.0 and snap["h"]["max"] == 3.0
        assert "<=0" in snap["h"]["buckets"]    # the 0.0 observation
        json.dumps(snap)                         # JSON-able as-is
        reg.reset()
        assert reg.counter("c").value == 0
        assert reg.histogram("h").count == 0

    def test_type_mismatch_raises(self):
        reg = obs.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_export(self, tmp_path):
        reg = obs.MetricsRegistry()
        reg.counter("n").inc(5)
        path = tmp_path / "m.json"
        reg.export(str(path))
        assert json.loads(path.read_text())["n"]["value"] == 5

    def test_concurrent_increments(self):
        reg = obs.MetricsRegistry()
        c = reg.counter("c")

        def work():
            for _ in range(1000):
                c.inc()

        threads = [threading.Thread(target=work) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 8000


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

class TestProvenance:
    def test_tuned_kernels_have_decisions(self, tmp_path):
        """Every kernel the tuner decides on shows up in explain() with a
        roofline-backed origin."""
        from repro import autotune
        from repro.kernels import ops
        obs.clear_decisions()
        cache = autotune.TuningCache(str(tmp_path / "t.json"))
        from repro import compiler
        with compiler.options(tuning_cache=cache):
            x = jnp.ones((8, 64), jnp.float32)
            w = jnp.ones((64, 32), jnp.float32)
            ops.matmul(x, w, impl="dpia-jnp")   # the tuned dispatch path
        ds = obs.decisions()
        assert ds, "tuning produced no provenance decisions"
        mm = [d for d in ds if d.kernel == "matmul"]
        assert mm, f"no matmul decision in {[d.kernel for d in ds]}"
        d = mm[-1]
        assert d.origin in ("analytic", "measured", "cache(analytic)",
                            "cache(measured)")
        assert d.terms, "decision carries no roofline terms"
        report = obs.explain("matmul")
        assert "matmul" in report and d.origin in report
        # second lookup over the same cache (measure=False, the serving
        # path): origin becomes cache(...) and keeps the roofline terms
        obs.clear_decisions()
        autotune.tune("matmul", cache=cache, measure=False, m=8, k=64, n=32)
        (d2,) = [d for d in obs.decisions() if d.kernel == "matmul"]
        assert d2.origin.startswith("cache("), d2.origin
        assert d2.terms, "cache-hit decision lost its roofline terms"

    def test_explain_empty(self):
        obs.clear_decisions()
        assert "no decisions" in obs.explain("nope-no-such-kernel")


# ---------------------------------------------------------------------------
# Engine.stats() + recompile detector
# ---------------------------------------------------------------------------

class TestEngineStats:
    def test_unified_stats_dict(self, dense_model):
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               kv_layout="paged", block_size=16)
        reqs = [Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=6),
                Request(prompt=jnp.arange(9) % cfg.vocab, max_new_tokens=4)]
        eng.run(reqs)
        st = eng.stats()
        # one dict supersedes the scattered accessors — which must agree
        assert st["decode_compiles"] == eng.decode_cache_misses()
        assert st["prefill_entries"] == eng.prefill_cache_size()
        assert st["scheduler"]["admits"] == 2
        assert st["scheduler"]["retires"] == 2
        assert st["scheduler"]["pending"] == 0
        assert st["kv_pool"]["used"] == 0       # all pages returned
        assert st["recompiles_after_warm"] == 0
        assert "executor_cache" in st

    def test_lifecycle_metrics_observed(self, dense_model):
        cfg, model, params = dense_model
        obs.metrics_reset()
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        eng.run([Request(prompt=jnp.arange(5) % cfg.vocab,
                         max_new_tokens=6)])
        snap = obs.metrics_snapshot()
        assert snap["serve.requests_submitted"]["value"] >= 1
        assert snap["serve.requests_retired"]["value"] >= 1
        assert snap["serve.ttft_s"]["count"] >= 1
        assert snap["serve.queue_wait_s"]["count"] >= 1
        assert snap["serve.e2e_s"]["count"] >= 1

    def test_recompile_detector_fires_on_bucket_miss(self, dense_model,
                                                     caplog):
        """Warm on a small bucket, then force a LONGER prompt through —
        the new prefill bucket grows the jit cache and the detector must
        flag it (counter + stats + log record), exactly once."""
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        short = [Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=4)]
        eng.run(short)                          # first run() marks warm
        assert eng.stats()["recompiles_after_warm"] == 0

        with caplog.at_level(logging.WARNING, logger="repro.serve.engine"):
            eng.run([Request(prompt=jnp.arange(30) % cfg.vocab,
                             max_new_tokens=4)])
        st = eng.stats()
        assert st["recompiles_after_warm"] >= 1
        assert any("jit cache grew after warm-up" in r.message
                   for r in caplog.records)

        # warm traffic after the detector advanced its baseline: quiet
        caplog.clear()
        with caplog.at_level(logging.WARNING, logger="repro.serve.engine"):
            eng.run(short)
        assert st["recompiles_after_warm"] == \
            eng.stats()["recompiles_after_warm"]
        assert not caplog.records

    def test_traced_run_produces_loadable_trace(self, dense_model,
                                                tmp_path):
        """The acceptance criterion: a traced ContinuousEngine.run()
        yields a Chrome/Perfetto document with the serving spans nested
        correctly."""
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        obs.enable()
        eng.run([Request(prompt=jnp.arange(5) % cfg.vocab,
                         max_new_tokens=6)])
        obs.disable()
        path = tmp_path / "serve-trace.json"
        obs.export_trace(str(path))
        doc = json.loads(path.read_text())
        names = [e["name"] for e in doc["traceEvents"]]
        assert "serve.step_chunk" in names
        assert "serve.decode_chunk" in names
        assert "serve.prefill_chunk" in names
        decode = next(e for e in doc["traceEvents"]
                      if e["name"] == "serve.decode_chunk")
        assert decode["args"]["parent"] == "serve.step_chunk"


def drive(eng, reqs, key=None):
    """submit + step_chunk to idle; returns per-request RequestResults."""
    with eng._options_scope():
        eng._run_key = key if key is not None else jax.random.PRNGKey(7)
        rids = [eng.submit(r, stream=i) for i, r in enumerate(reqs)]
        while not eng.sched.idle:
            eng.step_chunk()
    return [eng.take_result(rid) for rid in rids]


# ---------------------------------------------------------------------------
# histogram percentiles
# ---------------------------------------------------------------------------

class TestHistogramPercentiles:
    def test_interpolated_quantiles_plausible(self):
        reg = obs.MetricsRegistry()
        h = reg.histogram("lat")
        for v in range(1, 101):
            h.observe(float(v))
        snap = reg.snapshot()["lat"]
        # base-2 buckets are coarse: assert ordering + sane ranges, not
        # exact values
        assert 25 <= snap["p50"] <= 75
        assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]
        assert snap["p99"] >= 64.0            # the top bucket's floor
        assert h.percentile(0.0) >= snap["min"]

    def test_quantiles_clamped_to_observed_range(self):
        reg = obs.MetricsRegistry()
        h = reg.histogram("one")
        h.observe(3.0)
        snap = reg.snapshot()["one"]
        assert snap["p50"] == snap["p99"] == 3.0   # clamped to min/max

    def test_underflow_bucket(self):
        reg = obs.MetricsRegistry()
        h = reg.histogram("z")
        for _ in range(10):
            h.observe(0.0)
        assert reg.snapshot()["z"]["p99"] == 0.0

    def test_empty_histogram_has_no_quantiles(self):
        reg = obs.MetricsRegistry()
        assert reg.histogram("e").percentile(0.5) is None


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    @pytest.fixture(autouse=True)
    def _clean_recorder(self):
        obs.configure_flight(dir=None)
        obs.flight_clear()
        yield
        obs.configure_flight(dir=None)
        obs.flight_clear()

    def test_events_ring_with_tracing_disabled(self):
        """The recorder is always on: obs.event lands in the ring even
        though the span tracer records nothing."""
        assert not obs.enabled()
        obs.event("unit.boundary", x=1)
        assert obs.trace_events() == []
        (e,) = [e for e in obs.flight_tail()
                if e["name"] == "unit.boundary"]
        assert e["kind"] == "event" and e["args"]["x"] == 1

    def test_spans_and_counter_deltas_tapped(self):
        obs.counter("unit.flight_c").inc(3)
        obs.enable()
        with obs.span("unit.flight_span"):
            pass
        seen = {(e["kind"], e["name"]) for e in obs.flight_tail()}
        assert ("metric", "unit.flight_c") in seen
        assert ("span", "unit.flight_span") in seen

    def test_ring_bounded(self):
        from repro.obs.recorder import FlightRecorder
        r = FlightRecorder(capacity=8)
        for i in range(100):
            r.record("event", f"e{i}")
        assert len(r) == 8
        assert r.tail(1)[0]["name"] == "e99"

    def test_dump_document_and_artefact(self, tmp_path):
        obs.configure_flight(dir=str(tmp_path))
        obs.event("pre.failure", req=7)
        doc = obs.flight_dump("unit_reason", req_id=7, why="test")
        assert doc["version"] == 1 and doc["reason"] == "unit_reason"
        assert doc["ctx"] == {"req_id": 7, "why": "test"}
        assert any(e["name"] == "pre.failure" for e in doc["events"])
        assert "metrics" in doc and "provenance" in doc
        assert obs.counter("obs.flight_dumps").value >= 1
        (path,) = tmp_path.glob("flight-*.json")
        loaded = json.loads(path.read_text())
        assert loaded["reason"] == "unit_reason"
        assert loaded["seq"] == doc["seq"]
        assert obs.flight_dumps()[-1]["reason"] == "unit_reason"

    def test_failed_request_dumps_clean_run_does_not(self, dense_model):
        """The resilience-bench contract as a unit drill: a clean run
        leaves the recorder silent; a NaN-poisoned request produces a
        ``request_failed`` dump attributing it by req_id."""
        from repro.testing import faults
        cfg, model, params = dense_model
        req = Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=4)

        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        (r,) = drive(eng, [req])
        assert r.state == "ok"
        assert obs.flight_dumps() == []

        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        with faults.inject("serve.nan_prefill(req_id=0)"):
            (r,) = drive(eng, [req])
        assert r.state == "failed"
        dumps = obs.flight_dumps()
        assert any(d["reason"] == "request_failed"
                   and d["ctx"]["req_id"] == 0 for d in dumps), \
            [d["reason"] for d in dumps]
        # the ring inside the dump shows the fault firing that caused it
        (d,) = [d for d in dumps if d["reason"] == "request_failed"]
        assert any(e["name"] == "faults.injected" for e in d["events"])

    def test_fault_event_carries_request_ctx(self, dense_model):
        """Satellite: a fault firing is attributed to the request(s) it
        hit via the site ctx riding in the event payload."""
        from repro.serve.resilience import ResilienceConfig
        from repro.testing import faults
        cfg, model, params = dense_model
        eng = ContinuousEngine(
            model, params, max_seq=64, slots=2, chunk=4,
            resilience=ResilienceConfig(retry_backoff_s=0.001))
        with faults.inject("serve.chunk_error(times=1)"):
            (r,) = drive(eng, [Request(prompt=jnp.arange(5) % cfg.vocab,
                                       max_new_tokens=4)])
        assert r.state == "ok"                  # retried through
        fired = [e for e in obs.flight_tail()
                 if e["name"] == "faults.injected" and e["kind"] == "event"]
        assert fired, "fault firing did not land in the recorder ring"
        assert any(e["args"].get("site") == "serve.chunk_error"
                   and "0" in e["args"].get("req_ids", "")
                   for e in fired), [e["args"] for e in fired]

    def test_recorder_overhead_under_5_percent(self, dense_model):
        """Satellite bound: one always-on boundary event (ring append +
        disabled instant) must cost < 5% of a jitted kernel call."""
        cfg, model, params = dense_model
        tok = jnp.zeros((4, 1), jnp.int32)
        cache = model.init_cache(4, 32)
        step = jax.jit(lambda p, t, c: model.decode_step(p, t, c,
                                                         jnp.int32(1)))
        jax.block_until_ready(step(params, tok, cache)[0])   # compile

        ts = []
        for _ in range(9):
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, tok, cache)[0])
            ts.append(time.perf_counter() - t0)
        kernel_t = sorted(ts)[len(ts) // 2]

        n = 50_000
        assert not obs.enabled()
        t0 = time.perf_counter()
        for _ in range(n):
            obs.event("x", a=1)
        per_event = (time.perf_counter() - t0) / n
        assert per_event < 0.05 * kernel_t, (
            f"recorder event costs {per_event * 1e9:.0f} ns, kernel call "
            f"{kernel_t * 1e6:.1f} us — overhead {per_event / kernel_t:.2%}")


# ---------------------------------------------------------------------------
# request-scoped traces
# ---------------------------------------------------------------------------

class TestRequestScopedTraces:
    def test_lifecycle_events_carry_req_id(self, dense_model):
        from repro.obs import report
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4)
        obs.enable()
        drive(eng, [Request(prompt=jnp.arange(5) % cfg.vocab,
                            max_new_tokens=6),
                    Request(prompt=jnp.arange(9) % cfg.vocab,
                            max_new_tokens=4)])
        obs.disable()
        evs = obs.trace_events()
        by_name = {}
        for e in evs:
            by_name.setdefault(e["name"], []).append(e)
        assert {e["args"]["req_id"] for e in by_name["serve.submit"]} \
            == {0, 1}
        assert by_name["serve.first_token"], "no TTFT events"
        assert all("ttft_s" in e["args"]
                   for e in by_name["serve.first_token"])
        # decode chunks name the co-batched requests they advanced
        decode = by_name["serve.decode_chunk"]
        assert any(e["args"].get("req_ids") for e in decode)
        # terminal retire event present for both requests
        assert {e["args"]["req_id"]
                for e in by_name["serve.retire"]} == {0, 1}
        assert all(e["args"]["state"] == "ok"
                   for e in by_name["serve.retire"])

        # the report stitches one request's timeline out of the trace
        tl = report.request_timeline(evs, "0")
        assert "serve.submit" in tl and "serve.decode_chunk" in tl

    def test_timeline_empty_for_unknown_request(self):
        from repro.obs import report
        assert "no events" in report.request_timeline([], "42")


# ---------------------------------------------------------------------------
# roofline drift audit
# ---------------------------------------------------------------------------

class TestDriftAudit:
    @pytest.fixture(autouse=True)
    def _fresh_auditor(self):
        from repro.obs import audit
        audit.reset()
        obs.flight_clear()
        yield
        audit.reset()

    def test_ratio_drift_fires_once_after_shift(self):
        from repro.obs import audit
        before = obs.counter("tune.drift").value
        a = audit.DriftAuditor(min_samples=8, tolerance=2.0)
        # calibration: no baseline yet, returns None, never fires
        for _ in range(8):
            assert a.observe("unit|k", 1.0) is None
        assert a.observe("unit|k", 1.1) == pytest.approx(1.1, rel=1e-6)
        assert obs.counter("tune.drift").value == before   # within 2x
        assert a.observe("unit|k", 5.0) == pytest.approx(5.0, rel=1e-6)
        assert obs.counter("tune.drift").value == before + 1
        a.observe("unit|k", 5.0)                           # no re-fire
        assert obs.counter("tune.drift").value == before + 1
        snap = a.snapshot()
        assert snap["keys"]["unit|k"]["fired"] is True
        assert snap["fired"] == 1
        # the firing landed in the flight-recorder ring
        assert any(e["name"] == "tune.drift" for e in obs.flight_tail())

    def test_stable_measurements_stay_quiet(self):
        from repro.obs import audit
        before = obs.counter("tune.drift").value
        a = audit.DriftAuditor(min_samples=8, tolerance=2.0)
        for i in range(50):
            a.observe("unit|stable", 1.0 + 0.1 * (i % 3))  # small wobble
        assert obs.counter("tune.drift").value == before
        assert a.snapshot()["fired"] == 0

    def test_ranking_audit_miscalibrated_hw_fires_default_quiet(self):
        """The acceptance drill: timings agree with the default roofline's
        ranking (quiet), but a deliberately mis-calibrated HwModel ranks a
        measured-slow candidate first — the audit flags it."""
        import dataclasses

        from repro.autotune import cost
        from repro.obs import audit

        # measured timings consistent with the default model: the fused
        # vpu-leaf candidate IS fastest, the unblocked seq fallback slow
        record = {"kernel": "dot", "shape": {"n": 4096},
                  "timings": {"block=4096,leaf=vpu": 1.0e-5,
                              "block=None,leaf=seq": 2.0e-3}}

        before = obs.counter("tune.drift").value
        a = audit.DriftAuditor()
        f = a.audit_record("dot", "dot|n=4096|unit", record,
                           hw=cost.hw_model())
        assert f is not None and f["agree"], f
        assert obs.counter("tune.drift").value == before

        # a grid-overhead mis-calibration inverts the ranking: the model
        # now prefers the unblocked candidate the measurements refute
        bad = dataclasses.replace(cost.hw_model(),
                                  grid_overhead_s=1e-5 * 1e4)
        f = a.audit_record("dot", "dot|n=4096|unit", record, hw=bad)
        assert f is not None and not f["agree"], f
        assert f["predicted_best"] == "block=None,leaf=seq"
        assert f["measured_best"] == "block=4096,leaf=vpu"
        assert f["slowdown_x"] > 100
        assert obs.counter("tune.drift").value == before + 1
        snap = a.snapshot()
        assert snap["ranking"]["dot|n=4096|unit"]["agree"] is False
        # once per key: a second audit does not re-fire
        a.audit_record("dot", "dot|n=4096|unit", record, hw=bad)
        assert obs.counter("tune.drift").value == before + 1

    def test_ranking_fire_marks_provenance_stale(self):
        import dataclasses

        from repro.autotune import cost
        from repro.obs import audit, provenance

        key = "dot|n=4096|stale-unit"
        provenance.record("kernel", "dot", key, {"block": 4096},
                          "cache(measured)")
        record = {"kernel": "dot", "shape": {"n": 4096},
                  "timings": {"block=4096,leaf=vpu": 1.0e-5,
                              "block=None,leaf=seq": 2.0e-3}}
        bad = dataclasses.replace(cost.hw_model(), grid_overhead_s=0.1)
        audit.DriftAuditor().audit_record("dot", key, record, hw=bad)
        d = provenance.get(key)
        assert d.origin == "cache(measured)[stale]"
        assert "consider re-tuning" in d.note

    def test_record_without_timings_skipped(self):
        from repro.obs import audit
        assert audit.DriftAuditor().audit_record(
            "dot", "k", {"timings": {"block=64,leaf=vpu": 1e-5}}) is None
        assert audit.DriftAuditor().audit_record("dot", "k", {}) is None


# ---------------------------------------------------------------------------
# the report renderer
# ---------------------------------------------------------------------------

class TestReport:
    def test_render_metrics_and_drift(self):
        from repro.obs import report
        snap = {"a.count": {"type": "counter", "value": 3},
                "a.lat": {"type": "histogram", "count": 4, "mean": 1.0,
                          "p50": 1.0, "p95": 2.0, "p99": 2.5, "max": 3.0,
                          "min": 0.5, "buckets": {}}}
        out = report.render_metrics(snap)
        assert "a.count" in out and "p95=2" in out
        drift = {"tolerance": 2.0, "fired": 1,
                 "keys": {"k1": {"n": 9, "fired": True, "drift_x": 5.0}},
                 "ranking": {"k2": {"predicted_best": "a",
                                    "measured_best": "b",
                                    "slowdown_x": 3.0}}}
        out = report.render_drift(drift)
        assert "DRIFTED" in out and "MIS-RANKED" in out

    def test_render_dump_and_history(self):
        from repro.obs import report
        doc = {"seq": 3, "reason": "request_failed", "ctx": {"req_id": 1},
               "events": [{"kind": "event", "name": "serve.submit",
                           "t": 0.0, "args": {"req_id": 1}},
                          {"kind": "span", "name": "serve.decode_chunk",
                           "t": 0.0, "dur_us": 12.5}],
               "drift": {}}
        out = report.render_dump(doc)
        assert "request_failed" in out and "serve.decode_chunk" in out
        hist = [{"t": "2026-08-08T00:00:00Z",
                 "serve": {"fused_tok_s": 5000.0},
                 "recompiles": 0, "drift": 0,
                 "resilience": {"faults_injected": 10}}]
        out = report.render_history(hist)
        assert "fused=5000" in out
        assert "empty" in report.render_history([])

    def test_live_render_smoke(self):
        from repro.obs import report
        obs.counter("unit.report_c").inc()
        obs.event("unit.report_e")
        out = report.render()
        assert "repro system report" in out
        assert "flight recorder" in out

    def test_cli_on_artefacts(self, tmp_path, capsys):
        from repro.obs import report
        obs.flight_clear()
        obs.configure_flight(dir=str(tmp_path / "fl"))
        obs.flight_dump("unit_cli", req_id=9)
        obs.configure_flight(dir=None)
        hist = tmp_path / "hist.json"
        hist.write_text(json.dumps([{"t": "2026-08-08", "serve": {},
                                     "recompiles": 0, "drift": 0}]))
        rc = report.main(["--flight", str(tmp_path / "fl"),
                          "--history", str(hist)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "unit_cli" in out and "bench history" in out
