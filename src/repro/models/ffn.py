"""FFN layers: SwiGLU MLP and MoE (top-k, capacity-based GShard dispatch).

The MoE einsum formulation is EP-ready: the expert dimension is sharded over
the 'model' mesh axis (sharding/rules.py), so the dispatch/combine einsums
lower to all_to_all-style collectives under SPMD.  The router adds the usual
load-balance auxiliary loss."""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense


class MlpParams(NamedTuple):
    w_gate: jax.Array   # (d, f)
    w_up: jax.Array     # (d, f)
    w_down: jax.Array   # (f, d)


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> MlpParams:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return MlpParams(init_dense(ks[0], d, f, cfg.dtype),
                     init_dense(ks[1], d, f, cfg.dtype),
                     init_dense(ks[2], f, d, cfg.dtype))


def mlp(p: MlpParams, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p.w_gate))
    h = h * jnp.einsum("bsd,df->bsf", x, p.w_up)
    return jnp.einsum("bsf,fd->bsd", h, p.w_down)


class MoeParams(NamedTuple):
    router: jax.Array     # (d, E)
    w_gate: jax.Array     # (E, d, f)
    w_up: jax.Array       # (E, d, f)
    w_down: jax.Array     # (E, f, d)


def init_moe(key, cfg: ModelConfig) -> MoeParams:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dense = lambda k, i, o: jnp.stack(  # noqa: E731
        [init_dense(kk, i, o, cfg.dtype) for kk in jax.random.split(k, e)])
    return MoeParams(
        router=init_dense(ks[0], d, e, "float32"),
        w_gate=dense(ks[1], d, f),
        w_up=dense(ks[2], d, f),
        w_down=jnp.stack([init_dense(kk, f, d, cfg.dtype)
                          for kk in jax.random.split(ks[3], e)]),
    )


def moe(p: MoeParams, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE, capacity-based scatter/gather dispatch.

    Dispatch/combine are scatter-adds and gathers rather than one-hot einsums:
    the GShard-style dense dispatch costs 2.5*k*T^2*d dispatch FLOPs and a
    (T, E, cap) tensor — ~70x the useful compute at 1M-token batches.  The
    scatter form costs O(T*k*d) data movement and zero MXU work, leaving the
    expert matmuls as the only dots (verified by the scan-aware HLO counter).
    Returns (out, aux_loss).
    """
    from repro.sharding import ctx

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s

    # GShard-style GROUPS: routing/capacity computed per data-parallel group,
    # so slot assignment (cumsum) and the dispatch scatter are group-LOCAL —
    # no cross-shard scatter for GSPMD to turn into all-gathers
    # (EXPERIMENTS.md section Perf, dbrx iterations 1-2).
    g = 1
    mesh = ctx.get_mesh()
    if mesh is not None:
        import numpy as np
        dp = ctx.dp_axes() or ()
        g = int(np.prod([mesh.shape[a] for a in dp])) or 1
        if t % g or (t // g) < 1:
            g = 1
    tl = t // g
    # capacity floor min(tl, 64): small (decode-sized) batches never drop,
    # so cached decode agrees with full-sequence scoring
    cap = max(int(cfg.moe_capacity_factor * tl * k / e), min(tl, 64), 1)

    xg = x.reshape(g, tl, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p.router)
    probs = jax.nn.softmax(logits, axis=-1)

    topk_p, topk_i = jax.lax.top_k(probs, k)                    # (g, tl, k)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                            # (e,)
    one_hot_all = jax.nn.one_hot(topk_i, e, dtype=jnp.float32)
    ce_frac = jnp.mean(jnp.sum(one_hot_all, axis=2), axis=(0, 1))
    aux = e * jnp.sum(me * ce_frac) / k

    # slot assignment: position within (group, expert) via group-local cumsum
    flat_i = topk_i.reshape(g, tl * k)
    one_hot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)         # (g, tlk, e)
    pos = jnp.sum(jnp.cumsum(one_hot, axis=1) * one_hot, axis=-1) - 1
    keep = pos < cap
    gate = topk_p.reshape(g, tl * k) * keep                      # (g, tlk)
    pos_c = jnp.clip(pos, 0, cap - 1)

    # group-local scatter into (g, e, cap, d) expert buffers
    tok_idx = jnp.repeat(jnp.arange(tl), k)                      # (tlk,)
    xk = jnp.take(xg, tok_idx, axis=1)                           # (g, tlk, d)
    xk = xk * keep[..., None].astype(xk.dtype)
    g_idx = jnp.broadcast_to(jnp.arange(g)[:, None], flat_i.shape)
    # buf stays dp-sharded on g and REPLICATED on e: the scatter is local,
    # the expert einsum contracts against e-sharded weights (output lands
    # e-sharded), and the only collective is one ye all-gather over 'model'
    # before the token-side combine — ~t*k*d bytes/layer, the EP ideal
    # (EXPERIMENTS.md section Perf, dbrx iteration 3).
    buf = jnp.zeros((g, e, cap, d), xg.dtype)
    buf = buf.at[g_idx, flat_i, pos_c].add(xk, mode="drop")
    buf = ctx.constraint(buf, ctx.dp_axes(), None, None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p.w_gate))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p.w_up)
    ye = jnp.einsum("gecf,efd->gecd", h, p.w_down)               # (g,e,cap,d)
    ye = ctx.constraint(ye, ctx.dp_axes(), None, None, None)

    # group-local gather back, combine weighted by gate
    yk = ye[g_idx, flat_i, pos_c]                                # (g, tlk, d)
    yk = yk * gate[..., None].astype(ye.dtype)
    out = jnp.zeros((g, tl, d), ye.dtype).at[
        g_idx, jnp.broadcast_to(tok_idx[None], flat_i.shape)].add(yk)
    out = ctx.constraint(out, ctx.dp_axes(), None, None)
    return out.reshape(b, s, d).astype(x.dtype), aux
