"""HLO analysis: collective-byte accounting + roofline terms.

``collective_bytes`` parses HLO text and sums the result-shape bytes of every
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), which cost_analysis() does not report.  ``roofline``
combines cost_analysis with the collective bytes into the three-term model
(EXPERIMENTS.md section Roofline):

    compute    = FLOPs / (chips * peak_flops)
    memory     = bytes / (chips * hbm_bw)
    collective = coll_bytes / (chips * ici_bw)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e-like hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# matches:  %x = f32[8,16]{1,0} all-reduce(...)   or tuple results
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = _shape_bytes(shape_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll: CollectiveStats
    chips: int
    bytes_min: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0           # write-once ceiling
    memory_floor_s: float = 0.0     # perfectly-fused floor
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: Optional[float] = None
    xla_flops_raw: Optional[float] = None

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.bytes_accessed / (self.chips * HBM_BW)
        self.memory_floor_s = self.bytes_min / (self.chips * HBM_BW)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.collective_s = self.coll.total_bytes / (self.chips * ICI_BW)
        terms["collective"] = self.collective_s
        self.bottleneck = max(terms, key=terms.get)
        return self

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if self.model_flops and self.flops:
            return self.model_flops / self.flops
        return None

    @property
    def step_time_s(self) -> float:
        """Optimistic (max of terms) step-time estimate."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "bytes_min": self.bytes_min,
            "coll_bytes": self.coll.total_bytes,
            "coll_count": self.coll.total_count,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_floor_s": self.memory_floor_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_frac": self.useful_flops_frac,
            "xla_flops_raw": self.xla_flops_raw,
        }


def analyze(compiled, *, chips: int, model_flops: Optional[float] = None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from a compiled jax executable.

    Uses the scan-aware HLO counter (hlo_counter.py): XLA's cost_analysis
    counts while/scan bodies once, which undercounts layer-scanned models by
    the layer count.  All quantities are per-partition (the SPMD module), so
    the time terms divide by per-chip peak rates with chips=1 scaling — we
    keep the global convention by multiplying back by ``chips``.
    """
    from . import hlo_counter

    text = hlo_text if hlo_text is not None else compiled.as_text()
    tot = hlo_counter.analyze_text(text)
    # per-partition counts x chips = global work
    flops = tot.flops * chips
    byts = tot.bytes * chips
    coll = CollectiveStats(
        bytes_by_kind={k: v * chips for k, v in tot.coll_by_kind.items()},
        count_by_kind={k: 1 for k in tot.coll_by_kind},
    )
    r = Roofline(flops=flops, bytes_accessed=byts, coll=coll,
                 chips=chips, bytes_min=tot.bytes_min * chips,
                 model_flops=model_flops).finalize()
    # raw (scan-unaware) XLA numbers kept for reference
    try:
        c = compiled.cost_analysis()
        ca = c[0] if isinstance(c, (list, tuple)) else (c or {})
        r.xla_flops_raw = float(ca.get("flops", 0.0))
    except Exception:
        pass
    return r
