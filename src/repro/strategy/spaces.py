"""Strategy *programs* for the autotune spaces.

The six hand-written kernel spaces in ``repro.autotune.space`` used to pick
one of several builder functions per params dict; here every point of every
space is instead a :class:`~repro.strategy.lang.Strategy` program applied
to the kernel's *naive spec* — the schedule is derived, never hand-built,
and the derivation (the :class:`StrategyTrace`) travels with the winner
into the tuning cache.  ``autotune.space`` delegates to
:func:`spec_builder` + :func:`program_for`, with oracle-equality against
the legacy builders pinned in tests/test_strategy.py.

:func:`generic_space` is the open-ended version the language buys us: the
same rules composed blindly over *any* well-typed DPIA term, ill-typed or
inapplicable compositions failing harmlessly — demonstrated on a fused
RMSNorm→matmul program (:func:`fused_rmsnorm_matmul`) that has no hand
space anywhere in the repo.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.dpia import phrases as P
from repro.core.dpia.types import Arr, Num

from . import traverse
from .lang import Result, Strategy, id_, rule, seq, try_

__all__ = ["spec_builder", "program_for", "generic_programs",
           "generic_space", "fused_rmsnorm_matmul", "GRID0"]

GRID0 = "grid(0)"

Builder = Callable[[], Tuple[P.Phrase, List[P.Var]]]


# ---------------------------------------------------------------------------
# the six kernel spaces, as (naive spec, params -> strategy program)
# ---------------------------------------------------------------------------

def spec_builder(kernel: str, **shape) -> Builder:
    """The naive (strategy-free) spec each kernel's space derives from."""
    from repro.kernels import dpia_blas
    if kernel in ("dot", "asum", "scal"):
        naive = getattr(dpia_blas, f"naive_{kernel}")
        n = shape["n"]
        return lambda: naive(n)
    if kernel == "matmul":
        m, k, n = shape["m"], shape["k"], shape["n"]
        return lambda: dpia_blas.naive_matmul(m, k, n)
    if kernel == "rmsnorm":
        rows, d = shape["rows"], shape["d"]
        eps = shape.get("eps", 1e-6)
        return lambda: dpia_blas.naive_rmsnorm(rows, d, eps)
    if kernel == "softmax":
        rows, d = shape["rows"], shape["d"]
        return lambda: dpia_blas.naive_softmax(rows, d)
    raise ValueError(f"spec_builder: unknown kernel {kernel!r}")


def _blocked_reduce_program(block: int, leaf: str) -> Strategy:
    prog = seq(rule("fuse_map_into_reduce"),
               rule("blocked_reduce", block=block,
                    partial_level=GRID0, combine="add"))
    if leaf == "vpu":
        # innermost-first: the per-block sequential reduce (inside the grid
        # map's binder) becomes the whole-block VPU FullReduce; topdown
        # would wrongly fire on the outer partials-combine instead
        prog = seq(prog, traverse.bottomup(rule("vpu_reduce")))
    return prog


def _row_block_program(row_block: int) -> Strategy:
    return seq(rule("split_join", block=row_block),
               traverse.one(rule("with_level", level=GRID0)))


def program_for(kernel: str, params: Dict[str, object]) -> Strategy:
    """The strategy program one params dict of a kernel's space denotes.

    Shape-independent: divisibility and typing side conditions live in the
    rules, so an inapplicable program *fails* rather than building a bad
    term."""
    if kernel in ("dot", "asum"):
        if params.get("block") is None:
            return id_()
        return _blocked_reduce_program(int(params["block"]),
                                       str(params.get("leaf", "vpu")))
    if kernel == "scal":
        if params.get("block") is None:
            return id_()
        prog = _row_block_program(int(params["block"]))
        if params.get("vector") is None:
            # the block handled as one lifted VPU op (the lanes reading)
            return seq(prog, traverse.bottomup(rule("lift_lanes")))
        return seq(prog, traverse.bottomup(
            rule("vectorize", width=int(params["vector"]))))
    if kernel == "matmul":
        return rule("tile_matmul", bm=int(params["bm"]),
                    bk=int(params["bk"]))
    if kernel in ("rmsnorm", "softmax"):
        return _row_block_program(int(params["row_block"]))
    raise ValueError(f"program_for: unknown kernel {kernel!r}")


# ---------------------------------------------------------------------------
# the generic space: any well-typed term, strategies for free
# ---------------------------------------------------------------------------

def generic_programs(blocks: Sequence[int],
                     lanes: Sequence[int] = (128,),
                     tiles: Sequence[int] = (32, 64, 128, 256)
                     ) -> List[Tuple[Dict[str, object], Strategy]]:
    """Candidate (params, program) pairs composing the rule vocabulary.

    Deliberately over-generates: programs whose side conditions a given
    term cannot meet simply fail at ``apply`` time and are dropped by
    :func:`generic_space` — failure-as-a-value is what lets one menu serve
    every term."""
    out: List[Tuple[Dict[str, object], Strategy]] = [
        ({"rewrite": "id"}, id_()),
    ]
    for b in blocks:
        out.append((
            {"rewrite": "blocked_reduce", "block": b},
            rule("blocked_reduce", block=b, partial_level=GRID0)))
        out.append((
            {"rewrite": "fuse+blocked", "block": b},
            _blocked_reduce_program(b, "seq")))
        out.append((
            {"rewrite": "fuse+blocked+vpu", "block": b},
            _blocked_reduce_program(b, "vpu")))
        out.append((
            {"rewrite": "split_join", "block": b},
            _row_block_program(b)))
        out.append((
            {"rewrite": "split+lanes", "block": b},
            seq(_row_block_program(b), traverse.bottomup(rule("lift_lanes")))))
        for w in lanes:
            if b % w == 0:
                out.append((
                    {"rewrite": "split+vec", "block": b, "vector": w},
                    seq(_row_block_program(b),
                        traverse.bottomup(rule("vectorize", width=w)))))
    for bm in tiles:
        for bk in tiles:
            out.append((
                {"rewrite": "tile_matmul", "bm": bm, "bk": bk},
                rule("tile_matmul", bm=bm, bk=bk)))
            out.append((
                {"rewrite": "tile_matmul+vmem", "bm": bm, "bk": bk},
                seq(rule("tile_matmul", bm=bm, bk=bk),
                    try_(rule("stage_vmem")))))
    return out


def generic_space(expr: P.Phrase,
                  blocks: Sequence[int] = (128, 256, 512, 1024, 2048),
                  lanes: Sequence[int] = (128,),
                  tiles: Sequence[int] = (32, 64, 128, 256)
                  ) -> List[Tuple[Dict[str, object], Strategy, Result]]:
    """Every generic program that *succeeds* on ``expr``, deduplicated by
    the structural fingerprint of the rewritten term.  The identity always
    survives, so the space is never empty for a well-typed term."""
    out, seen = [], set()
    for params, prog in generic_programs(blocks, lanes, tiles):
        res = prog.apply(expr)
        if not res.ok:
            continue
        fp = traverse.fingerprint(res.phrase)
        if fp in seen:
            continue
        seen.add(fp)
        out.append((params, prog, res))
    return out


def fused_rmsnorm_matmul(rows: int, d: int, n: int, eps: float = 1e-6
                         ) -> Tuple[P.Phrase, List[P.Var]]:
    """RMSNorm fused into a matmul — ``(rmsnorm(xs, w)) @ B`` as one term.

    No hand space exists for this op anywhere in the repo; the generic
    space gives it MXU tiling (``tile_matmul`` matches the outer matmul
    shape with the normalisation riding along as the lhs operand) and row
    blocking for free."""
    from repro.kernels import dpia_blas
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    w = P.var_exp("w", Arr(d, Num()))
    b = P.var_exp("B", Arr(d, Arr(n, Num())))
    normed = P.Map(dpia_blas.rmsnorm_row(d, eps, w), xs)
    e = P.Map(lambda row: P.Map(
        lambda col: P.Reduce(
            lambda q, acc: P.add(acc, q), P.lit(0.0),
            P.Map(lambda z: P.mul(P.Fst(z), P.Snd(z)), P.Zip(row, col))),
        P.Transpose(b)), normed)
    return e, [xs, w, b]
