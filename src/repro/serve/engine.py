"""Serving engines: the decode fast path.

The decode hot loop runs entirely on device: a jitted ``lax.scan`` advances
``chunk`` tokens per call with sampling (per-request temperature + top-k)
fused into the step, the KV cache and the token/position/key buffers donated
(``donate_argnums``) so decode is copy-free, and the host syncs exactly once
per chunk — it reads the ``(batch, chunk)`` token block after the scan, never
an individual token.

Two engines share that core:

  * :class:`BatchedEngine` — static batch: prefill all requests together,
    decode lock-step until every request has its tokens (the oracle the
    continuous engine is tested against).
  * :class:`ContinuousEngine` — continuous batching over a fixed number of
    device slots: requests are admitted into free slots and retired at chunk
    boundaries (:mod:`repro.serve.scheduler`), prompts are right-padded to
    power-of-two buckets and the decode batch is always ``slots`` wide, so
    jit sees a small closed set of shapes — zero recompiles after one pass
    over the buckets.  KV memory is a strategy dimension
    (``kv_layout="dense"|"paged"|"auto"``, :mod:`repro.serve.paged`) and
    long prompts prefill in chunks across boundaries (``prefill_chunk=``),
    capping the bucket set.
  * :class:`ShardedEngine` — the same continuous engine with the slot axis
    sharded over a named mesh axis (``data``): device state carries
    ``NamedSharding`` placements and GSPMD partitions the identical jitted
    chunk, so decode runs data-parallel and stays token-identical.  With
    ``hosts=`` the mesh's devices partition into failure domains
    (:mod:`repro.serve.domains`): a host lost or straggling at a chunk
    boundary evacuates its slots back to the queue, shrinks the mesh onto
    the survivors, and records the shrink as a ``degraded(mesh(a)->mesh(b))``
    provenance origin — survivors and evacuees alike stay token-identical.

Every engine can keep a scheduler-state **journal** (``journal=`` path,
:class:`repro.serve.domains.SchedulerJournal`): submissions, per-boundary
emitted-token snapshots, and terminal states, append-only and per-record
checksummed, so a crashed/killed engine's surviving requests
``domains.replay`` to token identity in a fresh process.

Sampling determinism: each request's PRNG stream is
``fold_in(run_key, request_index)`` advanced once per sampled token, so the
tokens a request receives are a function of the request alone — independent
of which other requests share the batch, of slot assignment, and of chunk
size.  That is what makes continuous-batching output token-identical to the
static oracle.

Engines with a ``tuning_cache`` pre-tune the strategy autotuner for the
model's kernel shapes at build time, stage the corresponding executors, and
persist them ahead-of-time next to the tuning cache
(``repro.compiler.executor_cache().save_aot``) — a restarted engine loads
the lowered programs and skips Stage I->II entirely.  ``run`` scopes the
``repro.kernels.ops`` dispatch to that cache thread-locally via
``repro.compiler.options(tuning_cache=...)``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ft.resilience import Watchdog
from repro.models.transformer import Model
from repro.serve.resilience import (RequestResult, ResilienceConfig,
                                    record_degradation)
from repro.serve.scheduler import Scheduler, pick_bucket, seq_buckets
from repro.testing import faults

log = logging.getLogger("repro.serve.engine")

__all__ = ["Request", "BatchedEngine", "ContinuousEngine", "ShardedEngine",
           "sample", "sample_tokens"]


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """Single-temperature sampling (whole batch shares the knobs).

    ``temperature <= 0`` is greedy argmax.  ``top_k > 0`` keeps the k
    largest logits per row; values tied with the k-th largest are all kept
    (the cutoff is a >=-threshold, not a count), and ``top_k >= vocab`` is a
    no-op."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)


def sample_tokens(logits, keys, temps, top_ks):
    """Per-request sampling, vectorised over the batch — the form fused into
    the decode chunk.

    logits (b, vocab) f32; keys (b, 2) per-slot PRNG keys; temps (b,) f32
    (``<= 0`` means greedy for that row); top_ks (b,) int32 (``0`` means no
    top-k filter).  Same per-row semantics as :func:`sample`.

    The expensive paths are gated on runtime predicates (``lax.cond``), so
    an all-greedy batch pays an argmax and nothing else — no full-vocab
    sort, no gumbel draw — even though the same compiled chunk serves every
    temperature mix."""
    vocab = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)

    def with_topk(scaled):
        desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        k = jnp.clip(jnp.where(top_ks > 0, top_ks, vocab), 1, vocab)
        kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)
        return jnp.where(scaled < kth, -jnp.inf, scaled)

    def sampled(_):
        t = jnp.maximum(temps, 1e-6)[:, None]
        scaled = logits / t
        masked = jax.lax.cond(jnp.any((top_ks > 0) & (temps > 0.0)),
                              with_topk, lambda s: s, scaled)
        return jax.vmap(lambda kk, row: jax.random.categorical(kk, row))(
            keys, masked)

    toks = jax.lax.cond(jnp.any(temps > 0.0), sampled,
                        lambda _: greedy, None)
    return jnp.where(temps <= 0.0, greedy, toks).astype(jnp.int32)


def _split_keys(keys):
    """Advance a (b, 2) batch of PRNG keys one step: (carry, subkeys)."""
    pairs = jax.vmap(lambda k: jax.random.split(k))(keys)
    return pairs[:, 0], pairs[:, 1]


def _slot_axis(big, small) -> Optional[int]:
    """The slot/batch axis of a cache leaf: the unique axis where the
    1-slot shape differs from the engine shape (None when slots == 1, i.e.
    the slot IS the cache).  Works on every cache pytree leaf (dense
    KVCache, rwkv states, the hybrid mamba+kv dict) — shared by slot
    insertion and by ShardedEngine's sharding specs so the two can never
    disagree on which axis is the batch."""
    return next((i for i, (a, c) in enumerate(zip(big.shape, small.shape))
                 if a != c), None)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray          # (s,) or (s, K)
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    out_tokens: Optional[List[int]] = None
    # per-request deadlines, both measured from submission: ``deadline_s``
    # is end-to-end, ``ttft_deadline_s`` applies until the first token.
    # Enforced at chunk boundaries (the host never sees mid-chunk time);
    # an expired request ends in terminal state "timeout" with its partial
    # tokens intact (repro.serve.resilience.STATES).
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None


# ---------------------------------------------------------------------------
# shared engine core
# ---------------------------------------------------------------------------

class _EngineBase:
    """Model/params + the jitted fast-path functions + tuner/AOT warm-up."""

    def __init__(self, model: Model, params, *, max_seq: int, chunk: int,
                 tuning_cache=None, batch_sizes=(1, 8), aot="auto",
                 kv_layout: str = "dense",
                 resilience: Optional[ResilienceConfig] = None):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', got "
                             f"{kv_layout!r}")
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.chunk = chunk
        self.kv_layout = kv_layout
        self.tuning_cache = tuning_cache
        self.tuned: Dict[str, dict] = {}
        self.resilience = resilience or ResilienceConfig()
        # chunk-level straggler detection reuses the hardened train-loop
        # Watchdog (its disarm race is fixed — a chunk finishing just under
        # the deadline can no longer record a spurious straggler)
        self._watchdog: Optional[Watchdog] = None
        if self.resilience.chunk_deadline_s is not None:
            self._watchdog = Watchdog(self.resilience.chunk_deadline_s,
                                      on_straggler=self._on_straggler)
        self._n_chunk_calls = 0
        self._n_chunk_retries = 0
        self._n_chunk_quarantines = 0
        self._n_nan_quarantines = 0
        self._n_degradations = 0
        # recompile detector: (decode compiles, prefill entries) at the last
        # ``mark_warm()``; None until the engine declares itself warm
        self._jit_baseline = None
        self._recompiles_after_warm = 0
        self._drift_audited = False
        if tuning_cache is not None:
            self._warm(batch_sizes, aot)
        self._prefill = jax.jit(
            lambda params, tokens, cache, lengths:
            model.prefill(params, tokens, cache, lengths=lengths))
        # fresh-cache prefill: the zero cache is materialised INSIDE the
        # program, so XLA fuses the zero-init with the cache writes — no
        # host-side init_cache allocation, no input-cache copy per call
        self._prefill_fresh = jax.jit(
            lambda params, tokens, lengths:
            model.prefill(params, tokens,
                          model.init_cache(tokens.shape[0], max_seq),
                          lengths=lengths))
        self._prefill_exes: Dict[tuple, object] = {}
        self._warned_prefill_fallback = False
        self._sample0 = jax.jit(sample_tokens)
        self._chunk_fn = self._make_chunk_fn()

    # -- fused decode chunk --------------------------------------------------

    def _make_chunk_fn(self):
        model, cfg, max_seq = self.model, self.model.cfg, self.max_seq

        def chunk_fn(params, cache, tokens, pos, keys, temps, top_ks, bt):
            # paged: gather each slot's pages into a dense-shaped view ONCE
            # per chunk; steps attend/update the view and mirror the token
            # write into the pool — the page indirection is paid per chunk,
            # not per token per layer
            view = None if bt is None else model.gather_paged_view(cache, bt)
            bad0 = jnp.zeros(tokens.shape[:1], bool)

            def step(carry, _):
                tokens, cache, view, pos, keys, bad = carry
                tok = tokens[:, None]
                if cfg.n_codebooks:
                    tok = jnp.broadcast_to(
                        tok[..., None],
                        (tok.shape[0], 1, cfg.n_codebooks))
                if view is None:
                    logits, cache = model.decode_step(params, tok, cache,
                                                      pos, block_tables=bt)
                else:
                    logits, cache, view = model.decode_step(
                        params, tok, cache, pos, block_tables=bt,
                        kv_view=view)
                # NaN guard: a per-slot poison flag, sticky across the scan.
                # Pure observation — the token dataflow is untouched, so
                # clean rows stay bitwise-identical with the guard on
                bad = bad | ~jnp.isfinite(
                    logits.reshape(logits.shape[0], -1)).all(axis=1)
                keys, sub = _split_keys(keys)
                nxt = sample_tokens(logits, sub, temps, top_ks)
                # clamp: a retired slot keeps decoding until the boundary;
                # past max_seq its (per-slot-path) cache writes are dropped
                # (the paged path drops through the block-table sentinel)
                pos = jnp.minimum(pos + 1, max_seq)
                return (nxt, cache, view, pos, keys, bad), nxt

            (tokens, cache, view, pos, keys, bad), toks = jax.lax.scan(
                step, (tokens, cache, view, pos, keys, bad0), None,
                length=self.chunk)
            return cache, tokens, pos, keys, toks.T, bad  # toks: (b, chunk)

        # cache + token/pos/key buffers are donated: decode is copy-free and
        # the engine rebinds the returned buffers each chunk.  ``bt`` (the
        # block tables; None for dense layouts) is tiny and read-only.
        return jax.jit(chunk_fn, donate_argnums=(1, 2, 3, 4))

    def _on_straggler(self, chunk_i: int, dt: float) -> None:
        obs.counter("serve.stragglers").inc()
        obs.event("serve.straggler", chunk=chunk_i, elapsed_s=round(dt, 4))
        log.warning("decode chunk %d exceeded the chunk deadline "
                    "(%.3fs > %.3fs) — straggler suspected", chunk_i, dt,
                    self.resilience.chunk_deadline_s)

    @staticmethod
    def _args_consumed(args) -> bool:
        """True when any donated buffer in ``args`` was consumed by a
        failed dispatch — re-invoking would read deleted buffers, so the
        retry loop must stop and the caller rebuild device state."""
        for leaf in jax.tree_util.tree_leaves(args):
            if getattr(leaf, "is_deleted", None) and leaf.is_deleted():
                return True
        return False

    def _call_chunk(self, args, req_ids: str = ""):
        """Invoke the fused decode chunk with the resilience wrapping: the
        ``serve.slow_chunk`` / ``serve.chunk_error`` fault sites, the
        chunk-level straggler watchdog, and bounded retry-with-backoff for
        transient failures.

        ``req_ids`` (comma-joined active request ids) attributes the fault
        sites and failure events to the requests riding the chunk, so a
        drill's trace/flight-dump names who was affected.

        Retry is only safe while the donated buffers are intact — faults
        injected here fire *before* dispatch, and a dispatch that died
        after consuming its donation (:meth:`_args_consumed`) is not
        retried: the exception propagates and the continuous engine
        quarantines in-flight work + rebuilds device state."""
        rc = self.resilience
        self._n_chunk_calls += 1
        attempt = 0
        while True:
            try:
                if self._watchdog is not None:
                    self._watchdog.arm(self._n_chunk_calls)
                try:
                    f = faults.should_fire("serve.slow_chunk",
                                           req_ids=req_ids)
                    if f is not None:
                        time.sleep(float(f.value or 0.05))
                    faults.raise_if("serve.chunk_error", req_ids=req_ids)
                    return self._chunk_fn(*args)
                finally:
                    if self._watchdog is not None:
                        self._watchdog.disarm()
            except Exception as e:
                attempt += 1
                obs.counter("serve.chunk_failures").inc()
                obs.event("serve.chunk_failure", attempt=attempt,
                          req_ids=req_ids,
                          error=f"{type(e).__name__}: {e}")
                if attempt > rc.max_chunk_retries or self._args_consumed(args):
                    raise
                self._n_chunk_retries += 1
                log.warning("decode chunk failed (%s: %s); retry %d/%d",
                            type(e).__name__, e, attempt,
                            rc.max_chunk_retries)
                time.sleep(rc.retry_backoff_s * attempt)

    # -- prefill: per-bucket AOT executables ---------------------------------

    def _prefill_call(self, tokens, lengths):
        """Run the fresh-cache, length-aware prefill through a PER-SHAPE
        ahead-of-time compiled executable.

        This is the admission path's fix for the PR 3 prefill regression
        (BENCH_serve.json showed fused prefill LOSING to the legacy loop):
        ``jax.jit`` dispatch re-hashed the call signature every admission,
        and every call re-padded + copied a host-initialised zero cache
        through an undonated argument.  The engine instead lowers +
        compiles once per padded-bucket shape, calls the executable
        directly, and lets the program build its own zero cache.  Falls
        back to the jitted path if the executable rejects the arguments
        (e.g. sharding drift)."""
        key = (tokens.shape, str(tokens.dtype))
        exe = self._prefill_exes.get(key)
        if exe is None:
            with obs.span("serve.prefill_compile", shape=str(tokens.shape)):
                exe = self._prefill_fresh.lower(self.params, tokens,
                                                lengths).compile()
            self._prefill_exes[key] = exe
        try:
            return exe(self.params, tokens, lengths)
        except Exception as e:
            # safe only because nothing is donated here; warn so a
            # persistent mismatch (every admission paying jit dispatch)
            # is a diagnosable regression, not an invisible one
            obs.counter("serve.prefill_fallbacks").inc()
            obs.event("serve.prefill_fallback",
                      error=f"{type(e).__name__}: {e}")
            if not self._warned_prefill_fallback:
                self._warned_prefill_fallback = True
                import warnings
                msg = (f"prefill executable rejected its arguments "
                       f"({type(e).__name__}: {e}); falling back to jit "
                       f"dispatch for this engine")
                log.warning("%s", msg)
                warnings.warn(msg, RuntimeWarning)
            return self._prefill_fresh(self.params, tokens, lengths)

    def prefill_cache_size(self) -> int:
        """Number of compiled prefill entries (AOT executables + any jitted
        continuation/paged variants) — the serving benchmark's prefill
        recompile accounting."""
        n = len(self._prefill_exes) + int(self._prefill._cache_size())
        for name in ("_prefill_cont", "_prefill_paged0", "_prefill_pagedC"):
            fn = getattr(self, name, None)
            if fn is not None:
                n += int(fn._cache_size())
        return n

    def decode_cache_misses(self) -> int:
        """Number of XLA compilations of the fused decode chunk so far (the
        'recompile count' the serving benchmark and tests watch)."""
        return int(self._chunk_fn._cache_size())

    # -- unified stats + recompile detector ----------------------------------

    def stats(self) -> dict:
        """Every number the engine exposes, in one dict.

        Supersedes poking ``decode_cache_misses()`` / ``prefill_cache_size()``
        / the executor cache / the scheduler one at a time (those accessors
        all remain).  Subclasses extend the dict; they never replace keys."""
        from repro import compiler
        return {
            "decode_compiles": self.decode_cache_misses(),
            "prefill_entries": self.prefill_cache_size(),
            "recompiles_after_warm": self._recompiles_after_warm,
            "executor_cache": compiler.executor_cache().stats(),
            "latency": self._latency_stats(),
            "resilience": {
                "chunk_retries": self._n_chunk_retries,
                "chunk_quarantines": self._n_chunk_quarantines,
                "nan_quarantines": self._n_nan_quarantines,
                "degradations": self._n_degradations,
                "stragglers": (len(self._watchdog.events)
                               if self._watchdog is not None else 0),
            },
        }

    @staticmethod
    def _latency_stats() -> dict:
        """Percentile summaries of the serving latency histograms.

        Reads the process-wide metrics registry (histograms are global, so
        numbers cover every engine in the process); only histograms with
        observations appear."""
        reg = obs.registry()
        out = {}
        for name in ("serve.queue_wait_s", "serve.ttft_s", "serve.e2e_s",
                     "serve.decode_tok_s", "serve.chunk_s"):
            h = reg.histogram(name)
            if h.count:
                out[name.split(".", 1)[1]] = {
                    "count": h.count, "mean": h.mean,
                    "p50": h.percentile(0.50),
                    "p95": h.percentile(0.95),
                    "p99": h.percentile(0.99),
                }
        return out

    def _jit_sizes(self):
        return (self.decode_cache_misses(), self.prefill_cache_size())

    def mark_warm(self) -> None:
        """Declare the jit caches warm: any growth past this point is a
        *recompile* — flagged by the detector, counted in ``stats()``.
        ``run`` calls this automatically when its first batch completes."""
        self._jit_baseline = self._jit_sizes()
        self._audit_drift()

    def _audit_drift(self) -> None:
        """Serve-boundary roofline audit: re-rank every *measured* tuning
        record for this engine's cache under the current HwModel and fire
        ``tune.drift`` on predicted-vs-measured ranking disagreement.

        Runs once per engine at the warm boundary (analytic only — builds
        exprs, compiles nothing); records without per-candidate timings
        are skipped, so analytic-only caches cost ~nothing."""
        if self.tuning_cache is None or self._drift_audited:
            return
        self._drift_audited = True
        try:
            from repro.autotune.api import _resolve_cache
            obs.audit_cache(_resolve_cache(self.tuning_cache))
        except Exception:
            log.debug("drift audit skipped", exc_info=True)

    def _check_recompiles(self) -> None:
        """Compare jit-cache sizes against the warm baseline; flag growth.

        Fires a structured obs event + a ``logging`` warning (NOT
        ``warnings.warn`` — a recompile is a performance regression, never
        an error) and advances the baseline so each growth is reported
        once."""
        if self._jit_baseline is None:
            return
        cur = self._jit_sizes()
        base = self._jit_baseline
        grew = sum(max(0, c - b) for c, b in zip(cur, base))
        if not grew:
            return
        self._recompiles_after_warm += grew
        self._jit_baseline = cur
        obs.counter("serve.recompiles_after_warm").inc(grew)
        obs.event("serve.recompile_after_warm",
                  decode_compiles=cur[0], prefill_entries=cur[1],
                  baseline_decode=base[0], baseline_prefill=base[1])
        log.warning(
            "jit cache grew after warm-up: decode compiles %d -> %d, "
            "prefill entries %d -> %d (a new shape/bucket reached the "
            "engine; warm traffic should never recompile)",
            base[0], cur[0], base[1], cur[1])

    # -- autotune + AOT warm-up ----------------------------------------------

    def _aot_dir(self, aot) -> Optional[str]:
        if aot is None or aot is False:
            return None
        if isinstance(aot, str) and aot != "auto":
            return aot
        path = getattr(self.tuning_cache, "path", None) or (
            self.tuning_cache if isinstance(self.tuning_cache, str) else None)
        return (str(path) + ".aot") if path else None

    def _warm(self, batch_sizes, aot) -> None:
        from repro import autotune, compiler
        from repro.kernels import ops
        cfg = self.model.cfg
        with obs.span("engine.warm", max_seq=self.max_seq,
                      batch_sizes=str(tuple(batch_sizes))):
            self.tuned = autotune.warm_for_model(
                cfg, max_seq=self.max_seq, cache=self.tuning_cache,
                batch_sizes=batch_sizes)
            aot_dir = self._aot_dir(aot)
            if aot_dir is None:
                return
            store = compiler.executor_cache()
            store.load_aot(aot_dir)  # a prior engine's programs: skip staging
            before = set(store.keys())
            with self._options_scope():
                for kernel, shape in autotune.model_kernel_shapes(
                        cfg, max_seq=self.max_seq, batch_sizes=batch_sizes):
                    try:
                        ops.warm_kernel(kernel, **shape)
                    except (ValueError, AssertionError):
                        continue  # shape with no valid strategy space
            # export only the keys THIS engine staged — a shared process
            # cache must not leak another model's programs into this AOT
            # directory
            store.save_aot(aot_dir, keys=set(store.keys()) - before)

    def _options_scope(self):
        """The compile-options scope this engine's kernels run under."""
        from repro import compiler
        if self.tuning_cache is None:
            return contextlib.nullcontext()
        # kv_layout is a strategy dimension: executors staged under this
        # scope carry it in their cache keys, like the mesh descriptor
        return compiler.options(tuning_cache=self.tuning_cache,
                                kv_layout=self.kv_layout)

    # -- shared pieces -------------------------------------------------------

    def _pad_prompt(self, prompt, to: int):
        """RIGHT-pad a (s[, K]) prompt with token 0 to length ``to``."""
        pad_n = to - prompt.shape[0]
        return jnp.pad(prompt, [(0, pad_n)] + [(0, 0)] * (prompt.ndim - 1))

    def _check_request(self, r: Request) -> None:
        need = int(r.prompt.shape[0]) + max(int(r.max_new_tokens), 0)
        if need > self.max_seq:
            raise ValueError(
                f"request needs {need} cache positions (prompt "
                f"{int(r.prompt.shape[0])} + {r.max_new_tokens} new) but "
                f"max_seq is {self.max_seq}")


# ---------------------------------------------------------------------------
# static batch (the oracle)
# ---------------------------------------------------------------------------

class BatchedEngine(_EngineBase):
    """Static-batch serving engine: prefill a batch of requests together,
    then decode lock-step in fused on-device chunks until every request has
    its ``max_new_tokens``.

    Each request is sampled with its *own* temperature/top-k (fixing the
    seed bug where the whole batch ran at ``requests[0].temperature``).
    Prompts are right-padded to the batch max; ``prefill(lengths=...)``
    gathers each row's real next-token logits, so padding never distorts
    positions or outputs.

    ``tuning_cache`` (a path or repro.autotune.TuningCache) pre-tunes the
    strategy autotuner for this model's kernel shapes at engine build time,
    stages the matching executors, and persists them AOT next to the cache;
    ``run`` scopes the ``repro.kernels.ops`` DPIA dispatch to that cache via
    ``repro.compiler.options(tuning_cache=...)`` — thread-local, per-engine.
    """

    def __init__(self, model: Model, params, max_seq: int = 512,
                 tuning_cache=None, batch_sizes=(1, 8), chunk: int = 8,
                 aot="auto", resilience: Optional[ResilienceConfig] = None):
        super().__init__(model, params, max_seq=max_seq, chunk=chunk,
                         tuning_cache=tuning_cache, batch_sizes=batch_sizes,
                         aot=aot, resilience=resilience)

    def run(self, requests: List[Request], key=None) -> List[List[int]]:
        with self._options_scope():
            return self._run(requests, key)

    def _run(self, requests: List[Request], key=None) -> List[List[int]]:
        cfg = self.model.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        for r in requests:
            self._check_request(r)
        b = len(requests)
        lengths = [int(r.prompt.shape[0]) for r in requests]
        s = max(lengths)
        tokens = jnp.stack([self._pad_prompt(r.prompt, s) for r in requests])
        logits, cache = self._prefill_call(tokens,
                                           jnp.asarray(lengths, jnp.int32))

        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        top_ks = jnp.asarray([getattr(r, "top_k", 0) or 0 for r in requests],
                             jnp.int32)
        keys = jnp.stack([jax.random.fold_in(key, i) for i in range(b)])
        keys, sub = _split_keys(keys)
        first = self._sample0(logits, sub, temps, top_ks)

        outs: List[List[int]] = [[] for _ in requests]
        remaining = [max(int(r.max_new_tokens), 0) for r in requests]
        first_host = np.asarray(first)
        for i in range(b):
            if remaining[i] > 0:
                outs[i].append(int(first_host[i]))
                remaining[i] -= 1

        pos = jnp.asarray(lengths, jnp.int32)
        tokens = first
        while any(n > 0 for n in remaining):
            live = ",".join(str(i) for i, n in enumerate(remaining) if n > 0)
            cache, tokens, pos, keys, toks, _bad = self._call_chunk(
                (self.params, cache, tokens, pos, keys, temps, top_ks, None),
                req_ids=live)
            block = np.asarray(toks)          # the chunk's one host sync
            for i in range(b):
                take = min(remaining[i], block.shape[1])
                outs[i].extend(int(t) for t in block[i, :take])
                remaining[i] -= take
        return outs


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

class ContinuousEngine(_EngineBase):
    """Continuous-batching engine over ``slots`` fixed device decode lanes.

    Requests are admitted into free slots and retired at chunk boundaries;
    prompts prefill right-padded to a power-of-two bucket
    (:func:`repro.serve.scheduler.seq_buckets`), each admission inserting its
    slot's cache into the donated engine cache.  The decode batch is always
    ``slots`` wide — free lanes decode padding that is simply discarded — so
    the jitted shape set is ``{(slots, chunk)} x {prefill buckets}`` and
    warm traffic never recompiles.

    Output is token-identical to :class:`BatchedEngine` on the same
    requests/key for every model family: per-request PRNG streams and
    padding-invariant prefill (attention by causal masking, ssm/hybrid by
    masked recurrent-state updates) make the tokens a function of the
    request alone.

    ``kv_layout`` makes KV memory a strategy dimension:

      * ``"dense"`` — one ``(slots, max_seq)`` cache (the PR 3 layout);
      * ``"paged"`` — KV lives in a pool of ``kv_blocks`` pages of
        ``block_size`` positions (:mod:`repro.serve.paged`); each slot maps
        into the pool through a ``(max_blocks,)`` block-table row, pages
        are reserved at admission and freed at retirement, and peak KV
        memory is the *pool* size — a policy, not ``slots * max_seq``;
      * ``"auto"`` — let the tuner's HBM roofline pick
        (:func:`repro.autotune.pick_kv_layout`).

    ``prefill_chunk`` caps the admission bucket set: prompts longer than it
    are CHUNKED — split across successive chunk boundaries, one prefill
    chunk each — so long prompts neither stall the other lanes for a whole
    prompt-length prefill nor add the largest power-of-two buckets to the
    jit shape set.
    """

    def __init__(self, model: Model, params, max_seq: int = 512,
                 slots: int = 4, chunk: int = 8, min_bucket: int = 16,
                 tuning_cache=None, batch_sizes=None, aot="auto",
                 kv_layout: str = "dense", block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 journal=None):
        if kv_layout == "auto":
            from repro import autotune
            kv_layout = autotune.pick_kv_layout(
                model.cfg, slots=slots, max_seq=max_seq,
                block_size=block_size, cache=tuning_cache)["layout"]
        if kv_layout == "paged":
            if max_seq % block_size != 0:
                raise ValueError(
                    f"paged layout needs block_size ({block_size}) to "
                    f"divide max_seq ({max_seq}) so the gathered view is "
                    f"shape-identical to the dense cache")
            self.block_size = block_size
            self.max_blocks = max_seq // block_size
            self.kv_blocks = int(kv_blocks or slots * self.max_blocks)
        self.prefill_chunk = prefill_chunk
        super().__init__(model, params, max_seq=max_seq, chunk=chunk,
                         tuning_cache=tuning_cache,
                         batch_sizes=batch_sizes or (1, slots), aot=aot,
                         kv_layout=kv_layout, resilience=resilience)
        self.slots = slots
        limit = (max_seq if prefill_chunk is None
                 else max(min(prefill_chunk, max_seq), min_bucket))
        self.buckets = seq_buckets(limit, min_bucket)
        self._insert = jax.jit(self._insert_slot, donate_argnums=(0,))
        model_ = self.model
        if kv_layout == "paged":
            def paged_prefill(first):
                def fn(params, tokens, kv, bt_row, state, start, lengths):
                    return model_.prefill_paged(params, tokens, kv, bt_row,
                                                state, start, lengths,
                                                first=first)
                return jax.jit(fn, donate_argnums=(2,))
            self._prefill_paged0 = paged_prefill(True)
            self._prefill_pagedC = paged_prefill(False)
        else:
            self._prefill_cont = jax.jit(
                lambda params, tokens, cache, start, lengths:
                model_.prefill(params, tokens, cache, start=start,
                               lengths=lengths, attend_cache=True),
                donate_argnums=(2,))
        # scheduler-state journal (a path, or a SchedulerJournal): every
        # submit/boundary-snapshot/terminal is appended checksummed, so a
        # killed engine's surviving requests replay to token identity
        # (repro.serve.domains.replay)
        if journal is None:
            self.journal = None
        elif isinstance(journal, str):
            from repro.serve.domains import SchedulerJournal
            self.journal = SchedulerJournal(journal)
        else:
            self.journal = journal
        self._reset_state()

    # -- device state --------------------------------------------------------

    def _init_device_state(self, park: bool = False) -> None:
        """(Re)build every device-resident buffer for the CURRENT
        ``kv_layout`` — factored out of :meth:`_reset_state` so the
        resilience paths (chunk-failure quarantine, paged->dense
        degradation) can rebuild device state without discarding the
        scheduler's pending queue or terminal records.  ``park=True``
        starts every lane at ``pos == max_seq`` (writes drop) — the safe
        posture when the rebuild happens mid-traffic."""
        b = self.slots
        if self.kv_layout == "paged":
            from repro.serve.paged import BlockPool
            self.cache = self.model.init_paged_cache(
                b, self.max_seq, n_blocks=self.kv_blocks,
                block_size=self.block_size)
            # all-sentinel tables: every lane's writes drop until admission
            self.block_tables = jnp.full((b, self.max_blocks),
                                         self.kv_blocks, jnp.int32)
            self.pool = BlockPool(self.kv_blocks, self.block_size)
        else:
            self.cache = self.model.init_cache(b, self.max_seq)
            self.block_tables = None
            self.pool = None
        self.tokens = jnp.zeros((b,), jnp.int32)
        self.pos = (jnp.full((b,), self.max_seq, jnp.int32) if park
                    else jnp.zeros((b,), jnp.int32))
        self.keys = jnp.stack(
            [jax.random.PRNGKey(i) for i in range(b)])
        self.temps = jnp.zeros((b,), jnp.float32)
        self.top_ks = jnp.zeros((b,), jnp.int32)
        # immutable zero staging template, reused by every paged admission
        # (never donated): no per-admission init dispatch; dense admissions
        # need no template at all — the fresh-cache prefill executable
        # builds its own zero cache
        self._zero_staging = (self.model.init_prefill_state(1)
                              if self.kv_layout == "paged" else None)
        self._staging: Dict[int, object] = {}
        self._admit_logits: Dict[int, jax.Array] = {}

    def _reset_state(self) -> None:
        self._init_device_state()
        self.sched = Scheduler(self.slots, pool=self.pool)
        self._requests: Dict[int, Request] = {}
        self._stream_keys: Dict[int, jax.Array] = {}
        self._next_id = 0
        self._run_key = jax.random.PRNGKey(0)

    @staticmethod
    def _insert_slot(big, small, slot):
        """Insert a batch=1 cache into the engine cache at ``slot``.

        Works on every cache pytree; per leaf the batch axis comes from
        :func:`_slot_axis`."""
        def ins(bl, sl):
            axis = _slot_axis(bl, sl)
            if axis is None:          # slots == 1: the slot IS the cache
                return sl.astype(bl.dtype)
            start = [jnp.int32(0)] * bl.ndim
            start[axis] = jnp.asarray(slot, jnp.int32)
            return jax.lax.dynamic_update_slice(
                bl, sl.astype(bl.dtype), tuple(start))
        return jax.tree_util.tree_map(ins, big, small)

    # -- API -----------------------------------------------------------------

    def submit(self, request: Request, stream: Optional[int] = None) -> int:
        """Queue a request; returns its id.

        ``stream`` is the request's PRNG stream index: its tokens are
        sampled from ``fold_in(run_key, stream)`` advanced once per token.
        ``run`` passes each request's position in its batch — the same
        stream the static oracle uses — so outputs stay token-identical
        across engine reuse and resubmission.  Streaming callers that omit
        it get the (unique, monotonically increasing) request id."""
        self._check_request(request)
        rid = self._next_id
        self._next_id += 1
        self._requests[rid] = request
        self._stream_keys[rid] = jax.random.fold_in(
            self._run_key, rid if stream is None else stream)
        self.sched.submit(rid, int(request.prompt.shape[0]),
                          max(int(request.max_new_tokens), 0),
                          deadline_s=request.deadline_s,
                          ttft_deadline_s=request.ttft_deadline_s)
        if self.journal is not None:
            self.journal.record_submit(
                rid, request.prompt,
                max_new=max(int(request.max_new_tokens), 0),
                temperature=request.temperature,
                top_k=getattr(request, "top_k", 0) or 0,
                stream=rid if stream is None else stream,
                deadline_s=request.deadline_s,
                ttft_deadline_s=request.ttft_deadline_s)
        return rid

    def take_output(self, rid: int) -> List[int]:
        """Collect (and release) a finished request's tokens.

        Completed requests hold their outputs until collected; collecting
        prunes every per-request record, so a long-running engine's memory
        is bounded by in-flight + uncollected work, not by total traffic."""
        return self.sched.pop_output(rid)

    def take_result(self, rid: int) -> RequestResult:
        """Collect (and release) a terminal request's full outcome —
        tokens + terminal state (``ok|timeout|cancelled|failed``) + reason
        (:class:`repro.serve.resilience.RequestResult`)."""
        return self.sched.pop_result(rid)

    def cancel(self, rid: int, reason: str = "cancelled by caller") -> None:
        """Cancel a pending or in-flight request at the current boundary.

        Partial tokens survive into the terminal result (state
        ``cancelled``); the device lane is parked and — paged — its pages
        return to the pool immediately.  Idempotent once terminal;
        KeyError for ids never submitted."""
        slot = self.sched.cancel(rid, reason)
        if slot is not None:
            self._evict_slot(slot)
        if self.journal is not None and rid in self.sched.done:
            # cancellation happens between boundaries: journal the final
            # snapshot + terminal now, not at the next step_chunk (there
            # may never be one)
            toks = self.sched.outputs.get(rid)
            if toks:
                self.journal.record_progress(rid, toks)
            state, why = self.sched.done[rid]
            self.journal.record_terminal(rid, state, why)
        self._requests.pop(rid, None)
        self._stream_keys.pop(rid, None)

    def run(self, requests: List[Request], key=None) -> List[List[int]]:
        """Serve a closed set of requests to completion (convenience driver
        for the streaming ``submit`` + ``step_chunk`` API); returns outputs
        in submission order."""
        with self._options_scope():
            self._run_key = key if key is not None else jax.random.PRNGKey(0)
            rids = [self.submit(r, stream=i)
                    for i, r in enumerate(requests)]
            while not self.sched.idle:
                self.step_chunk()
            if self._jit_baseline is None:
                # first completed batch = warm: later jit-cache growth is a
                # recompile the detector flags
                self.mark_warm()
            return [self.take_output(rid) for rid in rids]

    def _check_request(self, r: Request) -> None:
        super()._check_request(r)
        if self.kv_layout == "paged":
            need = self.pool.blocks_for(
                int(r.prompt.shape[0]) + max(int(r.max_new_tokens), 0))
            if need > self.pool.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool only has "
                    f"{self.pool.n_blocks} (block_size "
                    f"{self.pool.block_size}); raise kv_blocks")

    # -- the chunk-boundary loop --------------------------------------------

    def step_chunk(self) -> List[int]:
        """Admit pending requests, advance in-flight prompt prefills by one
        chunk each, then decode one fused chunk.

        Returns the request ids retired at this boundary."""
        try:
            with obs.span("serve.step_chunk"):
                finished = self._step_chunk_inner()
        except Exception as e:
            # the resilience ladder is exhausted (or disabled) and the
            # exception is about to leave the engine: capture the black box
            obs.flight_dump("unhandled_exception",
                            error=f"{type(e).__name__}: {e}")
            raise
        if self.journal is not None:
            self._journal_sync(finished)
        self._check_recompiles()
        return finished

    def _journal_sync(self, finished: List[int]) -> None:
        """Journal this boundary: an emitted-token snapshot per request
        with new tokens, then a terminal record per retirement.  Chunk
        boundaries are the journal's granularity — inside a chunk the host
        observes nothing, so there is nothing finer to record."""
        for rid, toks in self.sched.outputs.items():
            self.journal.record_progress(rid, toks)
        for rid in finished:
            state_reason = self.sched.done.get(rid)
            if state_reason is not None:
                self.journal.record_terminal(rid, *state_reason)

    def _domain_sweep(self) -> None:
        """Failure-domain hook, run first at every chunk boundary —
        :class:`ShardedEngine` polls its host groups here; the unsharded
        engines have no domains to lose."""

    def _step_chunk_inner(self) -> List[int]:
        finished: List[int] = []
        # failure domains first: a lost host must be evacuated + the mesh
        # shrunk before this boundary admits into (or decodes on) it
        self._domain_sweep()
        # deadline sweep next: an expired request must not consume the
        # boundary's admission/prefill/decode work
        for slot, rid in self.sched.check_deadlines():
            if slot is not None:
                self._evict_slot(slot)
            finished.append(rid)
        # pool integrity: a corrupt block pool means tables may alias pages
        # across requests — degrade paged -> dense instead of decoding
        # through a damaged mapping
        if self.pool is not None and self.resilience.pool_check:
            if faults.should_fire("serve.pool_corrupt") is not None:
                faults.corrupt_pool(self.pool)
            problems = self.pool.validate()
            if problems:
                finished.extend(
                    self._degrade_to_dense("; ".join(problems)))
        self.sched.admissions()               # reserve slots (and KV blocks)
        if self.pool is not None:
            obs.gauge("serve.kv_pool.used_blocks").set(self.pool.used_blocks)
            obs.gauge("serve.kv_pool.free_blocks").set(self.pool.free_blocks)
        for slot, rid in self.sched.prefilling():
            if self._prefill_advance(slot, rid):      # one chunk per boundary
                if self._finish_admit(slot, rid):
                    finished.append(rid)
        if self.sched.busy_slots():
            self._before_chunk()              # hook: ShardedEngine pins here
            req_ids = ",".join(str(s.req_id) for s in self.sched.slots
                               if not s.free)
            t0 = time.perf_counter()
            try:
                with obs.span("serve.decode_chunk", chunk=self.chunk,
                              req_ids=req_ids):
                    (self.cache, self.tokens, self.pos, self.keys, toks,
                     bad) = self._call_chunk(
                        (self.params, self.cache, self.tokens, self.pos,
                         self.keys, self.temps, self.top_ks,
                         self.block_tables), req_ids=req_ids)
                    block = np.asarray(toks)  # the chunk's one host sync
                    bad_host = np.asarray(bad)
            except Exception as e:
                if not self.resilience.quarantine_on_chunk_failure:
                    raise
                finished.extend(self._quarantine_chunk_failure(e))
            else:
                # per-chunk wall time, measured at the boundary the host
                # already pays: the latency histogram + the drift auditor's
                # baseline-relative watch on this engine shape
                dt = time.perf_counter() - t0
                obs.histogram("serve.chunk_s").observe(dt)
                obs.drift_observe(
                    f"serve|decode_chunk|slots={self.slots}"
                    f"|chunk={self.chunk}", dt)
                slot_of = {s.req_id: i
                           for i, s in enumerate(self.sched.slots)
                           if not s.free}
                if self.resilience.nan_guard and bad_host.any():
                    finished.extend(self._quarantine_nan_rows(bad_host))
                retired = self.sched.record_chunk(block)
                for rid in retired:
                    self._park_lane(slot_of[rid])
                finished.extend(retired)
        for rid in finished:                  # release prompts/keys at retire
            self._requests.pop(rid, None)
            self._stream_keys.pop(rid, None)
        return finished

    # -- quarantine / degradation paths --------------------------------------

    def _evict_slot(self, slot: int) -> None:
        """Neutralise a lane whose request terminated outside the normal
        retire path (cancel/timeout/failure): park it and drop any
        admission scratch it was holding."""
        self._park_lane(slot)
        self._staging.pop(slot, None)
        self._admit_logits.pop(slot, None)

    def _quarantine_nan_rows(self, bad_host) -> List[int]:
        """Quarantine slots whose decode chunk produced non-finite logits:
        the request fails terminally, the lane is parked, and — paged —
        its pages are scrubbed before returning to the pool (a reissued
        page must never leak NaNs into the next occupant).  Rows the
        guard flagged while free/prefilling are stale lanes decoding
        padding; they are ignored."""
        out: List[int] = []
        for i, s in enumerate(self.sched.slots):
            if not bad_host[i] or s.free or s.prefilling:
                continue
            rid = s.req_id
            self._n_nan_quarantines += 1
            obs.counter("serve.nan_quarantines").inc()
            obs.event("serve.nan_quarantine", req_id=rid, slot=i)
            log.warning("request %d produced non-finite logits in slot %d "
                        "— quarantined (co-batched requests unaffected)",
                        rid, i)
            if self.kv_layout == "paged":
                self._scrub_pages(self.pool.owned(i))
            self.sched.fail(rid, "non-finite logits in decode chunk")
            self._evict_slot(i)
            out.append(rid)
        return out

    def _quarantine_chunk_failure(self, e: Exception) -> List[int]:
        """The decode chunk failed past the retry budget (or consumed its
        donated buffers): fail every in-flight request and rebuild the
        device state for the current layout.  Pending requests survive in
        the queue and admit into the rebuilt state."""
        self._n_chunk_quarantines += 1
        obs.counter("serve.chunk_quarantines").inc()
        obs.event("serve.chunk_quarantine",
                  error=f"{type(e).__name__}: {e}")
        log.warning("decode chunk failed past the retry budget (%s: %s); "
                    "failing in-flight requests and rebuilding device "
                    "state", type(e).__name__, e)
        failed = self._fail_in_flight(
            f"decode chunk failed: {type(e).__name__}: {e}")
        self._init_device_state(park=True)
        self.sched.pool = self.pool
        self._jit_baseline = None   # rebuilt buffers may re-lower; re-warm
        return failed

    def _fail_in_flight(self, reason: str) -> List[int]:
        """Fail every admitted request (used when shared device state is
        suspect); returns their ids.  Queued requests are untouched."""
        failed: List[int] = []
        for i, s in enumerate(self.sched.slots):
            if s.free:
                continue
            rid = s.req_id
            self.sched.fail(rid, reason)
            self._evict_slot(i)
            self._requests.pop(rid, None)
            self._stream_keys.pop(rid, None)
            failed.append(rid)
        return failed

    def _degrade_to_dense(self, reason: str) -> List[int]:
        """The paged->dense rung of the degradation ladder: the block pool
        failed validation, so the engine abandons the paged layout rather
        than write through a damaged page mapping.  In-flight requests
        fail (their pages are suspect); pending requests admit into the
        rebuilt dense cache; the switch is recorded as an obs provenance
        Decision with origin ``degraded(paged->dense)``."""
        self._n_degradations += 1
        log.warning("KV block pool failed validation (%s); degrading "
                    "kv_layout paged -> dense", reason)
        failed = self._fail_in_flight(f"kv pool corrupt: {reason}")
        record_degradation(
            "kv_layout", "serve.engine",
            key=f"serve|kv_layout|slots={self.slots}|max_seq={self.max_seq}",
            frm="paged", to="dense", layout="dense", note=reason)
        self.kv_layout = "dense"
        if not hasattr(self, "_prefill_cont"):
            # the dense continuation prefill only exists on engines built
            # dense; a degraded engine needs it from here on
            model_ = self.model
            self._prefill_cont = jax.jit(
                lambda params, tokens, cache, start, lengths:
                model_.prefill(params, tokens, cache, start=start,
                               lengths=lengths, attend_cache=True),
                donate_argnums=(2,))
        self._init_device_state(park=True)
        self.sched.pool = None
        self._jit_baseline = None   # dense chunk/prefill signatures are new
        return failed

    def _scrub_pages(self, blocks: List[int]) -> None:
        """Zero the KV pool contents of ``blocks`` before they return to
        the free list.  Needed because attention's validity masking keeps
        *weights* at zero but ``0 * NaN`` is NaN — a poisoned page handed
        to the next request would re-poison it."""
        if not blocks:
            return
        idx = jnp.asarray(sorted(blocks), jnp.int32)
        kv, state = self.model.split_paged_cache(self.cache)
        if kv is None:
            return

        def scrub(leaf):
            # pool leaves are (layers/groups, n_blocks, block_size, ...)
            if (leaf.ndim >= 3 and leaf.shape[1] == self.kv_blocks
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                return leaf.at[:, idx].set(0)
            return leaf
        kv = jax.tree_util.tree_map(scrub, kv)
        self.cache = self.model.merge_paged_cache(kv, state)

    def _poison_slot_cache(self, slot: int) -> None:
        """Deterministic damage for the ``serve.nan_decode`` drill: fill
        the slot's cached state with NaN so its next decode chunk trips
        the in-chunk NaN guard — exactly the flaky-HBM poison model."""
        if self.kv_layout == "paged":
            blocks = self.pool.owned(slot)
            if blocks:
                idx = jnp.asarray(sorted(blocks), jnp.int32)
                kv, state = self.model.split_paged_cache(self.cache)
                if kv is not None:
                    def poison(leaf):
                        if (leaf.ndim >= 3
                                and leaf.shape[1] == self.kv_blocks
                                and jnp.issubdtype(leaf.dtype,
                                                   jnp.floating)):
                            return leaf.at[:, idx].set(jnp.nan)
                        return leaf
                    kv = jax.tree_util.tree_map(poison, kv)
                    self.cache = self.model.merge_paged_cache(kv, state)
            return
        small = self.model.init_cache(1, self.max_seq)

        def poison(bl, sl):
            if not jnp.issubdtype(bl.dtype, jnp.floating):
                return bl
            axis = _slot_axis(bl, sl)
            if axis is None:
                return jnp.full_like(bl, jnp.nan)
            idx = [slice(None)] * bl.ndim
            idx[axis] = slot
            return bl.at[tuple(idx)].set(jnp.nan)
        self.cache = jax.tree_util.tree_map(poison, self.cache, small)

    def _before_chunk(self) -> None:
        """Hook between boundary admissions and the fused decode chunk —
        :class:`ShardedEngine` re-pins shardings here so admission-time
        host updates can never hand the chunk a new jit signature."""

    def stats(self) -> dict:
        out = super().stats()
        out["scheduler"] = self.sched.stats()
        if self.pool is not None:
            out["kv_pool"] = self.pool.stats()
        return out

    def _park_lane(self, slot: int) -> None:
        """Neutralise a freed lane: position past max_seq so its decode
        writes drop.  Load-bearing for the paged layout — the slot's pages
        go back to the pool at retirement and may be re-issued, so the
        lane must never write through its stale block table."""
        self.pos = self.pos.at[slot].set(self.max_seq)

    def _prefill_advance(self, slot: int, rid: int) -> bool:
        """Prefill the next prompt chunk of ``rid`` into ``slot``; True
        when the whole prompt is in the cache.

        Chunks are ``buckets[-1]`` tokens (the prefill-chunk cap); the tail
        is padded to the smallest bucket that fits, so the executable set
        stays one-per-bucket whatever the prompt length."""
        r = self._requests[rid]
        plen = int(r.prompt.shape[0])
        start = self.sched.slots[slot].prefill_pos
        if start == 0:
            self._begin_admit(slot)
        take = min(plen - start, self.buckets[-1])
        bucket = pick_bucket(take, self.buckets)
        with obs.span("serve.prefill_chunk", slot=slot, req_id=rid,
                      bucket=bucket, start=start):
            return self._prefill_advance_inner(slot, r, plen, start, take,
                                               bucket)

    def _prefill_advance_inner(self, slot, r, plen, start, take,
                               bucket) -> bool:
        tokens = self._pad_prompt(r.prompt[start:start + take], bucket)[None]
        lengths = jnp.asarray([take], jnp.int32)
        if self.kv_layout == "paged":
            kv, _ = self.model.split_paged_cache(self.cache)
            args = (self.params, tokens, kv, self.block_tables[slot],
                    self._staging[slot], jnp.int32(start), lengths)
            fn = self._prefill_paged0 if start == 0 else self._prefill_pagedC
            # same AOT-executable discipline as the dense admission path:
            # one compiled program per (bucket, first-chunk) signature.
            # No jit fallback here: the pools are DONATED, so re-running
            # after a partial failure would read deleted buffers — a
            # mismatch must surface, not silently slow-path
            exe_key = (tokens.shape, start == 0)
            exe = self._prefill_exes.get(exe_key)
            if exe is None:
                with obs.span("serve.prefill_compile",
                              shape=str(tokens.shape), first=start == 0):
                    exe = fn.lower(*args).compile()
                self._prefill_exes[exe_key] = exe
            logits, kv, staging = exe(*args)
            _, slot_state = self.model.split_paged_cache(self.cache)
            self.cache = self.model.merge_paged_cache(kv, slot_state)
            self._staging[slot] = staging
        else:
            if start == 0:
                logits, cache1 = self._prefill_call(tokens, lengths)
            else:
                logits, cache1 = self._prefill_cont(
                    self.params, tokens, self._staging[slot],
                    jnp.int32(start), lengths)
            self._staging[slot] = cache1
        rid = self.sched.slots[slot].req_id
        if (start + take >= plen
                and faults.should_fire("serve.nan_prefill",
                                       req_id=rid) is not None):
            # poison drill: the request's admission logits read as NaN
            logits = jnp.full_like(logits, jnp.nan)
        self._admit_logits[slot] = logits
        self.sched.prefill_advance(slot, take)
        return start + take >= plen

    def _begin_admit(self, slot: int) -> None:
        """Set up the slot for its (possibly multi-chunk) prompt prefill."""
        if self.kv_layout == "paged":
            from repro.serve.paged import table_row
            row = table_row(self.pool.owned(slot), self.max_blocks,
                            self.kv_blocks)
            self.block_tables = self.block_tables.at[slot].set(
                jnp.asarray(row, jnp.int32))
            self._staging[slot] = self._zero_staging
        self._park_lane(slot)  # mid-prefill decode writes must drop

    def _finish_admit(self, slot: int, rid: int) -> bool:
        """The prompt is fully cached: install the slot's decode state and
        sample the first token; True if it retired immediately."""
        r = self._requests[rid]
        length = int(r.prompt.shape[0])
        logits = self._admit_logits.pop(slot)
        staging = self._staging.pop(slot)
        if (self.resilience.nan_guard
                and not np.isfinite(np.asarray(logits)).all()):
            # poisoned prompt: quarantine at admission, before the slot's
            # state ever joins the shared decode batch
            self._n_nan_quarantines += 1
            obs.counter("serve.nan_quarantines").inc()
            obs.event("serve.nan_quarantine", req_id=rid, slot=slot,
                      where="prefill")
            log.warning("request %d produced non-finite prefill logits — "
                        "quarantined at admission", rid)
            if self.kv_layout == "paged":
                self._scrub_pages(self.pool.owned(slot))
            self.sched.fail(rid, "non-finite prefill logits")
            self._evict_slot(slot)
            return True
        if self.kv_layout == "paged":
            if staging is not None:           # recurrent state -> its slot
                kv, slot_state = self.model.split_paged_cache(self.cache)
                slot_state = self._insert(slot_state, staging, slot)
                self.cache = self.model.merge_paged_cache(kv, slot_state)
        else:
            self.cache = self._insert(self.cache, staging, slot)

        rkey = self._stream_keys[rid]
        carry, sub = _split_keys(rkey[None])
        temp = jnp.asarray([r.temperature], jnp.float32)
        top_k = jnp.asarray([getattr(r, "top_k", 0) or 0], jnp.int32)
        first = self._sample0(logits, sub, temp, top_k)

        self.tokens = self.tokens.at[slot].set(first[0])
        self.pos = self.pos.at[slot].set(length)
        self.keys = self.keys.at[slot].set(carry[0])
        self.temps = self.temps.at[slot].set(temp[0])
        self.top_ks = self.top_ks.at[slot].set(top_k[0])
        # one tiny host sync per ADMISSION (not per token): the first token
        done = self.sched.record_first(slot, int(np.asarray(first)[0]))
        if done:
            self._park_lane(slot)
        elif faults.should_fire("serve.nan_decode", req_id=rid) is not None:
            # poison drill: NaN the slot's cached state so the next decode
            # chunk trips the in-chunk NaN guard for this row
            self._poison_slot_cache(slot)
        return done


# ---------------------------------------------------------------------------
# sharded continuous batching (data-parallel slots over a mesh axis)
# ---------------------------------------------------------------------------

class ShardedEngine(ContinuousEngine):
    """Continuous batching with the slot axis sharded over a named mesh axis
    (``data`` by default) — the multi-host serving driver from the ROADMAP.

    The decode state (KV cache, token/pos/key/temp buffers) lives sharded
    over the mesh via ``NamedSharding``; params are replicated once at
    build time.  The fused decode chunk is the *same* jitted function as
    :class:`ContinuousEngine` — GSPMD partitions it over the batch axis, so
    each device decodes ``slots / mesh.shape[axis]`` lanes and no collective
    appears in the hot loop (per-request work never crosses shards).  That
    also makes the engine token-identical to the unsharded
    :class:`ContinuousEngine`: the per-row computation is bitwise the same,
    only its placement changes — strategy preservation at the serving level.

    Admission prefill still runs batch=1 (replicated) and inserts the slot
    cache into the sharded engine cache; shapes and shardings are closed
    after one pass over the prompt buckets, so warm traffic never
    recompiles (``decode_cache_misses()`` stays at 1).

    ``hosts`` turns on the failure-domain layer
    (:class:`repro.serve.domains.FailureDomains`): the mesh's devices
    partition into that many contiguous host groups (``hosts="auto"``
    groups by ``device.process_index`` on a real multi-host mesh), and at
    every chunk boundary the engine polls for a lost or straggling host
    (the ``mesh.host_lost`` / ``mesh.host_slow`` / ``collective.timeout``
    fault sites stand in for heartbeats in drills).  On a loss the dead
    host's slots are **evacuated** back to the queue front, the engine
    re-places its state on the shrunk mesh (lost rows zeroed — their HBM
    is gone), the autotuner re-ranks mesh candidates for the new
    descriptor, and the shrink is recorded as provenance origin
    ``degraded(mesh(data=8)->mesh(data=4))`` plus one flight dump with
    reason ``host_lost``.  Survivors keep their tokens; evacuees re-decode
    from their prompts bit-identically.  The shrink recompiles the chunk
    once (new shardings) — the warm baseline resets, so the recompile
    detector stays meaningful afterwards.
    """

    def __init__(self, model: Model, params, max_seq: int = 512,
                 slots: int = 8, chunk: int = 8, min_bucket: int = 16,
                 tuning_cache=None, batch_sizes=None, aot="auto",
                 mesh=None, mesh_axis: str = "data",
                 kv_layout: str = "dense", block_size: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 journal=None, hosts=None, host_slow_threshold: int = 3):
        from repro.sharding import ctx
        mesh = mesh if mesh is not None else ctx.get_mesh()
        if mesh is None:
            raise ValueError(
                "ShardedEngine needs a mesh: pass mesh=... or set the "
                "process mesh context (repro.sharding.ctx.set_mesh)")
        if mesh_axis not in mesh.shape:
            raise ValueError(f"mesh axis {mesh_axis!r} not in mesh axes "
                             f"{list(mesh.shape)}")
        n_shards = int(mesh.shape[mesh_axis])
        if slots % n_shards != 0:
            raise ValueError(f"slots ({slots}) must be divisible by mesh "
                             f"axis {mesh_axis!r} of size {n_shards}")
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.domains = None
        if hosts is not None:
            from repro.serve.domains import FailureDomains
            self.domains = FailureDomains(
                mesh, axis=mesh_axis,
                hosts=None if hosts == "auto" else int(hosts),
                slow_threshold=host_slow_threshold)
        self._n_host_losses = 0
        super().__init__(model, params, max_seq=max_seq, slots=slots,
                         chunk=chunk, min_bucket=min_bucket,
                         tuning_cache=tuning_cache, batch_sizes=batch_sizes,
                         aot=aot, kv_layout=kv_layout, block_size=block_size,
                         kv_blocks=kv_blocks, prefill_chunk=prefill_chunk,
                         resilience=resilience, journal=journal)

    # -- sharded device state ------------------------------------------------

    def _shardings(self):
        from jax.sharding import NamedSharding, PartitionSpec as PS
        rep = NamedSharding(self.mesh, PS())
        row = NamedSharding(self.mesh, PS(self.mesh_axis))
        return rep, row

    def _cache_sharding(self, big, small):
        """Per-leaf NamedSharding: the slot axis (:func:`_slot_axis`, the
        same detection ``_insert_slot`` uses) sharded over the mesh axis,
        all else replicated."""
        from jax.sharding import NamedSharding, PartitionSpec as PS
        axis = _slot_axis(big, small)
        if axis is None:
            return NamedSharding(self.mesh, PS())
        return NamedSharding(
            self.mesh, PS(*([None] * axis + [self.mesh_axis])))

    def _install_shardings(self) -> None:
        """(Re)compute ``_cache_shardings`` against the CURRENT mesh and
        replicate the params onto it — shared by the initial build and by
        the failure-domain re-placement after a mesh shrink."""
        rep, row = self._shardings()
        self.params = jax.device_put(self.params, rep)   # replicate weights
        if self.kv_layout == "paged":
            # page pools have no slot axis: they live REPLICATED (every
            # device holds the pool; slots map into it via their tables),
            # only the recurrent slot state shards over the mesh axis
            kv, st = self.model.split_paged_cache(self.cache)
            kv_sh = (None if kv is None
                     else jax.tree_util.tree_map(lambda _: rep, kv))
            st_sh = None
            if st is not None:
                small = self.model.init_prefill_state(1)
                st_sh = jax.tree_util.tree_map(
                    lambda bl, sl: self._cache_sharding(bl, sl), st, small)
            self._cache_shardings = self.model.merge_paged_cache(kv_sh,
                                                                 st_sh)
        else:
            small = self.model.init_cache(1, self.max_seq)
            self._cache_shardings = jax.tree_util.tree_map(
                lambda bl, sl: self._cache_sharding(bl, sl),
                self.cache, small)

    def _init_device_state(self, park: bool = False) -> None:
        # the resilience rebuild paths call this too (chunk-failure
        # quarantine, paged->dense degradation): the rebuilt state must
        # come back SHARDED, or the next chunk would recompile unsharded
        super()._init_device_state(park)
        self._install_shardings()
        self.cache = jax.tree_util.tree_map(
            jax.device_put, self.cache, self._cache_shardings)
        self._pin_slot_state()

    def _pin_slot_state(self) -> None:
        """Keep the per-slot vectors on their canonical sharding.  A no-op
        (no transfer) when already placed — called at chunk boundaries so
        host-side ``.at[slot].set`` admissions can never drift the decode
        chunk onto a new sharding signature (which would recompile)."""
        rep, row = self._shardings()
        self.tokens = jax.device_put(self.tokens, row)
        self.pos = jax.device_put(self.pos, row)
        self.keys = jax.device_put(self.keys, row)
        self.temps = jax.device_put(self.temps, row)
        self.top_ks = jax.device_put(self.top_ks, row)
        # the cache too: admission inserts (whose staging came from the
        # AOT prefill executable) can leave GSPMD free to re-place the
        # merged cache; re-pinning keeps the decode chunk on one signature
        self.cache = jax.tree_util.tree_map(
            jax.device_put, self.cache, self._cache_shardings)
        if self.block_tables is not None:
            # tables index a replicated pool: keep them replicated too
            self.block_tables = jax.device_put(self.block_tables, rep)

    def _before_chunk(self) -> None:
        self._pin_slot_state()

    def step_chunk(self):
        out = super().step_chunk()
        self._pin_slot_state()
        return out

    # -- failure domains: detection -> evacuation -> shrink ------------------

    def _domain_sweep(self) -> None:
        if self.domains is None:
            return
        ev = self.domains.poll()
        if ev is None:
            return
        if ev.kind == "slow":
            obs.counter("serve.host_slow").inc()
            obs.event("serve.host_slow", host=ev.host,
                      strikes=self.domains.slow_count(ev.host),
                      cause=ev.cause)
            log.warning("%s", ev.cause)
            if ev.delay_s:
                time.sleep(ev.delay_s)   # the drill's injected stall
            return
        self._handle_host_loss(ev.host, ev.cause)

    def _handle_host_loss(self, host: int, cause: str) -> None:
        """Survive the loss of ``host``: evacuate its slots back to the
        queue front, shrink the mesh onto the survivors, re-place device
        state, re-tune for the new descriptor, and record the shrink as a
        degradation (one provenance origin + one ``host_lost`` flight
        dump per event)."""
        from repro.mesh.strategy import descriptor
        dom = self.domains
        frm = descriptor(self.mesh)
        lost_slots = set(dom.slots_of_host(host, self.slots))
        # the slot axis must divide the surviving positions; when it would
        # not (uneven host sizes), drop further hosts from the tail until
        # it does — a smaller servable mesh beats an unshardable one.
        # With the usual hosts-divides-slots layouts this never iterates.
        drop = [host]

        def _size_after() -> int:
            return sum(len(g) for h, g in enumerate(dom.groups)
                       if dom.alive[h] and h not in drop)

        while _size_after() and self.slots % _size_after() != 0:
            extra = max(h for h in dom.alive_hosts() if h not in drop)
            drop.append(extra)
            lost_slots |= set(dom.slots_of_host(extra, self.slots))
        self._n_host_losses += 1
        obs.counter("serve.host_losses").inc()
        log.warning("host %d lost (%s): evacuating slots %s and shrinking "
                    "the mesh", host, cause, sorted(lost_slots))
        evacuated: List[int] = []
        # descending slot order + appendleft => evacuees rejoin the queue
        # front in ascending slot order (FIFO among themselves, ahead of
        # never-admitted requests)
        for slot in sorted(lost_slots, reverse=True):
            rid = self.sched.evacuate(slot, reason=cause)
            if rid is None:
                continue
            self._evict_slot(slot)
            evacuated.append(rid)
            if self.journal is not None:
                self.journal.record_evacuate(rid, host)
        for h in drop:
            dom.mark_lost(h)    # raises when no host survives: unservable
        new_mesh = dom.shrunk_mesh()
        to = descriptor(new_mesh)
        obs.event("serve.host_lost", host=host, cause=cause, frm=frm,
                  to=to, evacuated=",".join(str(r) for r in evacuated),
                  dropped_hosts=",".join(str(h) for h in drop))
        self._remesh(new_mesh, sorted(lost_slots))
        record_degradation(
            "mesh", "serve.engine",
            key=f"serve|mesh|slots={self.slots}|axis={self.mesh_axis}",
            frm=f"mesh({frm})", to=f"mesh({to})", note=cause,
            params={"mesh_axis": self.mesh_axis, "hosts": dom.n_hosts,
                    "alive": len(dom.alive_hosts())},
            dump=False)
        # exactly ONE flight dump per host-loss event, reason host_lost
        # (record_degradation's generic dump is suppressed above)
        obs.flight_dump("host_lost", host=host, cause=cause, frm=frm,
                        to=to, evacuated=",".join(str(r) for r in evacuated))
        if self.journal is not None:
            self.journal.record_shrink(frm, to, host, cause)
        self._retune_mesh(to)

    def _remesh(self, new_mesh, lost_slots: List[int]) -> None:
        """Re-place every device buffer onto ``new_mesh``, preserving the
        surviving slots' rows and zeroing the lost ones (the dead host's
        HBM is gone — nothing may depend on it, and survivors provably do
        not: their rows round-trip through the host copy bit-identical)."""
        with obs.span("serve.remesh", frm=str(self.mesh.shape),
                      to=str(new_mesh.shape)):
            cache_host = jax.device_get(self.cache)
            cache_host = self._zero_slot_rows(cache_host, lost_slots)
            (self.tokens, self.pos, self.keys, self.temps,
             self.top_ks) = jax.device_get(
                (self.tokens, self.pos, self.keys, self.temps, self.top_ks))
            if self.block_tables is not None:
                self.block_tables = jax.device_get(self.block_tables)
            self.mesh = new_mesh
            self._install_shardings()
            self.cache = jax.tree_util.tree_map(
                jax.device_put, cache_host, self._cache_shardings)
            self._pin_slot_state()
        # stale strategy artefacts: the old-mesh AOT prefill executables
        # would reject the re-placed params (jit re-lowers once, fine);
        # the chunk recompiles once for the new shardings — reset the warm
        # baseline so that expected compile is not flagged as drift
        self._prefill_exes.clear()
        self._jit_baseline = None

    def _zero_slot_rows(self, cache_host, lost_slots: List[int]):
        """Zero the lost slots' rows of a HOST-side cache pytree (numpy):
        the simulation of their HBM dying with the host."""
        if not lost_slots:
            return cache_host
        idx = np.asarray(sorted(lost_slots), dtype=np.int64)

        def zero(tree, small):
            def z(bl, sl):
                axis = _slot_axis(bl, sl)
                if axis is None:
                    return bl
                bl = np.array(bl)
                sli = [slice(None)] * bl.ndim
                sli[axis] = idx
                bl[tuple(sli)] = 0
                return bl
            return jax.tree_util.tree_map(z, tree, small)

        if self.kv_layout == "paged":
            kv, st = self.model.split_paged_cache(cache_host)
            if st is not None:
                st = zero(st, self.model.init_prefill_state(1))
            return self.model.merge_paged_cache(kv, st)
        return zero(cache_host, self.model.init_cache(1, self.max_seq))

    def _retune_mesh(self, desc: str) -> None:
        """Re-rank mesh-axis candidates for the shrunk descriptor (cache
        keys carry it, so this fills the cold rows the new mesh would
        otherwise tune one by one at dispatch)."""
        if self.tuning_cache is None:
            return
        from repro.serve.domains import retune_for_mesh
        try:
            retune_for_mesh(self.model.cfg, desc, max_seq=self.max_seq,
                            batch_sizes=(1, self.slots),
                            cache=self.tuning_cache)
        except Exception:
            log.debug("mesh retune for %s skipped", desc, exc_info=True)

    def stats(self) -> dict:
        out = super().stats()
        from repro.mesh.strategy import descriptor
        out["mesh"] = {"axis": self.mesh_axis,
                       "shards": int(self.mesh.shape[self.mesh_axis]),
                       "devices": int(self.mesh.devices.size),
                       "descriptor": descriptor(self.mesh)}
        if self.domains is not None:
            out["mesh"]["hosts"] = self.domains.describe()
        out["resilience"]["host_losses"] = self._n_host_losses
        return out
