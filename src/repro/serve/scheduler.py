"""Continuous-batching scheduler: host-side bookkeeping for the serving
engine's fixed device slots.

The engine owns a device-resident batch of ``n_slots`` decode lanes; this
module owns the *policy*: which pending request enters which free slot, which
sequence-length bucket its prompt is padded to, how far its prompt has been
prefilled (chunked prefill spreads a long prompt over successive chunk
boundaries), and when a slot retires.  All decisions happen at chunk
boundaries — inside a chunk the device runs a fused ``lax.scan`` with no
host involvement, so the scheduler never sees (or blocks) individual tokens.

Shape discipline: prompts are RIGHT-padded to a bucket from
:func:`seq_buckets` and the decode batch is always exactly ``n_slots`` wide,
so the jitted prefill/decode functions see a small closed set of shapes —
after one pass over the buckets there are zero recompiles, whatever traffic
arrives.  With chunked prefill the bucket set is capped at the engine's
prefill-chunk size, so long prompts never add the largest power-of-two
shapes to the jit set.

With a paged KV cache (:mod:`repro.serve.paged`), admission also *reserves*
blocks: a request is only admitted when the pool can hold its whole span
(prompt + decode budget), and its pages are returned at retirement — FIFO
order is preserved (no head-of-line skipping), so a block-starved pool
defers admissions rather than reordering them.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.serve.resilience import RequestResult
from repro.testing import faults

__all__ = ["seq_buckets", "pick_bucket", "Scheduler"]


@functools.lru_cache(maxsize=None)
def seq_buckets(max_seq: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """Power-of-two prompt buckets up to ``max_seq`` (always included),
    ascending.  Cached: every engine over the same ``(max_seq, min_bucket)``
    shares one tuple instead of recomputing it per construction."""
    if max_seq < 1:
        raise ValueError(f"max_seq must be >= 1, got {max_seq}")
    out = []
    b = min_bucket
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(sorted(set(out)))


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """The smallest bucket that fits ``n`` tokens.

    ``buckets`` must be sorted ascending (what :func:`seq_buckets` returns)
    — the lookup is a bisect, not a scan-and-sort per call."""
    i = bisect.bisect_left(buckets, n)
    if i == len(buckets):
        raise ValueError(f"prompt of {n} tokens exceeds the largest bucket "
                         f"{buckets[-1]}")
    return buckets[i]


@dataclasses.dataclass
class _Slot:
    """Host mirror of one device decode lane."""
    req_id: int = -1          # -1: free
    remaining: int = 0        # tokens still owed to the request
    prefill_pos: int = 0      # prompt positions already prefilled
    prefill_len: int = 0      # total prompt length (0 once decoding)

    @property
    def free(self) -> bool:
        return self.req_id < 0

    @property
    def prefilling(self) -> bool:
        """Admitted but the prompt is not fully in the cache yet — the lane
        decodes discarded padding until the last prefill chunk lands."""
        return self.req_id >= 0 and self.prefill_pos < self.prefill_len


class Scheduler:
    """Admission/retirement bookkeeping over ``n_slots`` decode lanes.

    The engine drives it:

      * ``submit(req_id, prompt_len, max_new)`` queues a request;
      * ``admissions()`` (at a chunk boundary) pops pending requests into
        free slots, FIFO — reserving KV blocks first when a ``pool`` is
        attached — and marks them prefilling;
      * ``prefilling()`` lists slots whose prompts still have chunks to
        prefill; the engine advances each by one chunk per boundary and
        records progress with ``prefill_advance(slot, n)``;
      * ``record_first(slot, token)`` accounts the token sampled from the
        (final) prefill logits;
      * ``record_chunk(tokens)`` accounts one decoded chunk for every
        decoding slot (``tokens``: (n_slots, chunk) host array) and retires
        slots whose requests are complete.

    Outputs accumulate in ``outputs[req_id]``; tokens a slot decodes past
    its request's ``max_new_tokens`` (chunks are fixed-length; requests are
    not) are discarded here and never reach the caller.

    Every request ends in exactly one terminal state
    (``ok|timeout|cancelled|failed`` — ``repro.serve.resilience.STATES``),
    recorded in ``done[req_id]`` and surfaced by ``pop_result``; partial
    tokens survive into the result whatever the state.  ``cancel``/``fail``
    work on pending AND slotted requests; ``check_deadlines`` sweeps
    per-request TTFT + e2e deadlines at chunk boundaries.
    """

    def __init__(self, n_slots: int, pool=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self.pending: Deque[int] = deque()
        self.meta: Dict[int, dict] = {}
        self.outputs: Dict[int, List[int]] = {}
        self.done: Dict[int, Tuple[str, str]] = {}  # rid -> (state, reason)
        self.pool = pool  # repro.serve.paged.BlockPool (or None: dense)
        # lifecycle accounting (``stats()`` / ``Engine.stats()``): admits and
        # retires are totals; a *deferral* is one chunk boundary at which the
        # queue head could not be admitted for lack of KV blocks
        self.n_admits = 0
        self.n_retires = 0
        self.n_deferrals = 0
        self.n_timeouts = 0
        self.n_cancelled = 0
        self.n_failed = 0
        self.n_evacuations = 0

    # -- intake --------------------------------------------------------------

    def submit(self, req_id: int, prompt_len: int, max_new: int, *,
               deadline_s: Optional[float] = None,
               ttft_deadline_s: Optional[float] = None) -> None:
        if req_id in self.meta or req_id in self.done:
            raise ValueError(f"request id {req_id} already submitted")
        self.meta[req_id] = {"prompt_len": prompt_len, "max_new": max_new,
                             "t_submit": time.perf_counter(),
                             "deadline_s": deadline_s,
                             "ttft_deadline_s": ttft_deadline_s}
        self.outputs[req_id] = []
        self.pending.append(req_id)
        obs.counter("serve.requests_submitted").inc()
        obs.event("serve.submit", req_id=req_id, prompt_len=prompt_len,
                  max_new=max_new)

    # -- chunk-boundary decisions -------------------------------------------

    def admissions(self) -> List[Tuple[int, int]]:
        """(slot index, req_id) pairs to admit now — free slots, FIFO.

        With a block pool, each admission first reserves pages for the
        request's whole span (prompt + decode budget); when the head of the
        queue does not fit, admission stops — later requests never jump
        ahead of it."""
        out = []
        now = time.perf_counter()
        for i, slot in enumerate(self.slots):
            if not self.pending:
                break
            if not slot.free:
                continue
            rid = self.pending[0]
            meta = self.meta[rid]
            starved = faults.should_fire("serve.pool_exhausted",
                                         req_id=rid) is not None
            if self.pool is not None or starved:
                need = (self.pool.blocks_for(
                    meta["prompt_len"] + meta["max_new"])
                    if self.pool is not None else 0)
                if starved or not self.pool.can_alloc(need):
                    # the queue head is block-starved: one deferral per
                    # boundary, however many slots were still free behind it
                    self.n_deferrals += 1
                    obs.counter("serve.admission_deferrals").inc()
                    obs.event("serve.admission_deferred", req_id=rid,
                              need_blocks=need,
                              free_blocks=(self.pool.free_blocks
                                           if self.pool is not None else 0))
                    break
                self.pool.alloc(i, need)
            self.pending.popleft()
            slot.req_id = rid
            slot.remaining = meta["max_new"]
            slot.prefill_pos = 0
            slot.prefill_len = meta["prompt_len"]
            self.n_admits += 1
            meta["t_admit"] = now
            obs.counter("serve.requests_admitted").inc()
            obs.histogram("serve.queue_wait_s").observe(
                now - meta["t_submit"])
            obs.event("serve.admit", req_id=rid, slot=i,
                      prompt_len=meta["prompt_len"])
            out.append((i, rid))
        return out

    def prefilling(self) -> List[Tuple[int, int]]:
        """(slot index, req_id) pairs with prompt chunks still to prefill."""
        return [(i, s.req_id) for i, s in enumerate(self.slots)
                if s.prefilling]

    def prefill_advance(self, slot_idx: int, n: int) -> None:
        """Account ``n`` prompt positions prefilled into ``slot_idx``."""
        slot = self.slots[slot_idx]
        slot.prefill_pos = min(slot.prefill_pos + n, slot.prefill_len)

    def record_first(self, slot_idx: int, token: int) -> bool:
        """Account the prefill-sampled token; True if the request is already
        complete (max_new_tokens == 1) and the slot retired.

        Recording the first token means the prompt is fully in the cache,
        so this also closes the slot's prefill window — callers that never
        chunk (the whole prompt in one admission call) need no
        ``prefill_advance`` at all."""
        slot = self.slots[slot_idx]
        slot.prefill_pos = slot.prefill_len
        meta = self.meta.get(slot.req_id)
        if meta is not None and "t_first" not in meta:
            meta["t_first"] = time.perf_counter()
            ttft = meta["t_first"] - meta["t_submit"]
            obs.histogram("serve.ttft_s").observe(ttft)
            obs.event("serve.first_token", req_id=slot.req_id,
                      slot=slot_idx, ttft_s=round(ttft, 6))
        if slot.remaining > 0:
            self.outputs[slot.req_id].append(int(token))
            slot.remaining -= 1
        if slot.remaining == 0:
            self._retire(slot_idx)
            return True
        return False

    def record_chunk(self, tokens) -> List[int]:
        """Account one decoded chunk; returns req_ids retired this boundary.

        ``tokens`` is a (n_slots, chunk) host int array — the single
        device->host transfer of the chunk.  Free and still-prefilling
        slots decoded discarded padding; their rows are skipped."""
        finished = []
        for i, slot in enumerate(self.slots):
            if slot.free or slot.prefilling:
                continue
            take = min(slot.remaining, tokens.shape[1])
            self.outputs[slot.req_id].extend(int(t) for t in tokens[i, :take])
            slot.remaining -= take
            if slot.remaining == 0:
                finished.append(slot.req_id)
                self._retire(i)
        return finished

    def _retire(self, slot_idx: int, state: str = "ok",
                reason: str = "") -> None:
        slot = self.slots[slot_idx]
        rid = slot.req_id
        meta = self.meta.get(rid)
        self.n_retires += 1
        obs.counter("serve.requests_retired").inc()
        if meta is not None:
            now = time.perf_counter()
            n_tok = len(self.outputs.get(rid, ()))
            obs.histogram("serve.request_tokens").observe(n_tok)
            obs.histogram("serve.e2e_s").observe(now - meta["t_submit"])
            t_first = meta.get("t_first")
            # decode throughput: tokens after the first, over the time after
            # the first — prefill latency is TTFT's burden, not decode's
            if t_first is not None and n_tok > 1 and now > t_first:
                obs.histogram("serve.decode_tok_s").observe(
                    (n_tok - 1) / (now - t_first))
        obs.event("serve.retire", req_id=rid, slot=slot_idx, state=state)
        slot.req_id = -1
        slot.remaining = 0
        slot.prefill_pos = slot.prefill_len = 0
        if self.pool is not None:
            self.pool.free(slot_idx)  # every page back; tables re-set on
            #                           the next admission, never trusted
        self._finish(rid, state, reason)

    def _finish(self, rid: int, state: str, reason: str) -> None:
        """Record a request's terminal state (exactly once per request)."""
        self.done[rid] = (state, reason)
        if state == "timeout":
            self.n_timeouts += 1
        elif state == "cancelled":
            self.n_cancelled += 1
        elif state == "failed":
            self.n_failed += 1
        if state != "ok":
            obs.counter(f"serve.requests_{state}").inc()
            obs.event("serve.request_terminal", req_id=rid, state=state,
                      reason=reason)
        if state in ("failed", "timeout"):
            # the black box: everything the process saw leading up to this
            # request going bad (cancellation is a caller action, not a
            # failure — no dump)
            obs.flight_dump(f"request_{state}", req_id=rid, why=reason)

    def _slot_of(self, rid: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.req_id == rid:
                return i
        return None

    def _terminate(self, rid: int, state: str,
                   reason: str) -> Optional[int]:
        """Move a live request to a terminal state; returns the slot index
        it occupied (the engine must park that device lane) or None if it
        was still pending / already terminal.  KeyError for unknown ids."""
        if rid in self.done:
            return None  # already terminal: idempotent
        if rid not in self.meta:
            raise KeyError(f"unknown request id {rid}")
        slot_idx = self._slot_of(rid)
        if slot_idx is not None:
            self._retire(slot_idx, state, reason)
            return slot_idx
        self.pending.remove(rid)
        self._finish(rid, state, reason)
        return None

    def cancel(self, rid: int, reason: str = "cancelled by caller"
               ) -> Optional[int]:
        """Cancel a pending or in-flight request (partial tokens kept).
        Returns the freed slot index when it was occupying a device lane
        (the engine parks it), else None.  No-op when already terminal."""
        return self._terminate(rid, "cancelled", reason)

    def fail(self, rid: int, reason: str) -> Optional[int]:
        """Quarantine a request as ``failed`` (same mechanics as cancel)."""
        return self._terminate(rid, "failed", reason)

    def evacuate(self, slot_idx: int,
                 reason: str = "host lost") -> Optional[int]:
        """Return an in-flight request to the FRONT of the pending queue —
        the failure-domain path (``repro.serve.domains``): its slot lived
        on a host that died, so the slot frees without the request ending.

        The request restarts from its prompt on re-admission: emitted
        tokens are discarded (they regenerate bit-identically — sampling
        is a pure function of the request's PRNG stream, independent of
        slot/batch/placement), timing metadata resets so TTFT is measured
        against the *new* admission, and — paged — the slot's pages return
        to the pool.  Returns the evacuated req_id, or None for a free
        slot.  Callers evacuating several slots appendleft in *descending*
        slot order to preserve FIFO among the evacuees."""
        slot = self.slots[slot_idx]
        rid = slot.req_id
        if rid < 0:
            return None
        meta = self.meta.get(rid)
        if meta is not None:
            meta.pop("t_first", None)
            meta.pop("t_admit", None)
        self.outputs[rid] = []
        slot.req_id = -1
        slot.remaining = 0
        slot.prefill_pos = slot.prefill_len = 0
        if self.pool is not None:
            self.pool.free(slot_idx)
        self.pending.appendleft(rid)
        self.n_evacuations += 1
        obs.counter("serve.evacuations").inc()
        obs.event("serve.evacuate", req_id=rid, slot=slot_idx,
                  reason=reason)
        return rid

    def check_deadlines(self, now: Optional[float] = None
                        ) -> List[Tuple[Optional[int], int]]:
        """Expire requests past their deadlines; returns
        ``(freed slot or None, req_id)`` per expiry.

        Two clocks per request, both from ``t_submit``: ``ttft_deadline_s``
        applies until the first token lands (``t_first``), ``deadline_s``
        applies end-to-end.  Swept at chunk boundaries — the engine cannot
        observe (or stop) anything mid-chunk, so a deadline is enforced at
        the first boundary at or after its expiry."""
        now = time.perf_counter() if now is None else now
        expired: List[Tuple[str, int]] = []
        for rid, meta in self.meta.items():
            if rid in self.done:
                continue
            waited = now - meta["t_submit"]
            dl = meta.get("deadline_s")
            ttft = meta.get("ttft_deadline_s")
            if dl is not None and waited >= dl:
                expired.append(("e2e deadline expired", rid))
            elif (ttft is not None and "t_first" not in meta
                  and waited >= ttft):
                expired.append(("ttft deadline expired", rid))
        out: List[Tuple[Optional[int], int]] = []
        for why, rid in expired:
            out.append((self._terminate(rid, "timeout", why), rid))
        return out

    def pop_result(self, req_id: int) -> RequestResult:
        """Collect a terminal request's tokens + state and drop its records
        — memory stays bounded by in-flight + uncollected work, not total
        traffic.  KeyError for ids never submitted (or already collected);
        ValueError while the request is still pending/in-flight."""
        if req_id not in self.done:
            if req_id in self.meta:
                raise ValueError(f"request {req_id} is still in flight")
            raise KeyError(f"unknown request id {req_id}")
        state, reason = self.done.pop(req_id)
        tokens = tuple(self.outputs.pop(req_id, ()))
        self.meta.pop(req_id, None)
        return RequestResult(req_id=req_id, tokens=tokens, state=state,
                             reason=reason)

    def pop_output(self, req_id: int) -> List[int]:
        """Tokens-only view of :meth:`pop_result` (the pre-resilience API).
        Raises the same KeyError/ValueError on unknown/in-flight ids."""
        return list(self.pop_result(req_id).tokens)

    # -- state ---------------------------------------------------------------

    def busy_slots(self) -> List[int]:
        """Slots actively DECODING (admitted and fully prefilled)."""
        return [i for i, s in enumerate(self.slots)
                if not s.free and not s.prefilling]

    def stats(self) -> Dict[str, int]:
        """Lifecycle totals + instantaneous occupancy (one dict, cheap)."""
        return {
            "admits": self.n_admits,
            "retires": self.n_retires,
            "deferrals": self.n_deferrals,
            "timeouts": self.n_timeouts,
            "cancelled": self.n_cancelled,
            "failed": self.n_failed,
            "evacuations": self.n_evacuations,
            "pending": len(self.pending),
            "busy": sum(1 for s in self.slots if not s.free),
            "prefilling": sum(1 for s in self.slots if s.prefilling),
            "slots": len(self.slots),
        }

    @property
    def idle(self) -> bool:
        return not self.pending and all(s.free for s in self.slots)
