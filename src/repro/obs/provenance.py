"""Strategy provenance: who picked what, from which inputs, and why.

The pipeline's claim is that tuned decisions — kernel rewrite params, mesh
placement, serving KV layout — are *preserved* end to end.  This module
makes each decision a record instead of a side effect: the tuner
(:func:`repro.autotune.tune`, ``pick_kv_layout``), the op-layer fallbacks
(:mod:`repro.kernels.ops`), and the AOT loader all ``record()`` here, and
``explain()`` renders the log as a human-readable "why did the compiler
pick this" report::

    from repro import obs
    print(obs.explain())

Each :class:`Decision` carries the decision *inputs* (kernel, shape, dtype,
backend, mesh descriptor, KV layout), the chosen params, the predicted
roofline terms (flops / hbm bytes / grid + loop structure / interconnect
traffic, from ``repro.autotune.cost.CostEstimate``), the measured time when
the tuner actually ran the candidate, and the **origin**:

    analytic          ranked by the roofline only, this process
    measured          compiled + timed, this process
    cache(analytic)   served from the persistent tuning cache (an
    cache(measured)     earlier process did the work; suffix = how)
    default           no tuned entry — the kernel's canonical default params
    aot-loaded        an executor rebuilt from the AOT program store
    degraded(a->b)    the degradation ladder fired: strategy ``a`` failed to
                      build/compile/validate and the runtime fell back to
                      ``b`` — e.g. ``degraded(tuned->default)`` (a tuned
                      entry failed, canonical defaults used),
                      ``degraded(pallas->jnp)`` (default params failed too,
                      dpia-jnp reference used), ``degraded(paged->dense)``
                      (KV block pool corrupt, serving switched layouts).
                      See docs/resilience.md for the full ladder.

Recording is always on (it happens at *tuning* time, which the op layer
memoises per process — never on a hot call path) and keyed by the same
canonical cache key the tuner uses, so one decision per (kernel, shape,
dtype, backend, mesh, layout) is retained with the latest origin.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Decision", "ProvenanceLog", "log", "record", "decisions",
           "get", "annotate", "clear", "explain"]


@dataclasses.dataclass(frozen=True)
class Decision:
    """One tuned choice, with everything needed to audit it."""
    kind: str                       # "kernel" | "mesh" | "kv_layout" | ...
    kernel: str
    key: str                        # the canonical tuning/executor cache key
    params: Dict[str, object]
    origin: str                     # see module docstring
    shape: Dict[str, object] = dataclasses.field(default_factory=dict)
    dtype: str = "float32"
    backend: str = "jnp"
    mesh: str = "single"
    layout: str = "dense"
    cost_s: Optional[float] = None        # predicted roofline seconds
    terms: Dict[str, float] = dataclasses.field(default_factory=dict)
    measured_us: Optional[float] = None
    n_candidates: int = 0
    note: str = ""
    strategy_trace: Optional[dict] = None  # serialised StrategyTrace doc
    t_wall: float = dataclasses.field(default_factory=time.time)

    def to_doc(self) -> dict:
        d = dataclasses.asdict(self)
        d["params"] = {k: _plain(v) for k, v in self.params.items()}
        return d

    def describe(self) -> str:
        shape_s = ",".join(f"{k}={v}" for k, v in sorted(self.shape.items()))
        params_s = ", ".join(f"{k}={v}" for k, v in
                             sorted(self.params.items())) or "(defaults)"
        lines = [f"[{self.kind}] {self.kernel} {shape_s} dtype={self.dtype} "
                 f"backend={self.backend} mesh={self.mesh} "
                 f"layout={self.layout}",
                 f"    picked {params_s}",
                 f"    origin {self.origin}"
                 + (f" over {self.n_candidates} candidates"
                    if self.n_candidates else "")]
        why = []
        if self.cost_s is not None:
            why.append(f"predicted {self.cost_s:.3g} s")
        if self.terms:
            why.append("roofline " + " ".join(
                f"{k}={v:.3g}" for k, v in sorted(self.terms.items()) if v))
        if self.measured_us is not None:
            why.append(f"measured {self.measured_us:.1f} us")
        if why:
            lines.append("    " + "; ".join(why))
        if self.strategy_trace and self.strategy_trace.get("steps"):
            lines.append("    derived by " + _trace_str(self.strategy_trace))
        if self.note:
            lines.append(f"    note: {self.note}")
        return "\n".join(lines)


def _trace_str(doc: dict) -> str:
    """Render a serialised StrategyTrace (lazy import: repro.strategy is a
    consumer of obs, so the dependency must not run at module load)."""
    try:
        from repro.strategy.lang import StrategyTrace
        return StrategyTrace.from_doc(doc).describe()
    except Exception:
        return " ; ".join(str(s.get("rule", "?"))
                          for s in doc.get("steps", ()))


def _plain(v):
    return v if isinstance(v, (str, int, float, bool)) or v is None else repr(v)


class ProvenanceLog:
    """Keyed store of the latest Decision per cache key, insert-ordered."""

    def __init__(self):
        self._decisions: Dict[str, Decision] = {}
        self._lock = threading.Lock()

    def record(self, d: Decision) -> None:
        with self._lock:
            self._decisions[d.key] = d

    def get(self, key: str) -> Optional[Decision]:
        return self._decisions.get(key)

    def annotate(self, key: str, **changes) -> Optional[Decision]:
        """Replace fields on the decision recorded under ``key`` (Decisions
        are frozen, so this installs a modified copy).  The drift audit uses
        it to mark entries ``stale``.  Returns the new Decision, or None
        when nothing is recorded under ``key``."""
        with self._lock:
            d = self._decisions.get(key)
            if d is None:
                return None
            d2 = dataclasses.replace(d, **changes)
            self._decisions[key] = d2
            return d2

    def decisions(self, kind: Optional[str] = None) -> List[Decision]:
        with self._lock:
            ds = list(self._decisions.values())
        if kind is not None:
            ds = [d for d in ds if d.kind == kind]
        return ds

    def clear(self) -> None:
        with self._lock:
            self._decisions.clear()

    def __len__(self) -> int:
        return len(self._decisions)

    def explain(self, key: Optional[str] = None,
                kind: Optional[str] = None) -> str:
        """The human-readable strategy report.

        ``key`` narrows to one decision (substring match on the cache key,
        so ``explain("matmul")`` works); ``kind`` filters by decision kind.
        """
        ds = self.decisions(kind)
        if key is not None:
            ds = [d for d in ds if key in d.key]
        if not ds:
            return ("strategy provenance — no decisions recorded"
                    + (f" matching {key!r}" if key else "")
                    + "\n(run something through repro.kernels.ops / "
                      "repro.autotune first)")
        by_origin: Dict[str, int] = {}
        for d in ds:
            by_origin[d.origin] = by_origin.get(d.origin, 0) + 1
        head = (f"strategy provenance — {len(ds)} decision"
                f"{'s' if len(ds) != 1 else ''} ("
                + ", ".join(f"{n} {o}" for o, n in sorted(by_origin.items()))
                + ")")
        return "\n".join([head] + [d.describe() for d in ds])


_log: Optional[ProvenanceLog] = None
_log_lock = threading.Lock()


def log() -> ProvenanceLog:
    """The process-wide provenance log."""
    global _log
    with _log_lock:
        if _log is None:
            _log = ProvenanceLog()
        return _log


def record(kind: str, kernel: str, key: str, params: Dict[str, object],
           origin: str, **kw) -> Decision:
    """Build + record a Decision in the process log; returns it."""
    d = Decision(kind=kind, kernel=kernel, key=key,
                 params=dict(params or {}), origin=origin, **kw)
    log().record(d)
    return d


def decisions(kind: Optional[str] = None) -> List[Decision]:
    return log().decisions(kind)


def get(key: str) -> Optional[Decision]:
    return log().get(key)


def annotate(key: str, **changes) -> Optional[Decision]:
    return log().annotate(key, **changes)


def clear() -> None:
    log().clear()


def explain(key: Optional[str] = None, kind: Optional[str] = None) -> str:
    return log().explain(key, kind)
