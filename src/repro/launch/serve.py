"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Initialises a model, prefills a batch of prompts, and decodes with the
batched or continuous-batching engine (greedy or sampled) over the fused
on-device decode chunks."""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4,
                    help="device decode lanes (continuous engine)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode tokens per fused on-device chunk")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import config, smoke_config
    from repro.models.transformer import Model
    from repro.serve.engine import BatchedEngine, ContinuousEngine, Request

    cfg = smoke_config(args.arch) if args.smoke else config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)

    shape = (args.prompt_len,)
    if cfg.n_codebooks:
        shape = shape + (cfg.n_codebooks,)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), shape, 0,
                                  cfg.vocab) for i in range(args.batch)]
    reqs = [Request(prompt=p, max_new_tokens=args.max_new,
                    temperature=args.temperature) for p in prompts]

    max_seq = args.prompt_len + args.max_new + 8
    if args.engine == "continuous":
        engine = ContinuousEngine(model, params, max_seq=max_seq,
                                  slots=args.slots, chunk=args.chunk)
    else:
        engine = BatchedEngine(model, params, max_seq=max_seq,
                               chunk=args.chunk)
    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name} batch={args.batch} generated {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"  request[{i}]: {o[:12]}{'...' if len(o) > 12 else ''}")


if __name__ == "__main__":
    main()
