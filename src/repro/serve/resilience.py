"""Serving resilience: request terminal states, retry/deadline policy, and
the degradation-ladder recording shared by the engines.

The serving claim (docs/resilience.md) extends the strategy language's
"failure as a value" discipline to the runtime: a fault never crashes the
engine — it moves one request to a terminal non-``ok`` state, or moves the
*strategy* one rung down a recorded degradation ladder, while co-batched
clean requests keep streaming bitwise-identical tokens.

This module holds the pieces shared by ``Scheduler`` and the engines:

  * :data:`STATES` / :class:`RequestResult` — the per-request terminal
    contract surfaced by ``pop_result``/``stats()``;
  * :class:`ResilienceConfig` — the engine policy knobs (NaN guard, chunk
    retry budget + backoff, chunk straggler deadline, pool validation);
  * :func:`record_degradation` — the one way a fallback becomes visible:
    an obs provenance Decision with origin ``degraded(from->to)``, the
    always-on ``serve.degradations`` counter, and a structured event.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro import obs

__all__ = ["STATES", "TERMINAL_NON_OK", "RequestResult", "ResilienceConfig",
           "record_degradation"]

# Per-request terminal states (the request-lifecycle contract):
#   ok         ran to completion; tokens are the full decode output
#   timeout    e2e or TTFT deadline expired; tokens are the partial output
#   cancelled  caller cancelled; tokens are the partial output
#   failed     quarantined (non-finite logits, repeated chunk failure, or
#              explicit fail()); tokens are the partial output
STATES = ("ok", "timeout", "cancelled", "failed")
TERMINAL_NON_OK = ("timeout", "cancelled", "failed")


@dataclasses.dataclass
class RequestResult:
    """Terminal outcome of one request: tokens + state + why."""
    req_id: int
    tokens: Tuple[int, ...]
    state: str                  # one of STATES
    reason: str = ""            # human-readable cause for non-ok states

    @property
    def ok(self) -> bool:
        return self.state == "ok"


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Engine-level fault policy (defaults are safe for production).

    ``nan_guard``: compute a per-slot non-finite flag inside the decode
    chunk (one extra all-reduce over logits, no change to the token
    dataflow) and quarantine poisoned slots at the chunk boundary.

    ``max_chunk_retries`` / ``retry_backoff_s``: transient decode-chunk
    failures (the executable raised before consuming its donated buffers)
    are retried with linear backoff; on exhaustion every in-flight request
    fails and the device state is rebuilt rather than crashing.

    ``chunk_deadline_s``: when set, a chunk exceeding it records a
    straggler event through the hardened ``ft.resilience.Watchdog``
    (detection only — the chunk is synchronous, so mitigation is a
    scheduling concern).

    ``pool_check``: validate the paged ``BlockPool`` free-list invariants
    each chunk; corruption degrades paged -> dense instead of corrupting
    cross-request KV state.
    """
    nan_guard: bool = True
    max_chunk_retries: int = 2
    retry_backoff_s: float = 0.02
    chunk_deadline_s: Optional[float] = None
    quarantine_on_chunk_failure: bool = True
    pool_check: bool = True


def record_degradation(kind: str, kernel: str, key: str, frm: str, to: str,
                       params: Optional[Dict[str, object]] = None,
                       dump: bool = True, **kw) -> str:
    """Record one rung of the degradation ladder; returns the origin string.

    Every fallback in the tree funnels through here (or through
    ``kernels.ops`` which emits the same triple) so ``obs.explain()``
    answers *why the strategy changed*: a provenance Decision with origin
    ``degraded(frm->to)``, the ``serve.degradations`` counter, and an
    event carrying the cause.  ``kw`` passes through to ``obs.record``
    (shape/dtype/backend/layout/note/...).

    ``dump=False`` suppresses the flight-recorder snapshot for callers
    that emit their own, richer dump for the same incident (the host-loss
    path dumps once with reason ``host_lost``; two black boxes for one
    event would break the bench's one-dump-per-event accounting).
    """
    origin = f"degraded({frm}->{to})"
    obs.record(kind, kernel, key, params or {}, origin, **kw)
    obs.counter("serve.degradations").inc()
    obs.event("serve.degraded", kind=kind, kernel=kernel, key=key,
              origin=origin, note=str(kw.get("note", "")))
    if dump:
        # a degradation is a strategy change under duress: snapshot the
        # black box so the dump shows what led up to it
        obs.flight_dump("degradation", kind=kind, kernel=kernel, key=key,
                        frm=frm, to=to)
    return origin
