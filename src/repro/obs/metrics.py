"""Process-wide metrics registry: counters, gauges, histograms.

The registry is always on — unlike tracing there is no enable switch,
because every instrument is a couple of float ops under a per-instrument
lock and the serving spine only touches them at *boundaries* (per chunk,
per admission, per retire), never per token or per scan step.  That keeps
the disabled-tracing serving path within its <2% overhead budget while the
numbers (TTFT, decode tok/s, pool occupancy, recompile counts) are always
available to ``snapshot()`` without a special run.

Instruments:

  * :class:`Counter` — monotonically increasing float (``inc``);
  * :class:`Gauge` — last-write-wins value (``set``/``inc``);
  * :class:`Histogram` — streaming count/total/min/max plus base-2
    magnitude buckets, enough for the serving latency distributions
    without storing samples.

``snapshot()`` returns plain dicts (JSON-able as-is); ``reset()`` zeroes
every instrument but keeps them registered, so long-lived processes can
take per-interval readings.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Dict, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
           "counter", "gauge", "histogram", "snapshot", "reset", "export",
           "set_delta_sink"]

# Optional tap on counter increments (the flight recorder registers here so
# metric deltas land in its ring).  One global read + ``if`` per inc() —
# and increments only happen at boundaries, never in a hot loop.
_delta_sink = None


def set_delta_sink(fn) -> None:
    """Register ``fn(name, delta)`` to observe every counter increment;
    ``None`` unregisters."""
    global _delta_sink
    _delta_sink = fn


class Counter:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n
        if _delta_sink is not None:
            _delta_sink(self.name, n)

    @property
    def value(self) -> float:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def _snap(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def _snap(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Streaming summary + base-2 magnitude buckets.

    The bucket for observation ``v > 0`` is ``floor(log2(v))``; zero and
    negative values land in a dedicated underflow bucket.  That is coarse
    but monotone and unbounded — latencies from nanoseconds to minutes all
    bucket meaningfully with no a-priori range choice."""
    __slots__ = ("name", "_count", "_total", "_min", "_max", "_buckets",
                 "_lock")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._zero()

    def _zero(self) -> None:
        self._count = 0
        self._total = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        v = float(v)
        b = math.floor(math.log2(v)) if v > 0 else -1024
        with self._lock:
            self._count += 1
            self._total += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            self._buckets[b] = self._buckets.get(b, 0) + 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._total / self._count if self._count else 0.0

    def _reset(self) -> None:
        with self._lock:
            self._zero()

    def percentile(self, q: float) -> Optional[float]:
        """Estimate the ``q``-quantile (``0 < q <= 1``) by linear
        interpolation inside the base-2 bucket holding it, clamped to the
        observed min/max (so single-value and edge buckets are exact).
        None when nothing has been observed."""
        with self._lock:
            return self._percentiles((q,))[0]

    def _percentiles(self, qs):
        """Quantile estimates for each q in ``qs``; call with lock held."""
        if not self._count:
            return [None] * len(qs)
        items = sorted(self._buckets.items())
        out = []
        for q in qs:
            target = q * self._count
            cum = 0
            val = self._max
            for k, n in items:
                if cum + n >= target:
                    # bucket k spans [2^k, 2^(k+1)); underflow bucket is 0
                    lo = 0.0 if k == -1024 else float(2.0 ** k)
                    hi = 0.0 if k == -1024 else float(2.0 ** (k + 1))
                    val = lo + (target - cum) / n * (hi - lo)
                    break
                cum += n
            out.append(min(max(val, self._min), self._max))
        return out

    def _snap(self) -> dict:
        with self._lock:
            p50, p95, p99 = self._percentiles((0.5, 0.95, 0.99))
            return {
                "type": "histogram", "count": self._count,
                "total": self._total,
                "mean": self._total / self._count if self._count else 0.0,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None,
                "p50": p50, "p95": p95, "p99": p99,
                "buckets": {f"2^{k}" if k != -1024 else "<=0": v
                            for k, v in sorted(self._buckets.items())},
            }


class MetricsRegistry:
    """Name -> instrument, created on first use; type mismatches raise."""

    _TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def names(self):
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        """{name: {"type": ..., ...}} for every registered instrument."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m._snap() for name, m in sorted(items)}

    def reset(self) -> None:
        """Zero every instrument (registrations survive)."""
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m._reset()

    def export(self, path: str) -> str:
        """Write ``snapshot()`` as JSON to ``path`` (atomic tmp+rename)."""
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path


_registry: Optional[MetricsRegistry] = None
_registry_lock = threading.Lock()


def registry() -> MetricsRegistry:
    """The process-wide registry the serving/compiler spine writes to."""
    global _registry
    with _registry_lock:
        if _registry is None:
            _registry = MetricsRegistry()
        return _registry


def counter(name: str) -> Counter:
    return registry().counter(name)


def gauge(name: str) -> Gauge:
    return registry().gauge(name)


def histogram(name: str) -> Histogram:
    return registry().histogram(name)


def snapshot() -> Dict[str, dict]:
    return registry().snapshot()


def reset() -> None:
    registry().reset()


def export(path: str) -> str:
    return registry().export(path)
