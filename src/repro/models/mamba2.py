"""Mamba2 (SSD) mixer — the zamba2 hybrid's sequence-mixing block.

Faithful structure: in-proj -> (gate z | conv'd x | B | C | dt), causal
depthwise conv, selective state-space recurrence with per-head scalar decay
A, gated out-proj.  The recurrence runs as a ``lax.scan`` over time (the
DPIA reading: a ``scanI``/reduceSeq strategy); a chunked SSD formulation is
the documented optimisation path (EXPERIMENTS.md section Perf).

State per layer: conv tail (b, conv_w-1, din + 2N) and SSM state
(b, nheads, hd, N) — constant-size, which is what makes long_500k runnable.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense

CONV_W = 4
HD = 64  # mamba2 head dim


class Mamba2Params(NamedTuple):
    w_in: jax.Array       # (d, 2*din + 2N + nheads)
    conv_w: jax.Array     # (conv_w, din + 2N)
    a_log: jax.Array      # (nheads,)
    dt_bias: jax.Array    # (nheads,)
    d_skip: jax.Array     # (nheads,)
    norm_w: jax.Array     # (din,)
    w_out: jax.Array      # (din, d)


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    din = 2 * cfg.d_model
    nheads = din // HD
    return din, nheads, cfg.ssm_state


class Mamba2State(NamedTuple):
    conv: jax.Array   # (b, CONV_W-1, din + 2N)
    ssm: jax.Array    # (b, nheads, HD, N)


def init_mamba2(key, cfg: ModelConfig) -> Mamba2Params:
    d = cfg.d_model
    din, nheads, n = dims(cfg)
    ks = jax.random.split(key, 3)
    return Mamba2Params(
        w_in=init_dense(ks[0], d, 2 * din + 2 * n + nheads, cfg.dtype),
        conv_w=(jax.random.normal(ks[1], (CONV_W, din + 2 * n)) * 0.1
                ).astype(cfg.dtype),
        a_log=jnp.zeros((nheads,), jnp.float32),
        dt_bias=jnp.zeros((nheads,), jnp.float32),
        d_skip=jnp.ones((nheads,), jnp.float32),
        norm_w=jnp.ones((din,), cfg.dtype),
        w_out=init_dense(ks[2], din, d, cfg.dtype),
    )


def init_state(cfg: ModelConfig, batch: int) -> Mamba2State:
    din, nheads, n = dims(cfg)
    return Mamba2State(
        conv=jnp.zeros((batch, CONV_W - 1, din + 2 * n), cfg.dtype),
        ssm=jnp.zeros((batch, nheads, HD, n), jnp.float32))


def _split_proj(cfg, proj):
    din, nheads, n = dims(cfg)
    z, xbc, dt = jnp.split(proj, [din, 2 * din + 2 * n], axis=-1)
    return z, xbc, dt


def forward(p: Mamba2Params, cfg: ModelConfig, x,
            state: Mamba2State = None, lengths=None):
    """Full-sequence forward; returns (y, final_state).

    ``lengths`` ((b,) int32, optional) marks the real prompt length per row
    of a RIGHT-padded batch: conv-tail and SSM state updates are masked off
    at padded positions, so the returned state is bitwise the state of the
    unpadded sequence (padding invariance for the recurrent path)."""
    b, s, d = x.shape
    din, nheads, n = dims(cfg)
    fresh = state is None

    proj = jnp.einsum("bsd,de->bse", x, p.w_in)
    z, xbc, dt_raw = _split_proj(cfg, proj)

    # causal depthwise conv over (x|B|C) with carried tail.  Fresh-sequence
    # zero states are derived from the activations so they INHERIT the
    # activations' sharding — plain jnp.zeros is replicated and makes GSPMD
    # unshard the whole scan chain (see attention.py / EXPERIMENTS.md Perf).
    if fresh:
        conv_state = xbc[:, :1, :] * 0
        conv_state = jnp.broadcast_to(
            conv_state, (b, CONV_W - 1, conv_state.shape[-1]))
    else:
        conv_state = state.conv
    xbc_ext = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    conv = sum(p.conv_w[i][None, None, :]
               * jax.lax.dynamic_slice_in_dim(xbc_ext, i, s, axis=1)
               for i in range(CONV_W))
    conv = jax.nn.silu(conv)
    if lengths is None:
        new_conv_tail = xbc_ext[:, -(CONV_W - 1):, :]
    else:
        # last CONV_W-1 REAL positions: row i's real tokens occupy ext
        # positions [CONV_W-1, CONV_W-1 + lengths[i]), so its tail starts
        # at ext position lengths[i]
        idx = (jnp.asarray(lengths, jnp.int32)[:, None]
               + jnp.arange(CONV_W - 1)[None, :])
        new_conv_tail = jnp.take_along_axis(
            xbc_ext, idx[:, :, None], axis=1)

    xs_, bc = jnp.split(conv, [din], axis=-1)
    b_in, c_in = jnp.split(bc, 2, axis=-1)                  # (b, s, N) each

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias)                        # (b, s, nh)
    a = -jnp.exp(p.a_log)                                    # (nh,)
    da = jnp.exp(dt * a)                                     # decay per step

    xh = xs_.reshape(b, s, nheads, HD).astype(jnp.float32)

    def step(h, inp):
        xh_t, b_t, c_t, da_t, dt_t, m_t = inp
        h_new = h * da_t[..., None, None] + (
            (dt_t[..., None] * xh_t)[..., None] * b_t[:, None, None, :])
        h = jnp.where(m_t[:, None, None, None], h_new, h)
        y = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y

    mask = (jnp.arange(s)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]
            if lengths is not None else jnp.ones((b, s), bool))
    seq = (xh.transpose(1, 0, 2, 3),
           b_in.astype(jnp.float32).transpose(1, 0, 2),
           c_in.astype(jnp.float32).transpose(1, 0, 2),
           da.transpose(1, 0, 2),
           dt.transpose(1, 0, 2),
           mask.transpose(1, 0))
    if fresh:  # sharding-inheriting zero state (see above)
        ssm0 = (xh[:, 0, :, :, None]
                * b_in.astype(jnp.float32)[:, 0, None, None, :]) * 0
    else:
        ssm0 = state.ssm
    h_final, ys = jax.lax.scan(step, ssm0, seq)
    y = ys.transpose(1, 0, 2, 3)                             # (b, s, nh, hd)
    y = y + p.d_skip[None, None, :, None] * xh               # skip connection
    y = y.reshape(b, s, din).astype(x.dtype)

    # gated rmsnorm (mamba2 style): norm(y * silu(z))
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(g32 * g32, axis=-1, keepdims=True)
    g = (g32 * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype) * p.norm_w

    out = jnp.einsum("bse,ed->bsd", g, p.w_out)
    return out, Mamba2State(new_conv_tail, h_final)


def decode_step(p: Mamba2Params, cfg: ModelConfig, x, state: Mamba2State):
    """Single-token step: x (b, 1, d)."""
    y, new_state = forward(p, cfg, x, state)
    return y, new_state
