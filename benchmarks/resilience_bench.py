"""Resilience benchmark: the fault-injection soak behind docs/resilience.md.

Drives the continuous-batching engine through a deterministic fault
schedule (``repro.testing.faults``) and asserts the three resilience
claims as *measured* outcomes, not code review:

  1. **zero crashes** — every phase runs to completion under injected
     NaN logits, transient chunk errors, stragglers, pool exhaustion,
     pool corruption, executor-build failures, and a corrupted tuning
     cache;
  2. **token identity for the innocent** — every request the faults did
     not target streams tokens bitwise-identical to the fault-free
     static-batch oracle, co-batched with the poisoned ones;
  3. **visible degradation for the rest** — faulted requests end in a
     terminal non-``ok`` state (never silently wrong), and every strategy
     fallback appears in obs provenance with origin ``degraded(a->b)``.

Phases (``--smoke`` keeps A + E and trims the request mix; the default
soak runs all of them):

  A  serving faults  — NaN prefill, NaN decode, transient chunk errors,
                       a straggler chunk, and an expired deadline, all in
                       one traffic mix;
  B  paged faults    — pool exhaustion (deferral, not drop) and a NaN
                       quarantine whose scrubbed pages are reused;
  C  pool corruption — paged -> dense degradation mid-traffic;
  D  kernel ladder   — executor build failures: tuned -> default -> jnp;
  E  artefact heal   — a corrupted tuning-cache record is quarantined at
                       load and rebuilt by the next ``tune()``;
  F  host loss       — (``--host-loss``, needs an 8-device platform) a
                       2-host ShardedEngine loses host 1 mid-decode: its
                       slots evacuate to the queue front, the mesh shrinks
                       ``data=8 -> data=4`` (recorded as provenance origin
                       ``degraded(mesh(...))`` + exactly ONE ``host_lost``
                       flight dump per loss event), and every request —
                       survivor and evacuee — retires token-identical to
                       the fault-free oracle; the checksummed scheduler
                       journal (``--journal-out``) verifies and replays.
                       A clean sharded run first proves zero dumps and
                       zero degradations without the fault.

The bench also exercises the flight recorder end to end: a clean phase
must produce ZERO dumps, and every request that ends ``failed``/``timeout``
must have a matching ``request_<state>`` dump attributing it by req_id
(``--flight-dir`` additionally writes each dump as a ``flight-*.json``
artefact for ``validate_trace.py --flight`` + CI upload).

Usage:
  PYTHONPATH=src python benchmarks/resilience_bench.py [--smoke]
      [--out FILE] [--trace FILE] [--metrics-out FILE]
      [--flight-dir DIR] [--no-assert]

Writes BENCH_resilience.json; ``--trace``/``--metrics-out`` export the
obs trace/metrics for ``benchmarks/validate_trace.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp


def _mk_model():
    from repro.models.common import ModelConfig
    from repro.models.transformer import Model
    cfg = ModelConfig(name="resil-bench", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab=128, dtype="float32", remat=False, max_seq=64)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n, Request):
    key = jax.random.PRNGKey(7)
    return [Request(
        prompt=jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (5 + 3 * i,), 0, cfg.vocab),
        max_new_tokens=4 + 3 * i, temperature=0.0) for i in range(n)]


def _drive(eng, reqs, key):
    """submit + step_chunk to idle; returns per-request RequestResults."""
    with eng._options_scope():
        eng._run_key = key
        rids = [eng.submit(r, stream=i) for i, r in enumerate(reqs)]
        while not eng.sched.idle:
            eng.step_chunk()
    return [eng.take_result(rid) for rid in rids]


def _tally(results, oracle, targeted, doc, phase):
    """Check the identity/terminal-state contract for one phase."""
    clean_ok, clean_bad, states = 0, 0, {}
    for i, r in enumerate(results):
        states[i] = r.state
        if i in targeted:
            assert r.state != "ok", \
                f"{phase}: faulted request {i} ended ok"
        else:
            if list(r.tokens) == oracle[i]:
                clean_ok += 1
            else:
                clean_bad += 1
    doc["phases"][phase] = {
        "states": {str(k): v for k, v in states.items()},
        "clean_identical": clean_ok,
        "clean_diverged": clean_bad,
    }
    assert clean_bad == 0, f"{phase}: {clean_bad} clean requests diverged"
    return clean_ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: NaN request + corrupt cache record only")
    ap.add_argument("--out", default="BENCH_resilience.json")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="enable span tracing; export Chrome trace JSON")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="export the metrics registry snapshot as JSON")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="write flight-recorder dumps as flight-*.json "
                         "artefacts into DIR")
    ap.add_argument("--host-loss", action="store_true",
                    help="run phase F (ShardedEngine host-loss drill; "
                         "needs >= 8 devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--journal-out", default=None, metavar="FILE",
                    help="phase F: write the scheduler journal here "
                         "(validate with validate_trace.py --journal)")
    ap.add_argument("--no-assert", action="store_true",
                    help="report only; do not enforce the contract")
    args = ap.parse_args()

    from repro import obs
    from repro.serve.engine import BatchedEngine, ContinuousEngine, Request
    from repro.serve.resilience import ResilienceConfig
    from repro.testing import faults

    if args.trace:
        obs.enable()
    if args.flight_dir:
        obs.configure_flight(dir=args.flight_dir)
    obs.flight_clear()

    cfg, model, params = _mk_model()
    key = jax.random.PRNGKey(7)
    n_req = 3 if args.smoke else 5
    reqs = _mk_requests(cfg, n_req, Request)

    print(f"# resilience_bench: {cfg.name} requests={n_req} "
          f"{'(smoke)' if args.smoke else '(soak)'}")

    t0 = time.perf_counter()
    oracle = BatchedEngine(model, params, max_seq=64, chunk=4).run(
        reqs, key=key)
    print(f"  oracle: {len(oracle)} requests, fault-free "
          f"({time.perf_counter() - t0:.1f}s)")

    doc = {"phases": {}, "fault_types": []}
    clean_identical = 0

    # every request that ends failed/timeout must leave a flight dump
    # attributing it; (req_id, state) pairs collected per faulted phase
    expect_dumps = []

    def _note_failures(results):
        for i, r in enumerate(results):
            if r.state in ("failed", "timeout"):
                expect_dumps.append((i, r.state))

    # -- phase 0: clean traffic must leave the flight recorder silent --------
    t0 = time.perf_counter()
    eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                           min_bucket=8)
    results = _drive(eng, reqs, key)
    clean_identical += _tally(results, oracle, set(), doc, "0_clean")
    assert len(obs.flight_dumps()) == 0, \
        [d["reason"] for d in obs.flight_dumps()]
    doc["phases"]["0_clean"]["flight_dumps"] = 0
    print(f"  0 clean: states={[r.state for r in results]}, "
          f"no flight dumps ({time.perf_counter() - t0:.1f}s)")

    # -- phase A: serving faults in one mix ----------------------------------
    t0 = time.perf_counter()
    eng = ContinuousEngine(
        model, params, max_seq=64, slots=2, chunk=4, min_bucket=8,
        resilience=ResilienceConfig(retry_backoff_s=0.001,
                                    chunk_deadline_s=0.25))
    if args.smoke:
        spec = "serve.nan_prefill(req_id=1)"
        doc["fault_types"] += ["nan_prefill"]
        targeted = {1}
        phase_reqs = list(reqs)
    else:
        spec = ("serve.nan_prefill(req_id=1); serve.nan_decode(req_id=2); "
                "serve.chunk_error(times=2); "
                "serve.slow_chunk(times=1, value=0.4)")
        doc["fault_types"] += ["nan_prefill", "nan_decode", "chunk_error",
                               "slow_chunk", "deadline"]
        targeted = {1, 2, n_req}     # n_req: the doomed deadline request
        phase_reqs = list(reqs) + [Request(prompt=reqs[0].prompt,
                                           max_new_tokens=4,
                                           deadline_s=0.0)]
    with faults.inject(spec) as plan:
        results = _drive(eng, phase_reqs, key)
    _note_failures(results)
    clean_identical += _tally(results, oracle, targeted, doc, "A_serving")
    rs = eng.stats()["resilience"]
    doc["phases"]["A_serving"].update(
        {"resilience": rs, "faults_fired": sum(f.fired for f in plan)})
    if not args.smoke:
        assert rs["chunk_retries"] == 2, rs
        assert rs["stragglers"] >= 1, rs
    print(f"  A serving faults: states="
          f"{[r.state for r in results]} retries={rs['chunk_retries']} "
          f"({time.perf_counter() - t0:.1f}s)")

    # -- phase B: paged — exhaustion defers; scrubbed pages are reused -------
    if not args.smoke:
        t0 = time.perf_counter()
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout="paged",
                               block_size=16, kv_blocks=10)
        with faults.inject("serve.pool_exhausted(req_id=0); "
                           "serve.nan_decode(req_id=2)"):
            results = _drive(eng, reqs, key)
        _note_failures(results)
        doc["fault_types"] += ["pool_exhausted"]
        clean_identical += _tally(results, oracle, {2}, doc, "B_paged")
        doc["phases"]["B_paged"]["deferrals"] = eng.sched.n_deferrals
        assert eng.sched.n_deferrals >= 1
        print(f"  B paged: deferrals={eng.sched.n_deferrals} states="
              f"{[r.state for r in results]} "
              f"({time.perf_counter() - t0:.1f}s)")

    # -- phase C: pool corruption degrades paged -> dense --------------------
    if not args.smoke:
        t0 = time.perf_counter()
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout="paged",
                               block_size=16)
        with faults.inject("serve.pool_corrupt(after=1)"):
            results = _drive(eng, reqs, key)
        _note_failures(results)
        doc["fault_types"] += ["pool_corrupt"]
        in_flight_failed = {i for i, r in enumerate(results)
                            if r.state == "failed"}
        clean_identical += _tally(results, oracle, in_flight_failed, doc,
                                  "C_pool_corrupt")
        assert eng.kv_layout == "dense", "engine did not degrade"
        degr = [d for d in obs.decisions()
                if d.origin == "degraded(paged->dense)"]
        assert degr, "paged->dense degradation not in provenance"
        doc["phases"]["C_pool_corrupt"]["kv_layout_after"] = eng.kv_layout
        print(f"  C pool corrupt: paged->dense, states="
              f"{[r.state for r in results]} "
              f"({time.perf_counter() - t0:.1f}s)")

    # -- phase D: the kernel degradation ladder ------------------------------
    if not args.smoke:
        t0 = time.perf_counter()
        from repro.kernels import ops
        x = jnp.arange(64, dtype=jnp.float32)
        ref = ops.dot(x, x, impl="xla")
        ops.clear_caches()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            with faults.inject(
                    "executor.build(key=dot*|pallas|*, times=-1)"):
                out = ops.dot(x, x, impl="dpia-pallas")
        assert jnp.allclose(out, ref), "degraded kernel wrong"
        doc["fault_types"] += ["executor_build"]
        origins = sorted({d.origin for d in obs.decisions()
                          if d.kernel == "dot"
                          and d.origin.startswith("degraded(")})
        assert "degraded(tuned->default)" in origins, origins
        assert "degraded(pallas->jnp)" in origins, origins
        ops.clear_caches()
        doc["phases"]["D_kernel_ladder"] = {"origins": origins}
        print(f"  D kernel ladder: {origins} "
              f"({time.perf_counter() - t0:.1f}s)")

    # -- phase E: corrupt tuning-cache record heals + rebuilds ---------------
    t0 = time.perf_counter()
    import tempfile
    from repro import autotune
    from repro.autotune.cache import TuningCache, make_key
    cache_path = os.path.join(tempfile.mkdtemp(prefix="resil-bench-"),
                              "tune.json")
    cache = TuningCache(cache_path)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        autotune.tune("dot", cache=cache, measure=False, n=64)
    k = make_key("dot", {"n": 64})
    assert cache.get(k) is not None
    raw = json.load(open(cache_path))
    raw.pop("checksum", None)
    raw["entries"][k] = "corrupt-record"
    with open(cache_path, "w") as f:
        json.dump(raw, f)
    before = obs.counter("artefact.entry_quarantined").value
    healed = TuningCache(cache_path)
    assert healed.get(k) is None, "corrupt record served"
    assert obs.counter("artefact.entry_quarantined").value > before
    assert os.path.isdir(cache_path + ".quarantine"), "no quarantine dir"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        autotune.tune("dot", cache=healed, measure=False, n=64)
    assert TuningCache(cache_path).get(k) is not None, "not rebuilt"
    doc["fault_types"] += ["artefact_corrupt"]
    doc["phases"]["E_artefact_heal"] = {
        "quarantined": True, "rebuilt": True,
        "quarantine_dir": cache_path + ".quarantine"}
    print(f"  E artefact heal: entry quarantined + rebuilt by tune() "
          f"({time.perf_counter() - t0:.1f}s)")

    # -- phase F: host loss — evacuation, mesh shrink, checksummed journal ---
    if args.host_loss and len(jax.devices()) < 8:
        print("  F host loss: SKIPPED — needs an 8-device platform (run "
              "under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        doc["phases"]["F_host_loss"] = {"skipped": "needs 8 devices"}
    elif args.host_loss:
        t0 = time.perf_counter()
        import tempfile
        from repro.serve.domains import SchedulerJournal
        from repro.serve.engine import ShardedEngine
        # 8 phase-local requests so both hosts' slots carry work when the
        # fault fires; decodes long enough (16 tokens, chunk=4) that every
        # request is still in flight at the loss boundary
        fkey = jax.random.PRNGKey(7)
        f_reqs = [Request(
            prompt=jax.random.randint(jax.random.fold_in(fkey, 200 + i),
                                      (4 + i,), 0, cfg.vocab),
            max_new_tokens=16, temperature=0.0) for i in range(8)]
        f_oracle = ContinuousEngine(model, params, max_seq=64, slots=8,
                                    chunk=4, min_bucket=8).run(f_reqs,
                                                               key=fkey)

        # a clean sharded run first: zero NEW dumps, zero NEW degradations
        dumps0 = len(obs.flight_dumps())
        degr0 = obs.counter("serve.degradations").value
        eng = ShardedEngine(model, params, max_seq=64, slots=8, chunk=4,
                            min_bucket=8, mesh=jax.make_mesh((8,), ("data",)),
                            hosts=2)
        clean = _drive(eng, f_reqs, fkey)
        assert all(r.state == "ok" for r in clean)
        assert [list(r.tokens) for r in clean] == f_oracle
        assert len(obs.flight_dumps()) == dumps0, \
            "clean sharded run left flight dumps"
        assert obs.counter("serve.degradations").value == degr0, \
            "clean sharded run recorded a degradation"

        # host 1 dies three boundaries in
        jpath = args.journal_out or os.path.join(
            tempfile.mkdtemp(prefix="resil-bench-"), "journal.jsonl")
        eng = ShardedEngine(model, params, max_seq=64, slots=8, chunk=4,
                            min_bucket=8, mesh=jax.make_mesh((8,), ("data",)),
                            hosts=2, journal=jpath)
        with faults.inject("mesh.host_lost(host=1, after=3)") as plan:
            results = _drive(eng, f_reqs, fkey)
        st = eng.stats()
        n_events = st["resilience"]["host_losses"]
        assert plan[0].fired == 1 and n_events == 1, (plan[0].fired,
                                                      n_events)
        # zero crashes; survivors retired in place, evacuees re-admitted on
        # the shrunk mesh — ALL token-identical to the fault-free oracle
        assert all(r.state == "ok" for r in results), \
            [r.state for r in results]
        ident = sum(list(r.tokens) == f_oracle[i]
                    for i, r in enumerate(results))
        assert ident == len(f_reqs), f"{len(f_reqs) - ident} diverged"
        clean_identical += ident
        assert st["mesh"]["descriptor"] == "data=4", st["mesh"]
        assert eng.sched.n_evacuations >= 1
        # the shrink is a recorded strategy change...
        mesh_degr = sorted({d.origin for d in obs.decisions()
                            if d.origin.startswith("degraded(mesh(")})
        assert mesh_degr, "mesh shrink not in provenance"
        # ...with exactly ONE flight dump per host-loss event
        host_dumps = [d for d in obs.flight_dumps()
                      if d["reason"] == "host_lost"]
        assert len(host_dumps) == n_events, \
            (len(host_dumps), n_events)
        # the checksummed journal tells the whole story and verifies clean
        jstate = SchedulerJournal.load(jpath)
        assert jstate.clean, "journal failed checksum verification"
        assert len(jstate.shrinks) == 1, jstate.shrinks
        assert jstate.shrinks[0]["to"] == "data=4"
        assert jstate.evacuations == eng.sched.n_evacuations
        doc["fault_types"] += ["host_lost"]
        doc["phases"]["F_host_loss"] = {
            "states": {str(i): r.state for i, r in enumerate(results)},
            "clean_identical": ident, "clean_diverged": 0,
            "origins": mesh_degr,
        }
        doc["host_loss"] = {
            "events": n_events,
            "evacuations": eng.sched.n_evacuations,
            "descriptor_before": "data=8",
            "descriptor_after": st["mesh"]["descriptor"],
            "token_identical": ident,
            "requests": len(f_reqs),
            "host_lost_dumps": len(host_dumps),
            "journal": jpath,
            "journal_clean": jstate.clean,
        }
        print(f"  F host loss: data=8->data=4, "
              f"{eng.sched.n_evacuations} evacuated, {ident}/{len(f_reqs)} "
              f"token-identical, {len(host_dumps)} host_lost dump, "
              f"journal clean ({time.perf_counter() - t0:.1f}s)")

    # -- report ---------------------------------------------------------------
    doc.update({
        "smoke": bool(args.smoke),
        "requests": n_req,
        "fault_types": sorted(set(doc["fault_types"])),
        "faults_injected": obs.counter("faults.injected").value,
        "degradations": (obs.counter("serve.degradations").value
                         + obs.counter("kernels.degradations").value),
        "artefact_load_failures": obs.counter("artefact.load_failed").value,
        "clean_identical": clean_identical,
        "terminal_states": {
            s: obs.counter(f"serve.requests_{s}").value
            for s in ("timeout", "cancelled", "failed")},
        "nan_quarantines": obs.counter("serve.nan_quarantines").value,
        "chunk_failures": obs.counter("serve.chunk_failures").value,
    })
    flight = obs.flight_dumps()
    doc["flight"] = {
        "dumps": len(flight),
        "reasons": sorted({d["reason"] for d in flight}),
        "expected_request_dumps": len(expect_dumps),
        "dir": args.flight_dir or "",
    }
    for name, v in (("bench.resil.faults_injected", doc["faults_injected"]),
                    ("bench.resil.degradations", doc["degradations"]),
                    ("bench.resil.clean_identical", clean_identical)):
        obs.gauge(name).set(v)
    doc["metrics"] = obs.metrics_snapshot()

    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"  wrote {args.out}")
    if args.trace:
        obs.export_trace(args.trace)
        print(f"  wrote {args.trace} ({len(obs.trace_events())} events)")
    if args.metrics_out:
        obs.export_metrics(args.metrics_out)
        print(f"  wrote {args.metrics_out}")

    if not args.no_assert:
        want = 2 if args.smoke else 5
        assert len(doc["fault_types"]) >= want, doc["fault_types"]
        # phase E's cache damage is real file corruption, not a fault-site
        # firing, so it counts as a fault type but not an injection
        assert doc["faults_injected"] >= want - 1
        assert doc["clean_identical"] >= 1
        assert doc["terminal_states"]["failed"] >= 1
        # the flight-recorder contract: every failed/timeout request left a
        # dump attributing it by req_id, degradations dumped too
        assert expect_dumps, "no failed/timeout requests observed"
        for rid, state in expect_dumps:
            assert any(d["reason"] == f"request_{state}"
                       and d["ctx"].get("req_id") == rid
                       for d in flight), (rid, state, doc["flight"])
        if not args.smoke:
            assert any(d["reason"] == "degradation" for d in flight), \
                doc["flight"]
        if args.flight_dir:
            files = [n for n in os.listdir(args.flight_dir)
                     if n.startswith("flight-") and n.endswith(".json")]
            assert len(files) >= len(flight), (len(files), len(flight))
    print(f"  OK: {len(doc['fault_types'])} fault types, "
          f"{int(doc['faults_injected'])} injections, "
          f"{clean_identical} clean requests token-identical, "
          f"{len(flight)} flight dumps "
          f"({len(expect_dumps)} request failures attributed), 0 crashes")


if __name__ == "__main__":
    main()
