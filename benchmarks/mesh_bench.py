"""Mesh strategy benchmark: per-op shardmap dispatch and sharded serving.

Runs on a FORCED 8-device CPU mesh (``--xla_force_host_platform_device_count``
is set before jax initialises, so this script must be a fresh process), and
measures two things:

  ops     — the six tuned kernels dispatched through ``dpia-shardmap``
            (mesh-level DPIA strategies -> shard_map + collectives) vs the
            single-device ``dpia-jnp`` pipeline and the plain XLA oracle:
            correctness (asserted) and wall time per call (reported);
  serving — ``serve.ShardedEngine`` (slot axis sharded over ``data``) vs the
            unsharded ``ContinuousEngine`` on the same traffic:
            token-identity (asserted), recompiles after warm-up (asserted
            zero), and tokens/s (reported).

Host-CPU "devices" share the same cores, so shardmap timings here measure
*dispatch overhead*, not speedup — the point of the benchmark is that the
mesh path is correct, cache-stable, and recompile-free; speedups come from
real accelerators.  Asserts cover exactly those invariants (``--no-assert``
to report only).

Usage:
  PYTHONPATH=src python benchmarks/mesh_bench.py [--smoke] [--out FILE]

Writes BENCH_mesh.json (``--out`` to override) and prints a summary.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# must happen before jax initialises: an 8-device host platform
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _best_of(fn, repeats: int = 5) -> float:
    jax.block_until_ready(fn())  # warm/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_ops(mesh, smoke: bool, repeats: int) -> dict:
    from repro import compiler
    from repro.kernels import ops

    n = 1 << 14 if smoke else 1 << 18
    rows, d = (64, 128) if smoke else (256, 512)
    m, k, nn = (64, 128, 64) if smoke else (256, 512, 256)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(n), "float32")
    y = jnp.asarray(rng.randn(n), "float32")
    X = jnp.asarray(rng.randn(rows, d), "float32")
    w = jnp.asarray(rng.randn(d), "float32")
    A = jnp.asarray(rng.randn(m, k), "float32")
    B = jnp.asarray(rng.randn(k, nn), "float32")

    cases = [
        ("dot", lambda impl: ops.dot(x, y, impl=impl)),
        ("asum", lambda impl: ops.asum(x, impl=impl)),
        ("scal", lambda impl: ops.scal(2.5, x, impl=impl)),
        ("matmul", lambda impl: ops.matmul(A, B, impl=impl)),
        ("rmsnorm", lambda impl: ops.rmsnorm(X, w, impl=impl)),
        ("softmax", lambda impl: ops.softmax(X, impl=impl)),
    ]

    out = {}
    print(f"# ops on mesh {dict(mesh.shape)} (n={n}, rows={rows}, "
          f"mkn={m}x{k}x{nn})")
    with compiler.options(mesh=mesh):
        for name, call in cases:
            want = np.asarray(call("xla"))
            got = np.asarray(call("dpia-shardmap"))
            np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3,
                                       err_msg=name)
            t_mesh = _best_of(lambda: call("dpia-shardmap"), repeats)
            t_one = _best_of(lambda: call("dpia-jnp"), repeats)
            t_xla = _best_of(lambda: call("xla"), repeats)
            out[name] = {"shardmap_us": t_mesh * 1e6,
                         "dpia_jnp_us": t_one * 1e6, "xla_us": t_xla * 1e6}
            print(f"  {name:8s} shardmap {t_mesh * 1e6:9.1f} us | "
                  f"dpia-jnp {t_one * 1e6:9.1f} us | "
                  f"xla {t_xla * 1e6:9.1f} us   (oracle-equal)")

    mesh_keys = [kk for kk in compiler.executor_cache().keys()
                 if "|shardmap|" in kk]
    out["mesh_executor_keys"] = len(mesh_keys)
    print(f"  mesh-keyed executors staged: {len(mesh_keys)}")
    return out


def bench_serving(mesh, smoke: bool, repeats: int, do_assert: bool) -> dict:
    from repro.models.common import ModelConfig
    from repro.models.transformer import Model
    from repro.serve.engine import ContinuousEngine, Request, ShardedEngine

    cfg = ModelConfig(name="mesh-bench", family="dense",
                      n_layers=2 if smoke else 4,
                      d_model=64 if smoke else 128, n_heads=4, n_kv_heads=2,
                      d_ff=128 if smoke else 256, vocab=256, dtype="float32",
                      remat=False, max_seq=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    slots = 8
    chunk = 8
    max_new = 16 if smoke else 32

    def reqs():
        key = jax.random.PRNGKey(42)
        return [Request(
            prompt=jax.random.randint(jax.random.fold_in(key, i),
                                      (8 + 2 * (i % 4),), 0, cfg.vocab),
            max_new_tokens=max_new) for i in range(slots + 4)]

    key = jax.random.PRNGKey(7)
    cont = ContinuousEngine(model, params, max_seq=cfg.max_seq, slots=slots,
                            chunk=chunk)
    shard = ShardedEngine(model, params, max_seq=cfg.max_seq, slots=slots,
                          chunk=chunk, mesh=mesh)

    want = cont.run(reqs(), key=key)        # warm + oracle
    got = shard.run(reqs(), key=key)        # warm + identity check
    identical = got == want
    compiles_warm = shard.decode_cache_misses()

    def run_cont():
        return cont.run(reqs(), key=key)

    def run_shard():
        return shard.run(reqs(), key=key)

    t_cont = t_shard = float("inf")
    n_tok = sum(len(o) for o in want)
    for _ in range(repeats):                 # interleaved best-of-N
        t0 = time.perf_counter()
        run_cont()
        t_cont = min(t_cont, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_shard()
        t_shard = min(t_shard, time.perf_counter() - t0)
    recompiles = shard.decode_cache_misses() - compiles_warm

    print(f"# serving: slots={slots} over {dict(mesh.shape)} "
          f"({len(reqs())} requests x {max_new} new tokens)")
    print(f"  continuous  {n_tok / t_cont:9.1f} tok/s")
    print(f"  sharded     {n_tok / t_shard:9.1f} tok/s   "
          f"(token-identical: {identical}, decode compiles "
          f"{compiles_warm}, recompiles after warm-up: {recompiles})")

    if do_assert:
        assert identical, "ShardedEngine tokens diverged from ContinuousEngine"
        assert recompiles == 0, f"{recompiles} recompiles after warm-up"
        assert compiles_warm == 1, f"{compiles_warm} decode chunk compiles"
        print("  asserts OK (token identity, 1 chunk compile, 0 recompiles)")

    return {"slots": slots, "chunk": chunk, "tokens": n_tok,
            "continuous_tok_s": n_tok / t_cont,
            "sharded_tok_s": n_tok / t_shard,
            "token_identical": bool(identical),
            "decode_compiles_warm": compiles_warm,
            "recompiles_after_warmup": recompiles}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short runs (CI): small shapes, fewer repeats")
    ap.add_argument("--out", default="BENCH_mesh.json")
    ap.add_argument("--no-assert", action="store_true",
                    help="report only; do not enforce identity/recompiles")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if n_dev < 8:
        raise SystemExit(f"mesh_bench needs 8 forced host devices, got "
                         f"{n_dev} — run in a fresh process (XLA_FLAGS is "
                         f"set at import, before jax initialises)")
    mesh = jax.make_mesh((8,), ("data",))
    repeats = 2 if args.smoke else 5

    ops_doc = bench_ops(mesh, args.smoke, repeats)
    serve_doc = bench_serving(mesh, args.smoke, repeats,
                              do_assert=not args.no_assert)

    doc = {"mesh": "data=8", "smoke": bool(args.smoke),
           "ops": ops_doc, "serving": serve_doc}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"  wrote {args.out}")


if __name__ == "__main__":
    main()
