"""Pretty printer for DPIA phrases (instantiates HOAS binders with fresh vars)."""
from __future__ import annotations

from . import phrases as P
from .types import AccT, ExpT, Idx, VarT, show_data


def show(p: P.Phrase, indent: int = 0) -> str:  # noqa: C901
    pad = "  " * indent
    s = lambda q: show(q, indent)  # noqa: E731
    if isinstance(p, P.Var):
        return p.name
    if isinstance(p, P.Lit):
        return f"{p.value:g}"
    if isinstance(p, P.UnOp):
        return f"{p.op}({s(p.e)})"
    if isinstance(p, P.BinOp):
        sym = {"add": "+", "sub": "-", "mul": "*", "div": "/",
               "max": "max", "min": "min"}[p.op]
        return f"({s(p.a)} {sym} {s(p.b)})"
    if isinstance(p, P.Map):
        x = P.Var(P.fresh("x"), ExpT(_elem(p.e)))
        sp = f"@{p.space}" if p.space else ""
        return f"map[{p.level}]{sp} (λ{x.name}. {s(p.f(x))}) ({s(p.e)})"
    if isinstance(p, P.Reduce):
        x = P.Var(P.fresh("x"), ExpT(_elem(p.e)))
        acc = P.Var(P.fresh("a"), P.type_of(p.init))
        return (f"reduce[{p.level}] (λ{x.name} {acc.name}. "
                f"{s(p.f(x, acc))}) ({s(p.init)}) ({s(p.e)})")
    if isinstance(p, P.Zip):
        return f"zip ({s(p.a)}) ({s(p.b)})"
    if isinstance(p, P.Split):
        return f"split {p.n} ({s(p.e)})"
    if isinstance(p, P.Join):
        return f"join ({s(p.e)})"
    if isinstance(p, P.PairE):
        return f"pair ({s(p.a)}) ({s(p.b)})"
    if isinstance(p, P.Fst):
        return f"fst ({s(p.e)})"
    if isinstance(p, P.Snd):
        return f"snd ({s(p.e)})"
    if isinstance(p, P.IdxE):
        return f"idx ({s(p.e)}) ({s(p.i)})"
    if isinstance(p, P.AsVector):
        return f"asVector<{p.w}> ({s(p.e)})"
    if isinstance(p, P.AsScalar):
        return f"asScalar ({s(p.e)})"
    if isinstance(p, P.DotBlock):
        return f"dotBlock ({s(p.a)}) ({s(p.b)})"
    if isinstance(p, P.FullReduce):
        return f"fullReduce[{p.op}] ({s(p.e)})"
    if isinstance(p, P.ToMem):
        return f"to{p.space.upper()} ({s(p.e)})"
    if isinstance(p, P.Skip):
        return "skip"
    if isinstance(p, P.SeqC):
        return f"{show(p.c1, indent)};\n{pad}{show(p.c2, indent)}"
    if isinstance(p, P.Assign):
        return f"{s(p.a)} := {s(p.e)}"
    if isinstance(p, P.New):
        v = P.Var(P.fresh("v"), VarT(p.d))
        body = show(p.f(v), indent + 1)
        return (f"new[{p.space}] {show_data(p.d)} (λ{v.name}.\n"
                f"{pad}  {body})")
    if isinstance(p, P.For):
        i = P.Var(P.fresh("i"), ExpT(Idx(p.n)))
        body = show(p.f(i), indent + 1)
        return f"for {p.n} (λ{i.name}.\n{pad}  {body})"
    if isinstance(p, P.ParFor):
        i = P.Var(P.fresh("i"), ExpT(Idx(p.n)))
        o = P.Var(P.fresh("o"), AccT(p.d))
        body = show(p.f(i, o), indent + 1)
        return (f"parfor[{p.level}] {p.n} ({s(p.a)}) (λ{i.name} {o.name}.\n"
                f"{pad}  {body})")
    if isinstance(p, P.VView):
        return f"<view {s(p.acc)}>"
    if isinstance(p, P.AccPart):
        return f"{s(p.v)}.1"
    if isinstance(p, P.ExpPart):
        return f"{s(p.v)}.2"
    if isinstance(p, P.IdxAcc):
        return f"idxAcc ({s(p.a)}) ({s(p.i)})"
    if isinstance(p, P.SplitAcc):
        return f"splitAcc {p.n} ({s(p.a)})"
    if isinstance(p, P.JoinAcc):
        return f"joinAcc {p.m} ({s(p.a)})"
    if isinstance(p, P.PairAcc1):
        return f"pairAcc1 ({s(p.a)})"
    if isinstance(p, P.PairAcc2):
        return f"pairAcc2 ({s(p.a)})"
    if isinstance(p, P.ZipAcc1):
        return f"zipAcc1 ({s(p.a)})"
    if isinstance(p, P.ZipAcc2):
        return f"zipAcc2 ({s(p.a)})"
    if isinstance(p, P.AsScalarAcc):
        return f"asScalarAcc ({s(p.a)})"
    if isinstance(p, P.AsVectorAcc):
        return f"asVectorAcc<{p.w}> ({s(p.a)})"
    if isinstance(p, P.MapI):
        x = P.Var(P.fresh("x"), ExpT(p.d1))
        o = P.Var(P.fresh("o"), AccT(p.d2))
        body = show(p.f(x, o), indent + 1)
        return (f"mapI[{p.level}] {p.n} (λ{x.name} {o.name}.\n{pad}  {body})\n"
                f"{pad}  ({s(p.e)}) ({s(p.a)})")
    if isinstance(p, P.ReduceI):
        x = P.Var(P.fresh("x"), ExpT(p.d1))
        y = P.Var(P.fresh("y"), ExpT(p.d2))
        o = P.Var(P.fresh("o"), AccT(p.d2))
        r = P.Var(P.fresh("r"), ExpT(p.d2))
        body = show(p.f(x, y, o), indent + 1)
        kont = show(p.k(r), indent + 1)
        return (f"reduceI {p.n} (λ{x.name} {y.name} {o.name}.\n{pad}  {body})\n"
                f"{pad}  ({s(p.init)}) ({s(p.e)}) (λ{r.name}.\n{pad}  {kont})")
    return object.__repr__(p)


def _elem(e: P.Phrase):
    from .types import Arr
    d = P.exp_data(e)
    assert isinstance(d, Arr), show_data(d)
    return d.elem
