"""Gradient compression for cross-pod (DCN) all-reduces.

Two modes:
  * bf16  — cast-before-reduce (used by default in the microbatch
            accumulation window of train/step.py; halves collective bytes);
  * int8  — error-feedback quantised all-reduce, for the 'pod' axis where
            DCN bandwidth dominates.  Must run inside shard_map (manual
            collectives); the residual is carried by the caller.

Error feedback keeps the quantisation bias out of the optimizer trajectory:
    q = Q(g + e);  e' = (g + e) - deQ(q);  allreduce(q)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def bf16_psum(tree, axis: str):
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis).astype(g.dtype),
        tree)


def _q8(x) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def int8_ef_psum(tree, err_tree, axis: str):
    """Error-feedback int8 all-reduce; returns (reduced_tree, new_err_tree).

    The int8 payload travels the wire (psum on int32 of the int8 values);
    scales are psum'd separately (sum of per-shard maxima upper-bounds the
    true scale; conservative and cheap)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q8(g32)
        deq = q.astype(jnp.float32) * scale
        new_e = g32 - deq
        total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                             axis)
        return total.astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(tree)
    flat_e = jax.tree_util.tree_leaves(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return red, err


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
