"""Strategy mining: compress winning traces into reusable abstractions.

Every tuned decision leaves a serialised :class:`StrategyTrace` in the
persistent tuning cache (``record["strategy_trace"]``).  This module mines
that corpus the imperative-stitch way: pairwise *anti-unification* of
traces — the longest common subsequence of ``(rule, path)`` steps, with
parameters that differ across the pair replaced by holes (``"?"``) — then
keeps the generalisations at least ``min_support`` winners instantiate.

The named :class:`Abstraction` s persist beside the cache
(``<cache>.abstractions.json``) and seed later searches: candidates whose
derivation matches a mined abstraction are ranked first
(:func:`seeded_order`, used by ``autotune.tune``), so on a warm corpus the
incumbent best is reached in fewer candidate evaluations — the metric
``benchmarks/strategy_bench.py`` pins down.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.ft import artefacts

from .lang import StrategyTrace

__all__ = ["HOLE", "AbsStep", "Abstraction", "anti_unify", "mine",
           "matches", "seeded_order", "abstractions_path",
           "save_abstractions", "load_abstractions"]

HOLE = "?"
ABSTRACTIONS_VERSION = 1


@dataclasses.dataclass(frozen=True)
class AbsStep:
    """One generalised trace step; params map to a value or to HOLE."""
    rule: str
    path: Tuple[str, ...]
    params: Tuple[Tuple[str, object], ...]  # sorted items; HOLE = any value

    def to_doc(self) -> dict:
        return {"rule": self.rule, "path": list(self.path),
                "params": {k: v for k, v in self.params}}

    @classmethod
    def from_doc(cls, doc: dict) -> "AbsStep":
        return cls(rule=str(doc["rule"]),
                   path=tuple(str(s) for s in doc.get("path", ())),
                   params=tuple(sorted(doc.get("params", {}).items())))


@dataclasses.dataclass
class Abstraction:
    """A named, parameter-holed rewrite subsequence mined from winners."""
    name: str
    steps: Tuple[AbsStep, ...]
    support: int = 0

    def to_doc(self) -> dict:
        return {"name": self.name, "support": self.support,
                "steps": [s.to_doc() for s in self.steps]}

    @classmethod
    def from_doc(cls, doc: dict) -> "Abstraction":
        return cls(name=str(doc["name"]),
                   steps=tuple(AbsStep.from_doc(s)
                               for s in doc.get("steps", ())),
                   support=int(doc.get("support", 0)))

    def describe(self) -> str:
        body = " ; ".join(
            s.rule + ("(" + ",".join(
                f"{k}={v}" for k, v in s.params) + ")" if s.params else "")
            + ("@" + "/".join(s.path) if s.path else "")
            for s in self.steps)
        return f"{self.name} [support={self.support}]: {body}"


# ---------------------------------------------------------------------------
# anti-unification
# ---------------------------------------------------------------------------

def _steps_of(trace) -> List[Tuple[str, Tuple[str, ...], Dict[str, object]]]:
    tr = StrategyTrace.from_doc(trace)
    return [(s.rule, s.path, dict(s.params)) for s in tr.steps]


def _merge_params(p1: Dict[str, object],
                  p2: Dict[str, object]) -> Tuple[Tuple[str, object], ...]:
    keys = set(p1) | set(p2)
    merged = {}
    for k in keys:
        v1, v2 = p1.get(k, HOLE), p2.get(k, HOLE)
        merged[k] = v1 if v1 == v2 else HOLE
    return tuple(sorted(merged.items()))


def anti_unify(t1, t2) -> Tuple[AbsStep, ...]:
    """Longest common ``(rule, path)`` subsequence of two traces, with
    differing parameters generalised to holes (classic LCS dynamic
    program; ties prefer earlier steps, so the result is deterministic)."""
    s1, s2 = _steps_of(t1), _steps_of(t2)
    n1, n2 = len(s1), len(s2)
    # lcs[i][j] = LCS length of s1[i:], s2[j:]
    lcs = [[0] * (n2 + 1) for _ in range(n1 + 1)]
    for i in range(n1 - 1, -1, -1):
        for j in range(n2 - 1, -1, -1):
            if s1[i][0] == s2[j][0] and s1[i][1] == s2[j][1]:
                lcs[i][j] = 1 + lcs[i + 1][j + 1]
            else:
                lcs[i][j] = max(lcs[i + 1][j], lcs[i][j + 1])
    out: List[AbsStep] = []
    i = j = 0
    while i < n1 and j < n2:
        if s1[i][0] == s2[j][0] and s1[i][1] == s2[j][1]:
            out.append(AbsStep(s1[i][0], s1[i][1],
                               _merge_params(s1[i][2], s2[j][2])))
            i, j = i + 1, j + 1
        elif lcs[i + 1][j] >= lcs[i][j + 1]:
            i += 1
        else:
            j += 1
    return tuple(out)


def matches(abstraction: Abstraction, trace) -> bool:
    """Does a trace instantiate the abstraction?  The abstraction's steps
    must appear as a subsequence, each step matching on (rule, path) with
    every non-hole param equal."""
    if not abstraction.steps:
        return False
    steps = _steps_of(trace)
    i = 0
    for rule_, path, params in steps:
        want = abstraction.steps[i]
        if rule_ == want.rule and path == want.path and all(
                v == HOLE or params.get(k) == v for k, v in want.params):
            i += 1
            if i == len(abstraction.steps):
                return True
    return False


def mine(records: Iterable, min_len: int = 2,
         min_support: int = 2, max_abstractions: int = 8
         ) -> List[Abstraction]:
    """Mine abstractions from tuning-cache records (or raw trace docs).

    ``records`` is a TuningCache, an iterable of cache record dicts, or an
    iterable of trace docs.  Pairwise anti-unification proposes
    generalisations of length >= ``min_len``; each is kept if at least
    ``min_support`` corpus traces instantiate it, ranked by (support,
    length) descending."""
    traces = _collect_traces(records)
    proposals: Dict[tuple, Tuple[AbsStep, ...]] = {}
    for i in range(len(traces)):
        for j in range(i + 1, len(traces)):
            g = anti_unify(traces[i], traces[j])
            if len(g) >= min_len:
                proposals.setdefault(g, g)
    scored = []
    for g in proposals.values():
        proto = Abstraction("?", g)
        support = sum(1 for t in traces if matches(proto, t))
        if support >= min_support:
            scored.append((support, len(g), g))
    # longer wins at equal support (more of the derivation captured);
    # the doc form of the steps is the deterministic tiebreak
    scored.sort(key=lambda s: (-s[0], -s[1],
                               json.dumps([a.to_doc() for a in s[2]],
                                          sort_keys=True)))
    out: List[Abstraction] = []
    for support, _, g in scored[:max_abstractions]:
        name = "mined/" + "+".join(dict.fromkeys(s.rule for s in g))
        if any(a.name == name for a in out):
            name = f"{name}#{sum(a.name.startswith(name) for a in out)}"
        out.append(Abstraction(name, g, support))
    return out


def _collect_traces(records) -> List[dict]:
    from repro.autotune.cache import TuningCache
    if isinstance(records, TuningCache):
        records = [records.get(k) for k in records.keys()]
    traces = []
    for r in records:
        if r is None:
            continue
        if isinstance(r, dict) and "steps" in r and "params" not in r:
            doc = r  # already a trace doc
        elif isinstance(r, dict):
            doc = r.get("strategy_trace")
        else:
            doc = None
        if doc and doc.get("steps"):
            traces.append(doc)
    return traces


# ---------------------------------------------------------------------------
# seeding
# ---------------------------------------------------------------------------

def seeded_order(candidates: Sequence, abstractions: Sequence[Abstraction]
                 ) -> List:
    """Stable partition of autotune Candidates: those whose derivation
    matches a mined abstraction first, everything else after, original
    order preserved within each half."""
    if not abstractions:
        return list(candidates)
    hits, rest = [], []
    for c in candidates:
        try:
            doc = c.trace_doc()
        except Exception:
            doc = None
        if doc and any(matches(a, doc) for a in abstractions):
            hits.append(c)
        else:
            rest.append(c)
    return hits + rest


# ---------------------------------------------------------------------------
# persistence (beside the tuning cache)
# ---------------------------------------------------------------------------

def abstractions_path(cache_path: str) -> str:
    root, _ = os.path.splitext(cache_path)
    return root + ".abstractions.json"


def save_abstractions(path: str, abstractions: Sequence[Abstraction]) -> str:
    """Atomic, checksummed write (repro.ft.artefacts) — a torn or
    bit-flipped abstractions file is detected and quarantined at load."""
    doc = {"version": ABSTRACTIONS_VERSION,
           "abstractions": [a.to_doc() for a in abstractions]}
    return artefacts.save_json(path, doc)


def load_abstractions(path: str) -> List[Abstraction]:
    """Read a mined-abstractions file; missing files are empty (an
    abstraction store is a cache, not a source of truth).  A CORRUPT file
    — unparseable, checksum-failed, or with malformed records — is
    quarantined to ``<path>.quarantine/`` and reported (warn-once log +
    always-on ``artefact.load_failed`` counter) instead of silently read
    as empty; the next ``mine()``+``save_abstractions`` rebuilds it."""
    doc = artefacts.load_json(path, what="strategy abstractions")
    if doc is None:
        return []
    if doc.get("version") != ABSTRACTIONS_VERSION:
        return []  # version skew: expected after an upgrade
    try:
        return [Abstraction.from_doc(a)
                for a in doc.get("abstractions", ())]
    except (ValueError, KeyError, TypeError, AttributeError) as e:
        artefacts.report_load_failure(
            path, "strategy abstractions", e,
            artefacts.quarantine(path))
        return []
