"""repro.obs — tracing, metrics, provenance, flight recorder, drift audit.

The compiler's claim ("the chosen strategy is preserved end to end") and
the serving engines' invariants ("token-identical, zero recompiles after
warm-up") are asserted by tests; this package makes them *observable* in
any run:

  trace       span tracer (thread-local stacks, monotonic clocks,
              near-zero overhead disabled) with Chrome/Perfetto JSON
              export — ``obs.enable()``, ``with obs.span("name"): ...``,
              ``obs.export_trace("trace.json")``, load in
              https://ui.perfetto.dev
  metrics     always-on process registry of counters / gauges /
              histograms (with interpolated p50/p95/p99 in every
              snapshot) — ``obs.counter("x").inc()``,
              ``obs.metrics_snapshot()``
  provenance  a record per tuned decision (kernel strategy, mesh
              placement, KV layout): inputs, predicted roofline terms,
              measured time, cache origin — ``print(obs.explain())``
  recorder    always-on flight recorder: a bounded ring of recent
              boundary events/spans/counter deltas, dumped as one JSON
              black box when a request fails, a degradation fires, a
              failure domain dies (reason ``host_lost`` — exactly one
              dump per host-loss event), or an artefact is quarantined —
              ``obs.flight_dump/flight_dumps``
  audit       roofline drift audit: baseline-relative per-key cost
              statistics plus cached-ranking re-checks that fire
              ``tune.drift`` and mark provenance ``[stale]`` —
              ``obs.drift_observe``, ``obs.audit_cache``
  report      one human-readable rendering of all of the above —
              ``python -m repro.obs.report``

The instrumented spine: ``Program.check/lower/compile`` spans, executor
cache build/hit/AOT events, autotune enumeration + measurement spans,
serving per-chunk spans, request-scoped lifecycle events (submit / admit
/ first_token / retire carry ``req_id``; decode chunks carry the
co-batched ``req_ids``), per-request latency histograms (queue wait,
TTFT, decode tok/s), KV pool occupancy gauges, and a recompile detector
that flags jit-cache growth after engine warm-up.  ``Engine.stats()`` is
the one-call summary.  See docs/observability.md.

Tracing defaults off; enable programmatically or with ``REPRO_TRACE=1``
(a path value also exports at exit).  Metrics, provenance, the recorder,
and the audit are always on — they only run at boundaries (tuning,
staging, chunk edges), never in a hot loop.  ``REPRO_FLIGHT_DIR`` makes
the recorder write its dumps as ``flight-*.json`` artefacts.
"""
from __future__ import annotations

from . import metrics, provenance, trace  # noqa: F401
from . import audit, recorder, report  # noqa: F401  (after the base trio)
from .audit import audit_cache, audit_record, auditor  # noqa: F401
from .audit import observe as drift_observe  # noqa: F401
from .provenance import annotate  # noqa: F401
from .recorder import FlightRecorder  # noqa: F401
from .recorder import clear as flight_clear  # noqa: F401
from .recorder import configure as configure_flight  # noqa: F401
from .recorder import dump as flight_dump  # noqa: F401
from .recorder import dumps as flight_dumps  # noqa: F401
from .recorder import tail as flight_tail  # noqa: F401
from .metrics import (  # noqa: F401
    MetricsRegistry, counter, gauge, histogram, registry,
)
from .metrics import export as export_metrics  # noqa: F401
from .metrics import reset as metrics_reset  # noqa: F401
from .metrics import snapshot as metrics_snapshot  # noqa: F401
from .provenance import (  # noqa: F401
    Decision, ProvenanceLog, decisions, explain, record,
)
from .provenance import clear as clear_decisions  # noqa: F401
from .provenance import log as provenance_log  # noqa: F401
from .trace import (  # noqa: F401
    Tracer, disable, enable, enabled, instant, span, to_chrome, traced,
    tracer,
)
from .trace import clear as clear_trace  # noqa: F401
from .trace import events as trace_events  # noqa: F401
from .trace import export as export_trace  # noqa: F401

# ``event`` is the structured point event: always lands in the flight
# recorder's ring, additionally in the trace when tracing is enabled
from .recorder import emit as event  # noqa: F401, E402

__all__ = [
    # tracing
    "Tracer", "tracer", "enable", "disable", "enabled", "span", "traced",
    "instant", "event", "trace_events", "clear_trace", "to_chrome",
    "export_trace",
    # metrics
    "MetricsRegistry", "registry", "counter", "gauge", "histogram",
    "metrics_snapshot", "metrics_reset", "export_metrics",
    # provenance
    "Decision", "ProvenanceLog", "record", "decisions", "explain",
    "annotate", "clear_decisions", "provenance_log",
    # flight recorder
    "FlightRecorder", "flight_dump", "flight_dumps", "flight_tail",
    "flight_clear", "configure_flight",
    # drift audit
    "auditor", "drift_observe", "audit_record", "audit_cache",
    "metrics", "provenance", "trace", "recorder", "audit", "report",
]
