"""Production meshes.  Functions (never module-level constants) so importing
this module does not touch jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
