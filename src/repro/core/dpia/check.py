"""SCIR interference control (paper section 3.2, Fig. 3) as a checker.

The substructural discipline we enforce on the AST:

  * every ``parfor`` / parallel ``mapI`` body must be *passive* apart from the
    acceptor parameter it is handed (the paper's ``->p`` requirement on the
    loop body) — this is the data-race-freedom guarantee;
  * parallel functional ``map`` bodies must not capture active identifiers;
  * variable occurrences are classified passively (``exp``/``.2`` reads) or
    actively (``acc``/``.1`` writes) following the Passify/Activate rules.

``check(phrase)`` = type check (phrases.type_of) + race-freedom.  Violations
raise :class:`RaceError` with the offending identifiers.
"""
from __future__ import annotations

from typing import Dict, Set

from . import phrases as P
from .types import AccT, ExpT, Idx, VarT


class RaceError(Exception):
    pass


PASSIVE, ACTIVE = "P", "A"


def _merge(into: Dict[str, Set[str]], frm: Dict[str, Set[str]]) -> None:
    for k, v in frm.items():
        into.setdefault(k, set()).update(v)


def uses(p: P.Phrase) -> Dict[str, Set[str]]:  # noqa: C901
    """Free identifier occurrences classified as passive/active."""
    out: Dict[str, Set[str]] = {}

    def go(q: P.Phrase) -> None:
        if isinstance(q, P.Var):
            if isinstance(q.t, ExpT):
                out.setdefault(q.name, set()).add(PASSIVE)
            else:  # acc / var / comm / fn-typed bare identifiers
                out.setdefault(q.name, set()).add(ACTIVE)
            return
        if isinstance(q, P.ExpPart):
            if isinstance(q.v, P.VView):
                go(q.v.exp)
            else:
                out.setdefault(q.v.name, set()).add(PASSIVE)
            return
        if isinstance(q, P.AccPart):
            if isinstance(q.v, P.VView):
                go(q.v.acc)
            else:
                out.setdefault(q.v.name, set()).add(ACTIVE)
            return
        if isinstance(q, P.Map):
            x = P.Var(P.fresh("x"), ExpT(_elem(q.e)))
            _merge(out, _without(uses(q.f(x)), {x.name}))
            go(q.e)
            return
        if isinstance(q, P.Reduce):
            x = P.Var(P.fresh("x"), ExpT(_elem(q.e)))
            acc = P.Var(P.fresh("acc"), P.type_of(q.init))
            _merge(out, _without(uses(q.f(x, acc)), {x.name, acc.name}))
            go(q.init)
            go(q.e)
            return
        if isinstance(q, P.New):
            v = P.Var(P.fresh("v"), VarT(q.d))
            _merge(out, _without(uses(q.f(v)), {v.name}))
            return
        if isinstance(q, P.For):
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            _merge(out, _without(uses(q.f(i)), {i.name}))
            return
        if isinstance(q, P.ParFor):
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            o = P.Var(P.fresh("o"), AccT(q.d))
            _merge(out, _without(uses(q.f(i, o)), {i.name, o.name}))
            go(q.a)
            return
        if isinstance(q, P.MapI):
            x = P.Var(P.fresh("x"), ExpT(q.d1))
            o = P.Var(P.fresh("o"), AccT(q.d2))
            _merge(out, _without(uses(q.f(x, o)), {x.name, o.name}))
            go(q.e)
            go(q.a)
            return
        if isinstance(q, P.ReduceI):
            x = P.Var(P.fresh("x"), ExpT(q.d1))
            y = P.Var(P.fresh("y"), ExpT(q.d2))
            o = P.Var(P.fresh("o"), AccT(q.d2))
            r = P.Var(P.fresh("r"), ExpT(q.d2))
            _merge(out, _without(uses(q.f(x, y, o)), {x.name, y.name, o.name}))
            _merge(out, _without(uses(q.k(r)), {r.name}))
            go(q.init)
            go(q.e)
            return
        # structural recursion over plain children
        for name in ("e", "a", "b", "i", "v", "c1", "c2", "init"):
            child = getattr(q, name, None)
            if isinstance(child, P.Phrase):
                go(child)

    go(p)
    return out


def _without(u: Dict[str, Set[str]], names: Set[str]) -> Dict[str, Set[str]]:
    return {k: v for k, v in u.items() if k not in names}


def _elem(e: P.Phrase):
    from .types import Arr
    d = P.exp_data(e)
    assert isinstance(d, Arr)
    return d.elem


def _actives(u: Dict[str, Set[str]]) -> Set[str]:
    return {k for k, v in u.items() if ACTIVE in v}


def check_race_free(p: P.Phrase) -> None:  # noqa: C901
    """Verify the parfor/parallel-map passivity discipline recursively."""
    if isinstance(p, P.ParFor):
        i = P.Var(P.fresh("i"), ExpT(Idx(p.n)))
        o = P.Var(P.fresh("o"), AccT(p.d))
        body = p.f(i, o)
        bad = _actives(_without(uses(body), {i.name})) - {o.name}
        if bad:
            raise RaceError(
                f"parfor[{p.level}] body actively uses {sorted(bad)}; a "
                f"parallel loop body may only write through its own acceptor")
        check_race_free(body)
        return
    if isinstance(p, P.MapI):
        x = P.Var(P.fresh("x"), ExpT(p.d1))
        o = P.Var(P.fresh("o"), AccT(p.d2))
        body = p.f(x, o)
        bad = _actives(_without(uses(body), {x.name})) - {o.name}
        if bad:
            raise RaceError(
                f"mapI[{p.level}] body actively uses {sorted(bad)}")
        check_race_free(body)
        return
    if isinstance(p, P.Map) and p.level.kind not in ("seq",):
        x = P.Var(P.fresh("x"), ExpT(_elem(p.e)))
        body = p.f(x)
        bad = _actives(_without(uses(body), {x.name}))
        if bad:
            raise RaceError(f"parallel map body actively uses {sorted(bad)}")
        check_race_free(body)
        check_race_free(p.e)
        return
    if isinstance(p, P.Reduce):
        x = P.Var(P.fresh("x"), ExpT(_elem(p.e)))
        acc = P.Var(P.fresh("acc"), P.type_of(p.init))
        check_race_free(p.f(x, acc))
        check_race_free(p.init)
        check_race_free(p.e)
        return
    if isinstance(p, P.New):
        check_race_free(p.f(P.Var(P.fresh("v"), VarT(p.d))))
        return
    if isinstance(p, P.For):
        check_race_free(p.f(P.Var(P.fresh("i"), ExpT(Idx(p.n)))))
        return
    if isinstance(p, P.ReduceI):
        x = P.Var(P.fresh("x"), ExpT(p.d1))
        y = P.Var(P.fresh("y"), ExpT(p.d2))
        o = P.Var(P.fresh("o"), AccT(p.d2))
        check_race_free(p.f(x, y, o))
        check_race_free(p.k(P.Var(P.fresh("r"), ExpT(p.d2))))
        check_race_free(p.init)
        check_race_free(p.e)
        return
    for name in ("e", "a", "b", "i", "v", "c1", "c2", "init"):
        child = getattr(p, name, None)
        if isinstance(child, P.Phrase):
            check_race_free(child)


def check(p: P.Phrase) -> None:
    """Full check: well-typed + race free."""
    P.type_of(p)
    check_race_free(p)
