"""Allocation hoisting out of parallel loops (paper section 6.4).

OpenCL (and Pallas) require temporary buffers to be declared up front rather
than allocated inside kernels.  This pass lifts every non-register ``new``
nested inside ``parfor`` loops to the top of the program, multiplying its
extent by the iteration counts of the enclosing parallel loops, and hands the
loop body a *view* (``VView``) of its private slice — exactly the paper's
transformation (their shaded-substitution example).

Two deterministic passes over the HOAS tree, keyed by structural paths so the
collect pass and the rebuild pass agree on which ``new`` is which.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from . import phrases as P
from .types import AccT, Arr, DataType, ExpT, Idx, VarT


def _probe(t) -> P.Var:
    return P.Var(P.fresh("probe"), t)


def collect(cmd: P.Phrase,
            spaces: Tuple[str, ...] = (P.HBM, P.VMEM)) -> Dict[str, Tuple[DataType, str]]:
    """Map structural-path -> (hoisted full data type, space) for every
    ``new`` in one of ``spaces`` under at least one ``parfor``."""
    items: Dict[str, Tuple[DataType, str]] = {}

    def go(q: P.Phrase, key: str, loop_ns: List[int]) -> None:
        if isinstance(q, P.SeqC):
            go(q.c1, key + "L", loop_ns)
            go(q.c2, key + "R", loop_ns)
        elif isinstance(q, P.New):
            if q.space in spaces and loop_ns:
                d_full: DataType = q.d
                for n in reversed(loop_ns):
                    d_full = Arr(n, d_full)
                items[key] = (d_full, q.space)
            go(q.f(_probe(VarT(q.d))), key + "N", loop_ns)
        elif isinstance(q, P.For):
            go(q.f(_probe(ExpT(Idx(q.n)))), key + "F", loop_ns)
        elif isinstance(q, P.ParFor):
            go(q.f(_probe(ExpT(Idx(q.n))), _probe(AccT(q.d))),
               key + "P", loop_ns + [q.n])
        elif isinstance(q, (P.MapI, P.ReduceI)):
            from . import stage2
            go(stage2.expand(q), key, loop_ns)
        elif isinstance(q, (P.Skip, P.Assign)):
            pass
        else:
            raise TypeError(f"hoist.collect: not a command {type(q).__name__}")

    go(cmd, "", [])
    return items


def hoist(cmd: P.Phrase,
          spaces: Tuple[str, ...] = (P.HBM, P.VMEM)) -> P.Phrase:
    """Lift parfor-nested allocations to the top (paper section 6.4)."""
    items = collect(cmd, spaces)
    if not items:
        return cmd
    keys = list(items)

    def rebuild(q: P.Phrase, key: str, idx_stack, handles) -> P.Phrase:
        if isinstance(q, P.SeqC):
            return P.SeqC(rebuild(q.c1, key + "L", idx_stack, handles),
                          rebuild(q.c2, key + "R", idx_stack, handles))
        if isinstance(q, P.New):
            if key in items:
                h = handles[key]
                acc: P.Phrase = P.AccPart(h)
                exp: P.Phrase = P.ExpPart(h)
                for i in idx_stack:
                    acc = P.IdxAcc(acc, i)
                    exp = P.IdxE(exp, i)
                vv = P.VView(acc, exp)
                return rebuild(q.f(vv), key + "N", idx_stack, handles)
            return P.New(q.d,
                         lambda v: rebuild(q.f(v), key + "N", idx_stack,
                                           handles),
                         space=q.space)
        if isinstance(q, P.For):
            return P.For(q.n,
                         lambda i: rebuild(q.f(i), key + "F", idx_stack,
                                           handles),
                         unroll=q.unroll)
        if isinstance(q, P.ParFor):
            return P.ParFor(
                q.n, q.d, q.a,
                lambda i, o: rebuild(q.f(i, o), key + "P",
                                     idx_stack + [i], handles),
                level=q.level)
        if isinstance(q, (P.MapI, P.ReduceI)):
            from . import stage2
            return rebuild(stage2.expand(q), key, idx_stack, handles)
        return q

    def mk(k: int, handles) -> P.Phrase:
        if k == len(keys):
            return rebuild(cmd, "", [], handles)
        key = keys[k]
        d_full, space = items[key]
        return P.New(d_full, lambda h: mk(k + 1, {**handles, key: h}),
                     space=space)

    return mk(0, {})
