"""Analytical cost model for DPIA strategy candidates.

Ranks candidates WITHOUT executing them: a structural walk over the
functional expression collects FLOPs, HBM traffic (write-once model, the
same discipline as ``repro.analysis.hlo_counter``), the per-grid-step VMEM
working set, and the loop structure (grid launches vs sequential trip
counts).  A roofline combine (cf. benchmarks/roofline.py) turns the counts
into predicted seconds:

    t = max(flops / peak, hbm_bytes / bw)
        + grid_steps * grid_overhead + loop_iters * loop_overhead
        + ici_bytes / ici_bw + collective_steps * collective_launch
        + vmem-overflow penalty

Mesh-level strategies are costed per *device*: a ``map[mesh(ax)]`` charges
one shard's body (wall clock, not the sum over shards) and a
``reduce[mesh(ax)]`` charges one ring all-reduce (2(n-1) hops x result
bytes over the interconnect) — so the ranking trades compute-per-device
against collective latency and refuses to shard problems too small to
amortise the all-reduce.

Absolute numbers are not the point — *order* is.  The model needs exactly
the properties the search relies on: monotone in problem size, punishes
fully-sequential strategies (huge trip counts), punishes over-fine blocking
(launch overhead), and rejects blocks whose working set overflows VMEM.

``xla_cost`` is the optional refinement: lower a compiled candidate and run
the scan-aware HLO counter over the real module text.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.core.dpia import phrases as P
from repro.core.dpia.types import Arr, Pair, Vec, dtype_of, is_numeric, shape_of

_DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
                "int32": 4, "int64": 8, "int16": 2, "int8": 1, "bool": 1}


@dataclass(frozen=True)
class HwModel:
    """Roofline parameters.  Defaults approximate one TPU core; only the
    *relative* magnitudes matter for ranking."""
    peak_flops: float = 1.0e12       # FLOP/s
    hbm_bw: float = 1.0e11           # bytes/s
    vmem_bytes: float = 16 * 2 ** 20  # per-step working-set budget
    grid_overhead_s: float = 2.0e-6  # per grid step (kernel launch / dispatch)
    loop_overhead_s: float = 5.0e-8  # per sequential loop iteration
    vmem_penalty_s: float = 1.0e-3   # added per x of working-set overflow
    ici_bw: float = 5.0e10           # inter-chip bytes/s (collective traffic)
    collective_launch_s: float = 5.0e-6  # per collective step (ring hop)
    hbm_capacity: float = 16e9       # resident-bytes budget (KV planning)


DEFAULT_HW = HwModel()

# Per-platform presets (ROADMAP PR 1 follow-up: per-backend HW models).
# The cpu preset is the tpu model uniformly slowed 5x — identical *ratios*,
# so single-device strategy rankings are platform-stable — but with a host
# RAM capacity; the gpu preset has genuinely different balance (higher
# flops-per-byte) and an 80 GB HBM budget.  The capacity term is what the
# KV-layout planner (:func:`pick_kv_layout`) ranks against.
HW_PRESETS = {
    "tpu": DEFAULT_HW,
    "cpu": HwModel(peak_flops=2.0e11, hbm_bw=2.0e10,
                   grid_overhead_s=1.0e-5, loop_overhead_s=2.5e-7,
                   ici_bw=1.0e10, collective_launch_s=2.5e-5,
                   hbm_capacity=64e9),
    "gpu": HwModel(peak_flops=1.0e13, hbm_bw=2.0e12,
                   grid_overhead_s=3.0e-6, loop_overhead_s=1.0e-7,
                   ici_bw=2.0e11, collective_launch_s=3.0e-6,
                   hbm_capacity=80e9),
}


def hw_model(platform: Optional[str] = None) -> HwModel:
    """The HwModel preset for ``platform`` (``jax.default_backend()`` when
    None); unknown platforms get the TPU-shaped default."""
    if platform is None:
        import jax
        platform = jax.default_backend()
    return HW_PRESETS.get(platform, DEFAULT_HW)


@dataclass
class CostEstimate:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    vmem_peak: float = 0.0     # largest per-grid-step working set
    grid_steps: float = 0.0
    loop_iters: float = 0.0
    ici_bytes: float = 0.0     # bytes crossing the mesh interconnect
    collective_steps: float = 0.0  # latency-bound collective hops

    def __add__(self, o: "CostEstimate") -> "CostEstimate":
        return CostEstimate(self.flops + o.flops,
                            self.hbm_bytes + o.hbm_bytes,
                            max(self.vmem_peak, o.vmem_peak),
                            self.grid_steps + o.grid_steps,
                            self.loop_iters + o.loop_iters,
                            self.ici_bytes + o.ici_bytes,
                            self.collective_steps + o.collective_steps)

    def scaled(self, s: float) -> "CostEstimate":
        return CostEstimate(self.flops * s, self.hbm_bytes * s,
                            self.vmem_peak, self.grid_steps * s,
                            self.loop_iters * s, self.ici_bytes * s,
                            self.collective_steps * s)

    def seconds(self, hw: HwModel = DEFAULT_HW) -> float:
        t = max(self.flops / hw.peak_flops, self.hbm_bytes / hw.hbm_bw)
        t += self.grid_steps * hw.grid_overhead_s
        t += self.loop_iters * hw.loop_overhead_s
        t += self.ici_bytes / hw.ici_bw
        t += self.collective_steps * hw.collective_launch_s
        if self.vmem_peak > hw.vmem_bytes:
            t += hw.vmem_penalty_s * (self.vmem_peak / hw.vmem_bytes)
        return t


def _bytes_of(d) -> float:
    shp = shape_of(d)
    n = 1.0
    for s in shp:
        n *= s
    if isinstance(d, Pair):
        return _bytes_of(d.fst) + _bytes_of(d.snd)
    if isinstance(d, Arr):
        return d.n * _bytes_of(d.elem)
    return n * _DTYPE_BYTES.get(dtype_of(d) if is_numeric(d) else "float32", 4)


def _elems_of(d) -> float:
    if isinstance(d, Pair):
        return _elems_of(d.fst) + _elems_of(d.snd)
    if isinstance(d, Arr):
        return d.n * _elems_of(d.elem)
    if isinstance(d, Vec):
        return float(d.n)
    return 1.0


def estimate(expr: P.Phrase) -> CostEstimate:  # noqa: C901
    """Cost of evaluating ``expr`` once (structural, no execution)."""
    if isinstance(expr, (P.Var,)):
        # reading an argument / bound block: charge its HBM bytes once here
        d = P.exp_data(expr)
        return CostEstimate(hbm_bytes=_bytes_of(d))
    if isinstance(expr, P.Lit):
        return CostEstimate(hbm_bytes=_bytes_of(expr.d))
    if isinstance(expr, P.UnOp):
        d = P.exp_data(expr)
        return estimate(expr.e) + CostEstimate(
            flops=_elems_of(d), hbm_bytes=_bytes_of(d))
    if isinstance(expr, P.BinOp):
        d = P.exp_data(expr)
        return (estimate(expr.a) + estimate(expr.b)
                + CostEstimate(flops=_elems_of(d), hbm_bytes=_bytes_of(d)))
    if isinstance(expr, P.Map):
        d = P.exp_data(expr.e)
        assert isinstance(d, Arr)
        x = P.Var(P.fresh("c"), P.ExpT(d.elem))
        body = estimate(expr.f(x))
        feed = estimate(expr.e)
        if expr.level.kind == "mesh":
            # SPMD over d.n shards: every device reads 1/n of the feed and
            # runs the per-shard body ONCE — wall clock is the per-device
            # cost, not the sum over shards (that is the whole point of the
            # mesh placement; the collective price lands on the mesh Reduce)
            return feed.scaled(1.0 / d.n) + body
        total = feed + body.scaled(d.n)
        if expr.level.kind == "grid":
            step_ws = body.hbm_bytes + _bytes_of(d.elem)
            return replace(total,
                           grid_steps=total.grid_steps + d.n,
                           vmem_peak=max(total.vmem_peak, step_ws))
        if expr.level.kind in ("seq", "par"):
            return replace(total, loop_iters=total.loop_iters + d.n)
        # lanes: one vectorised step, no per-elem loop
        return total
    if isinstance(expr, P.Reduce):
        d = P.exp_data(expr.e)
        assert isinstance(d, Arr)
        di = P.exp_data(expr.init)
        x = P.Var(P.fresh("c"), P.ExpT(d.elem))
        a = P.Var(P.fresh("c"), P.ExpT(di))
        body = estimate(expr.f(x, a))
        feed = estimate(expr.e) + estimate(expr.init)
        if expr.level.kind == "mesh":
            # the partials live one-per-shard; combining them is a single
            # ring all-reduce of the result value: 2(n-1) hops, each moving
            # the result bytes over the interconnect (latency-bound for the
            # scalar reductions, bandwidth-bound for block results)
            hops = 2.0 * max(d.n - 1, 1)
            return feed + CostEstimate(ici_bytes=hops * _bytes_of(di),
                                       collective_steps=hops)
        total = feed + body.scaled(d.n)
        if expr.level.kind in ("seq", "par"):
            return replace(total, loop_iters=total.loop_iters + d.n)
        return total
    if isinstance(expr, P.FullReduce):
        d = P.exp_data(expr.e)
        return estimate(expr.e) + CostEstimate(flops=_elems_of(d))
    if isinstance(expr, P.DotBlock):
        da = P.exp_data(expr.a)
        db = P.exp_data(expr.b)
        sa, sb = shape_of(da), shape_of(db)
        contract = sa[-1]
        out_elems = 1.0
        if len(sa) == 2:
            out_elems *= sa[0]
        if len(sb) == 2:
            out_elems *= sb[1]
        dout = P.exp_data(expr)
        return (estimate(expr.a) + estimate(expr.b)
                + CostEstimate(flops=2.0 * out_elems * contract,
                               hbm_bytes=_bytes_of(dout)))
    if isinstance(expr, P.Zip):
        return estimate(expr.a) + estimate(expr.b)
    if isinstance(expr, (P.Split, P.Join, P.Transpose, P.AsVector,
                         P.AsScalar, P.Fst, P.Snd)):
        return estimate(expr.e)  # pure re-views: free
    if isinstance(expr, P.PairE):
        return estimate(expr.a) + estimate(expr.b)
    if isinstance(expr, P.IdxE):
        return estimate(expr.e).scaled(0.0) + CostEstimate(
            hbm_bytes=_bytes_of(P.exp_data(expr)))
    if isinstance(expr, P.ToMem):
        inner = estimate(expr.e)
        if expr.space == P.VMEM:
            return replace(inner, vmem_peak=max(
                inner.vmem_peak, _bytes_of(P.exp_data(expr))))
        return inner
    raise TypeError(f"cost.estimate: unhandled phrase {type(expr).__name__}")


def predicted_seconds(expr: P.Phrase, hw: HwModel = DEFAULT_HW) -> float:
    return estimate(expr).seconds(hw)


# ---------------------------------------------------------------------------
# serving KV-layout roofline (dense vs paged) — the HBM-bytes term
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KvLayoutCost:
    """HBM view of one serving KV layout at one engine shape.

    ``resident_bytes`` is the cache's standing footprint (what the paged
    layout shrinks: the pool is sized for expected occupancy, not
    ``slots * max_seq``); ``step_hbm_bytes`` is the attention-side traffic
    of ONE decode step across all slots/layers (what the dense layout wins:
    the paged gather materialises a per-slot view, roughly doubling the
    read traffic)."""
    layout: str
    resident_bytes: float
    step_hbm_bytes: float

    def seconds(self, hw: HwModel = DEFAULT_HW) -> float:
        """Predicted decode-step seconds, with a capacity penalty that
        dominates once the resident cache blows the HBM budget — a layout
        that does not fit is not a candidate, it is a spill."""
        t = self.step_hbm_bytes / hw.hbm_bw
        if self.resident_bytes > hw.hbm_capacity:
            t += hw.vmem_penalty_s * (self.resident_bytes
                                      / hw.hbm_capacity) * 1e3
        return t


def kv_layout_cost(layout: str, *, slots: int, max_seq: int, kv_heads: int,
                   head_dim: int, layers: int, dtype_bytes: int = 4,
                   block_size: int = 16,
                   expected_seq: Optional[int] = None) -> KvLayoutCost:
    """The KV-layout roofline point for one engine shape.

    ``expected_seq`` is the anticipated MEAN occupied positions per slot
    (prompt + decode budget); it defaults to ``max_seq // 2`` — the paged
    pool is sized for it (rounded up to whole blocks per slot), while the
    dense cache always pays ``max_seq``."""
    if layout not in ("dense", "paged"):
        raise ValueError(f"unknown kv layout {layout!r}")
    per_pos = 2.0 * layers * kv_heads * head_dim * dtype_bytes  # k + v
    step = slots * max_seq * per_pos       # masked full-view read per token
    if layout == "dense":
        return KvLayoutCost("dense", slots * max_seq * per_pos, step)
    expected = max(1, int(expected_seq if expected_seq else max_seq // 2))
    blocks_per_slot = -(-min(expected, max_seq) // block_size)
    resident = slots * blocks_per_slot * block_size * per_pos
    return KvLayoutCost("paged", resident, 2.0 * step)  # + gather copy


# ---------------------------------------------------------------------------
# HLO-derived refinement (reuses the scan-aware counter)
# ---------------------------------------------------------------------------

def xla_cost(fn, args, hw: HwModel = DEFAULT_HW) -> Optional[float]:
    """Roofline seconds from the candidate's *compiled* HLO module, using
    repro.analysis.hlo_counter (scan-aware FLOPs / traffic).  Returns None
    when lowering fails (e.g. an exotic backend)."""
    import jax

    from repro.analysis.hlo_counter import analyze_text
    try:
        text = jax.jit(fn).lower(*args).compile().as_text()
    except Exception:
        return None
    t = analyze_text(text)
    return max(t.flops / hw.peak_flops, t.bytes / hw.hbm_bw)
