"""Assigned architecture configs (``--arch <id>``), exact per the assignment.

Each module defines ``config()`` (full size) and ``smoke_config()`` (reduced,
same family, for CPU tests).  ``REGISTRY`` maps arch id -> module.
"""
from importlib import import_module

ARCH_IDS = [
    "stablelm_1_6b", "qwen1_5_32b", "yi_9b", "qwen3_4b", "zamba2_2_7b",
    "dbrx_132b", "grok_1_314b", "chameleon_34b", "rwkv6_1_6b",
    "musicgen_large",
]

# public names with dashes/dots as given in the assignment
ALIASES = {
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen1.5-32b": "qwen1_5_32b",
    "yi-9b": "yi_9b",
    "qwen3-4b": "qwen3_4b",
    "zamba2-2.7b": "zamba2_2_7b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
    "chameleon-34b": "chameleon_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "musicgen-large": "musicgen_large",
}


def get(arch: str):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return import_module(f"repro.configs.{mod_name}")


def config(arch: str, **overrides):
    import dataclasses
    cfg = get(arch).config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(arch: str):
    return get(arch).smoke_config()
