"""Roofline drift audit: the cost model as a continuously validated component.

The autotuner's claim is that the analytic roofline *ranks* strategies
correctly — absolute seconds are explicitly not the point (see
``repro.autotune.cost``), order is.  Nothing checked that claim after
tuning time: a kernel can slow down under memory pressure, a cache record
can outlive the hardware it was measured on, and the serving engine would
keep trusting the stale ranking.  This module closes the loop two ways:

**Ratio drift** (:meth:`DriftAuditor.observe`) — streaming per-key
statistics over ``log(measured / predicted)`` (or ``log(measured)`` when
there is no prediction, e.g. per-chunk wall times).  Because the roofline
is only trusted for *order*, the audit is baseline-relative: the first
``min_samples`` observations establish the key's own baseline ratio, and
only a later shift beyond ``tolerance``x of that baseline fires — a CPU
run under a TPU-shaped HwModel never false-alarms on the constant offset.

**Ranking drift** (:meth:`DriftAuditor.audit_record`) — for tuning-cache
records that carry measured ``timings`` per candidate, rebuild each
candidate (``space.candidate_from_params``), re-rank analytically under
the current ``HwModel``, and compare the predicted argmin against the
measured argmin.  Disagreement means the model would pick the wrong
strategy today.

Either firing emits a ``tune.drift`` event + counter, lands in the flight
recorder ring, and annotates the decision's provenance entry ``stale``
(origin suffix + note suggesting a re-tune) so ``obs.explain()`` shows it.
Each key fires once per process (per drift kind) — drift is a state, not a
once-per-observation alarm.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

from . import metrics, provenance, recorder

__all__ = ["DriftAuditor", "auditor", "observe", "audit_record",
           "audit_cache", "snapshot", "reset"]

_TINY = 1e-12


class _KeyStats:
    """Welford accumulator over log-ratios, plus the baseline machinery."""
    __slots__ = ("n", "mean", "m2", "baseline", "fired", "last")

    def __init__(self):
        self.n = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.baseline: Optional[float] = None
        self.fired = False
        self.last = 0.0

    def push(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)
        self.last = x

    @property
    def std(self) -> float:
        return math.sqrt(self.m2 / (self.n - 1)) if self.n > 1 else 0.0

    def to_doc(self) -> dict:
        return {"n": self.n, "mean_log": self.mean, "std_log": self.std,
                "baseline_log": self.baseline, "fired": self.fired,
                "drift_x": (math.exp(self.last - self.baseline)
                            if self.baseline is not None else None)}


class DriftAuditor:
    """Per-key drift statistics + the ``tune.drift`` firing policy."""

    def __init__(self, min_samples: int = 8, tolerance: float = 2.0):
        self.min_samples = min_samples
        self.tolerance = tolerance        # x-factor beyond baseline to fire
        self._stats: Dict[str, _KeyStats] = {}
        self._rank_fired: Dict[str, dict] = {}
        self._lock = threading.Lock()

    # -- ratio drift ---------------------------------------------------------

    def observe(self, key: str, measured_s: float,
                predicted_s: Optional[float] = None) -> Optional[float]:
        """Feed one measurement; returns the drift factor (measured vs the
        key's own baseline) once a baseline exists, else None.  Fires
        ``tune.drift`` (kind ``ratio``) the first time the factor leaves
        ``[1/tolerance, tolerance]``."""
        if measured_s <= 0:
            return None
        r = measured_s / predicted_s if predicted_s else measured_s
        x = math.log(max(r, _TINY))
        with self._lock:
            st = self._stats.get(key)
            if st is None:
                st = self._stats[key] = _KeyStats()
            st.push(x)
            if st.baseline is None:
                if st.n >= self.min_samples:
                    st.baseline = st.mean
                return None
            drift = math.exp(x - st.baseline)
            should_fire = (not st.fired
                           and (drift > self.tolerance
                                or drift < 1.0 / self.tolerance))
            if should_fire:
                st.fired = True
        if should_fire:
            self._fire("ratio", key, drift_x=round(drift, 3),
                       n=st.n, note=f"measured cost drifted {drift:.2f}x "
                                    f"from its baseline")
        return drift

    # -- ranking drift -------------------------------------------------------

    def audit_record(self, kernel: str, key: str, record: dict,
                     hw=None) -> Optional[dict]:
        """Re-rank a tuning-cache record's measured candidates analytically;
        fire ``tune.drift`` (kind ``ranking``) when the roofline's best is
        not the measured best.  Returns a finding dict, or None when the
        record has fewer than two timed candidates (nothing to mis-rank)."""
        timings = record.get("timings") or {}
        if len(timings) < 2:
            return None
        from repro.autotune import cost as cost_mod
        from repro.autotune import space as space_mod
        if hw is None:
            hw = cost_mod.hw_model()
        shape = {k: v for k, v in (record.get("shape") or {}).items()}
        predicted: Dict[str, float] = {}
        for pk in timings:
            try:
                cand = space_mod.candidate_from_params(
                    kernel, _parse_params_key(pk), **shape)
                expr, _ = cand.build()
                predicted[pk] = cost_mod.predicted_seconds(expr, hw)
            except Exception:
                predicted[pk] = float("inf")
        if all(math.isinf(s) for s in predicted.values()):
            return None
        meas_best = min(timings, key=lambda pk: (timings[pk], pk))
        pred_best = min(predicted, key=lambda pk: (predicted[pk], pk))
        agree = meas_best == pred_best
        # how much slower the model's pick actually ran, measured
        slowdown = timings[pred_best] / max(timings[meas_best], _TINY)
        finding = {"key": key, "kernel": kernel, "agree": agree,
                   "measured_best": meas_best, "predicted_best": pred_best,
                   "slowdown_x": round(slowdown, 3),
                   "n_candidates": len(timings)}
        if not agree:
            with self._lock:
                first = key not in self._rank_fired
                self._rank_fired[key] = finding
            if first:
                self._fire("ranking", key, kernel=kernel,
                           predicted_best=pred_best,
                           measured_best=meas_best,
                           slowdown_x=finding["slowdown_x"],
                           note=f"roofline prefers [{pred_best}] but "
                                f"[{meas_best}] measured "
                                f"{slowdown:.2f}x faster")
        return finding

    def audit_cache(self, cache, hw=None) -> List[dict]:
        """Run :meth:`audit_record` over every record in a TuningCache that
        carries timings; returns the findings (agreeing ones included)."""
        findings = []
        for key in cache.keys():
            rec = cache.get(key)
            if not rec:
                continue
            kernel = rec.get("kernel") or key.split("|", 1)[0]
            f = self.audit_record(kernel, key, rec, hw=hw)
            if f is not None:
                findings.append(f)
        return findings

    # -- firing + export -----------------------------------------------------

    def _fire(self, kind: str, key: str, *, note: str, **detail) -> None:
        metrics.counter("tune.drift").inc()
        recorder.emit("tune.drift", kind=kind, key=key, **detail)
        # mark the provenance entry stale (suffix the origin once)
        dec = provenance.get(key)
        if dec is not None and not dec.origin.endswith("[stale]"):
            provenance.annotate(
                key, origin=dec.origin + "[stale]",
                note=(dec.note + "; " if dec.note else "")
                     + f"drift({kind}): {note} — consider re-tuning")

    def snapshot(self) -> dict:
        """JSON-able per-key stats + ranking findings (dump/report food)."""
        with self._lock:
            return {
                "tolerance": self.tolerance,
                "min_samples": self.min_samples,
                "keys": {k: st.to_doc() for k, st in self._stats.items()},
                "ranking": {k: dict(f) for k, f in self._rank_fired.items()},
                "fired": sum(1 for st in self._stats.values() if st.fired)
                + len(self._rank_fired),
            }

    def reset(self) -> None:
        with self._lock:
            self._stats.clear()
            self._rank_fired.clear()


def _parse_params_key(pk: str) -> Dict[str, object]:
    """Invert ``space.params_key``: ``"bk=128,bm=64"`` -> typed dict."""
    params: Dict[str, object] = {}
    if not pk:
        return params
    for part in pk.split(","):
        k, _, v = part.partition("=")
        params[k] = _coerce(v)
    return params


def _coerce(v: str):
    if v == "None":
        return None
    if v == "True":
        return True
    if v == "False":
        return False
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


# ---------------------------------------------------------------------------
# module-level singleton + convenience API
# ---------------------------------------------------------------------------

_auditor: Optional[DriftAuditor] = None
_auditor_lock = threading.Lock()


def auditor() -> DriftAuditor:
    """The process-wide drift auditor."""
    global _auditor
    with _auditor_lock:
        if _auditor is None:
            _auditor = DriftAuditor()
        return _auditor


def observe(key: str, measured_s: float,
            predicted_s: Optional[float] = None) -> Optional[float]:
    return auditor().observe(key, measured_s, predicted_s)


def audit_record(kernel: str, key: str, record: dict, hw=None):
    return auditor().audit_record(kernel, key, record, hw=hw)


def audit_cache(cache, hw=None) -> List[dict]:
    return auditor().audit_cache(cache, hw=hw)


def snapshot() -> dict:
    return auditor().snapshot()


def reset() -> None:
    auditor().reset()
