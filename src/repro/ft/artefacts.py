"""Self-healing JSON artefact stores: checksummed atomic writes, verified
loads, and quarantine instead of silent loss.

The tree persists several caches as JSON — the tuning cache
(``repro.autotune.cache``), the AOT program store
(``repro.compiler.executors``), mined strategy abstractions
(``repro.strategy.mine``).  They are *caches*: a corrupt file must never
abort a load.  But the pre-PR-8 behaviour — swallow ``OSError/ValueError``
and return empty — destroyed the evidence and the signal: a bit-flipped
tuning cache silently re-tuned forever.  This module gives every artefact
store the same discipline:

  * **checksummed writes** — :func:`save_json` embeds a ``checksum`` field
    (sha256 over the canonical JSON of the rest) and writes atomically
    (tmp + rename), so torn writes and bit flips are *detectable*;
  * **verified loads** — :func:`load_json` re-derives the checksum
    (legacy files without one still load) and treats parse failures,
    type mismatches, and checksum mismatches as corruption;
  * **quarantine, not deletion** — a corrupt file is moved aside into a
    ``<path>.quarantine/`` directory (:func:`quarantine`) so the next
    writer rebuilds a clean file while the evidence survives for
    inspection;
  * **a visible signal** — every load failure fires the always-on
    ``artefact.load_failed`` obs counter + a structured event naming the
    path, and a warn-once ``logging`` warning per path (the PR 6 pattern:
    the event stream sees every occurrence, the log warns once).

Missing files are *not* failures — they return None silently (a cold
cache is the normal first-run state).

Append-only journals (:func:`append_record` / :func:`read_records`) get the
same discipline per *record*: each JSONL line embeds its own checksum, so a
reader can recover a crash-torn journal to the last complete record — the
torn tail is the expected crash artefact, and recovery IS dropping it (no
quarantine; the signal is the ``artefact.journal_torn`` counter + event).
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import tempfile
import threading
from typing import Optional

from repro import obs
from repro.testing import faults

__all__ = ["save_json", "load_json", "quarantine", "report_load_failure",
           "append_record", "read_records", "CHECKSUM_FIELD"]

log = logging.getLogger("repro.ft.artefacts")

CHECKSUM_FIELD = "checksum"

_warned_paths: set = set()
_warn_lock = threading.Lock()


def _digest(payload: dict) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      default=str)
    return "sha256:" + hashlib.sha256(blob.encode()).hexdigest()


def save_json(path: str, doc: dict, *, checksum: bool = True,
              indent: int = 1) -> str:
    """Atomically write ``doc`` as JSON with an embedded content checksum.

    The checksum covers every field except ``checksum`` itself, computed
    over canonical (sorted, compact) JSON — so a reader can verify it
    regardless of formatting.  Atomic: tmp file + rename, the tmp is
    unlinked on failure and the ``OSError`` re-raised (callers that treat
    persistence as best-effort catch it)."""
    payload = {k: v for k, v in doc.items() if k != CHECKSUM_FIELD}
    out = dict(payload)
    if checksum:
        out[CHECKSUM_FIELD] = _digest(payload)
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".artefact-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(out, f, indent=indent, sort_keys=True, default=str)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def quarantine(path: str, qdir: Optional[str] = None) -> Optional[str]:
    """Move a corrupt artefact aside into ``qdir`` (default
    ``<path>.quarantine/``); returns the new location, or None if the move
    itself failed (the load still proceeds as empty — quarantine is
    evidence preservation, never a new failure mode)."""
    qdir = qdir or (path + ".quarantine")
    base = os.path.basename(path)
    try:
        os.makedirs(qdir, exist_ok=True)
        dest = os.path.join(qdir, base)
        n = 0
        while os.path.exists(dest):
            n += 1
            dest = os.path.join(qdir, f"{base}.{n}")
        os.replace(path, dest)
        return dest
    except OSError:
        return None


def report_load_failure(path: str, what: str, err: Exception,
                        quarantined: Optional[str] = None) -> None:
    """The load-failure signal: always-on counter + structured event per
    occurrence, warn-once ``logging`` warning per path."""
    obs.counter("artefact.load_failed").inc()
    obs.event("artefact.load_failed", path=str(path), what=what,
              error=f"{type(err).__name__}: {err}",
              quarantined=str(quarantined or ""))
    # an artefact quarantine is silent data loss narrowly averted — worth
    # the full black box, not just a counter
    obs.flight_dump("artefact_quarantine", path=str(path), what=what,
                    error=f"{type(err).__name__}: {err}")
    with _warn_lock:
        if path in _warned_paths:
            return
        _warned_paths.add(path)
    log.warning(
        "%s artefact %s failed to load (%s: %s)%s; continuing with an "
        "empty store — it will be rebuilt on the next write",
        what, path, type(err).__name__, err,
        f"; corrupt file quarantined to {quarantined}" if quarantined
        else "")


def load_json(path: str, *, what: str = "artefact",
              qdir: Optional[str] = None) -> Optional[dict]:
    """Read + verify a JSON artefact; None when missing OR corrupt.

    Missing files return None silently.  Corrupt files (unparseable, not
    an object, or checksum mismatch) are quarantined via
    :func:`quarantine` and reported via :func:`report_load_failure`, then
    return None — the caller starts empty and rebuilds.  The returned dict
    has the ``checksum`` field stripped.

    Fault site ``artefact.corrupt`` (ctx: ``what``, ``path``) makes a
    healthy file read as corrupt, for deterministic resilience drills."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError:
        return None  # missing: the normal cold-cache state
    try:
        if faults.should_fire("artefact.corrupt", what=what, path=path):
            raise ValueError("injected artefact corruption")
        doc = json.loads(text)
        if not isinstance(doc, dict):
            raise ValueError(f"top level is {type(doc).__name__}, "
                             f"expected object")
        stored = doc.pop(CHECKSUM_FIELD, None)
        if stored is not None and stored != _digest(doc):
            raise ValueError("checksum mismatch (torn write or bit flip)")
        return doc
    except ValueError as e:
        qpath = quarantine(path, qdir)
        report_load_failure(path, what, e, qpath)
        return None


# ---------------------------------------------------------------------------
# append-only checksummed journals (JSONL, one verified record per line)
# ---------------------------------------------------------------------------

def append_record(path: str, record: dict) -> None:
    """Append one record to a JSONL journal with an embedded per-record
    checksum (same sha256-over-canonical-JSON as :func:`save_json`, scoped
    to the single record).

    The write is a single ``write()`` of one line — the common torn-write
    failure is a truncated *last* line, which :func:`read_records` detects
    and drops.  Creates the file (and parent directory) on first append."""
    payload = {k: v for k, v in record.items() if k != CHECKSUM_FIELD}
    out = dict(payload)
    out[CHECKSUM_FIELD] = _digest(payload)
    line = json.dumps(out, sort_keys=True, separators=(",", ":"),
                      default=str)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(line + "\n")


def read_records(path: str, *, what: str = "journal"):
    """Read a checksummed JSONL journal; returns ``(records, clean)``.

    Reading stops at the first unparseable or checksum-failing line: a
    crash mid-append leaves a truncated tail, and the records up to the
    last complete, verified line ARE the recoverable state.  ``clean`` is
    False when a tail was dropped — reported through the always-on
    ``artefact.journal_torn`` counter + a structured event naming the path
    and line (the file itself is left untouched: subsequent appends go
    after the torn bytes, so callers recovering a journal should replay
    into a fresh one).  A missing file is an empty, clean journal."""
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError:
        return [], True
    records = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
            if not isinstance(doc, dict):
                raise ValueError(f"record is {type(doc).__name__}, "
                                 f"expected object")
            stored = doc.pop(CHECKSUM_FIELD, None)
            if stored is None or stored != _digest(doc):
                raise ValueError("record checksum mismatch (torn write "
                                 "or bit flip)")
        except ValueError as e:
            obs.counter("artefact.journal_torn").inc()
            obs.event("artefact.journal_torn", path=str(path), what=what,
                      line=i, error=f"{type(e).__name__}: {e}",
                      recovered=len(records))
            log.warning(
                "%s journal %s torn at line %d (%s); recovered %d complete "
                "records up to the last verified boundary", what, path, i,
                e, len(records))
            return records, False
        records.append(doc)
    return records, True
