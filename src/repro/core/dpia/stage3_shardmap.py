"""Stage III (mesh backend): mesh-level strategies -> shard_map + collectives.

This is our extension of the paper's strategy hierarchy to the multi-device
level (DESIGN.md section 2): ``map[mesh(ax)]`` distributes blocks over a named
mesh axis exactly as ``mapWorkgroup`` distributed blocks over OpenCL work
groups, and a ``reduce[mesh(ax)]`` over the distributed blocks becomes a
single ``lax.psum`` — the collective schedule in the lowered HLO is the one
the functional term dictates (strategy preservation at the collective level).

Canonical forms accepted (what the strategy rewrites produce):

  1. [Join] (Map_{mesh ax} f (Split c E))       -- sharded map
  2. Reduce_{mesh ax} (+|max) z (Map_{mesh ax} f (Split c E))  -- map+all-reduce

where E is built from input Vars with Zip (chunking commutes with Zip).
Argument Vars that do NOT flow through the Split (a scal's alpha, rmsnorm's
weight vector, matmul's B operand) are passed to every shard *replicated*
(``in_specs=PartitionSpec()``) — the mesh map shards the big operand and
broadcasts the small ones, exactly the data-parallel reading of the term.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

from . import phrases as P
from .types import Arr, ExpT


class MeshFormError(TypeError):
    pass


def _peel_join(e: P.Phrase):
    if isinstance(e, P.Join):
        return e.e, True
    return e, False


def _chunk_expr(e: P.Phrase, c: int):
    """Rewrite E (Vars/Zip of Vars) to its local-chunk version, returning the
    rewritten expression plus the list of (var, chunked_var) pairs."""
    if isinstance(e, P.Var):
        d = P.exp_data(e)
        if not isinstance(d, Arr):
            raise MeshFormError("sharded input must be an array")
        local = P.Var(e.name, ExpT(Arr(c, d.elem)))
        return local, [(e, local)]
    if isinstance(e, P.Zip):
        a, pa = _chunk_expr(e.a, c)
        b, pb = _chunk_expr(e.b, c)
        return P.Zip(a, b), pa + pb
    raise MeshFormError(
        f"cannot shard through {type(e).__name__}; expected Var/Zip")


def compile_expr_shardmap(expr: P.Phrase, arg_vars: Sequence[P.Var],
                          mesh: Mesh, *, inner: str = "jnp",
                          check: bool = True) -> Callable:
    """Compile a mesh-level functional strategy to a shard_map'd callable."""
    from . import stage3_jnp, stage3_pallas

    def compile_inner(e, vs):
        if inner == "pallas":
            return stage3_pallas.compile_expr_pallas(e, vs, check=check)
        return stage3_jnp.compile_expr(e, vs, check=check)

    names = [v.name for v in arg_vars]

    def extras_of(pairs):
        """Argument Vars not flowing through the Split: replicated inputs."""
        chunked = {v.name for v, _ in pairs}
        return [v for v in arg_vars if v.name not in chunked]

    # ---- form 2: distributed reduce --------------------------------------
    if isinstance(expr, P.Reduce) and expr.level.kind == "mesh":
        ax = expr.level.axis
        x = P.Var(P.fresh("x"), ExpT(P.exp_data(expr.init)))
        acc = P.Var(P.fresh("a"), ExpT(P.exp_data(expr.init)))
        body = expr.f(x, acc)
        if not (isinstance(body, P.BinOp) and body.op in ("add", "max")):
            raise MeshFormError("mesh reduce must combine with + or max")
        op = body.op
        inner_map = expr.e
        if not (isinstance(inner_map, P.Map)
                and inner_map.level.kind == "mesh"
                and inner_map.level.axis == ax):
            raise MeshFormError("mesh reduce must consume a mesh map")
        split = inner_map.e
        if not isinstance(split, P.Split):
            raise MeshFormError("mesh map must consume a split")
        nshards = mesh.shape[ax]
        d_in = P.exp_data(split)
        if d_in.n != nshards:
            raise MeshFormError(
                f"split yields {d_in.n} blocks but axis {ax!r} has {nshards}")
        local_e, pairs = _chunk_expr(split.e, split.n)
        extras = extras_of(pairs)
        blk = P.Var(P.fresh("blk"), ExpT(Arr(split.n, _elem(split))))
        per_block = inner_map.f(blk)
        local_vars = [lv for _, lv in pairs] + extras + [blk]
        local_fn = compile_inner(per_block, local_vars)

        def chunk_fn(*locs):
            from .interp import interp
            return interp(local_e, {lv.name: lo for (_, lv), lo
                                    in zip(pairs, locs)})

        in_specs = tuple(PS(ax) for _ in pairs) + tuple(PS() for _ in extras)
        out_specs = PS()

        def shard_fn(*args_in):
            locs, reps = args_in[:len(pairs)], args_in[len(pairs):]
            chunk = chunk_fn(*locs)
            part = local_fn(*(list(locs) + list(reps) + [chunk]))
            return jax.lax.psum(part, ax) if op == "add" \
                else jax.lax.pmax(part, ax)

        sm = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        order = [v.name for v, _ in pairs] + [v.name for v in extras]

        def fn(*args):
            env = dict(zip(names, args))
            return sm(*(env[n] for n in order))

        return fn

    # ---- form 1: sharded map ---------------------------------------------
    body_e, joined = _peel_join(expr)
    if isinstance(body_e, P.Map) and body_e.level.kind == "mesh":
        ax = body_e.level.axis
        split = body_e.e
        if not isinstance(split, P.Split):
            raise MeshFormError("mesh map must consume a split")
        nshards = mesh.shape[ax]
        d_in = P.exp_data(split)
        if d_in.n != nshards:
            raise MeshFormError(
                f"split yields {d_in.n} blocks but axis {ax!r} has {nshards}")
        local_e, pairs = _chunk_expr(split.e, split.n)
        extras = extras_of(pairs)
        blk = P.Var(P.fresh("blk"), ExpT(Arr(split.n, _elem(split))))
        per_block = body_e.f(blk)
        local_fn = compile_inner(
            per_block, [lv for _, lv in pairs] + extras + [blk])

        def chunk_fn(*locs):
            from .interp import interp
            return interp(local_e, {lv.name: lo for (_, lv), lo
                                    in zip(pairs, locs)})

        in_specs = tuple(PS(ax) for _ in pairs) + tuple(PS() for _ in extras)
        out_specs = PS(ax)

        def shard_fn(*args_in):
            locs, reps = args_in[:len(pairs)], args_in[len(pairs):]
            chunk = chunk_fn(*locs)
            out = local_fn(*(list(locs) + list(reps) + [chunk]))
            if not joined:
                out = jax.tree_util.tree_map(lambda l: l[None], out)
            return out

        sm = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        order = [v.name for v, _ in pairs] + [v.name for v in extras]

        def fn(*args):
            env = dict(zip(names, args))
            return sm(*(env[n] for n in order))

        return fn

    raise MeshFormError(
        "expression is not in a recognised mesh-level canonical form")


def _elem(split: P.Split):
    d = P.exp_data(split)
    assert isinstance(d, Arr) and isinstance(d.elem, Arr)
    return d.elem.elem


# self-register as a Stage III target (see repro.compiler.backends)
from repro.compiler.backends import Backend as _Backend  # noqa: E402
from repro.compiler.backends import register_backend as _register  # noqa: E402

_register(_Backend(
    name="shardmap", compile=compile_expr_shardmap,
    accepts=("mesh", "inner", "check"), requires=("mesh",),
    description="mesh-level strategies -> shard_map + collectives (pass "
                "mesh=, optional inner='jnp'|'pallas')"),
    aliases=("dpia-shardmap",), overwrite=True)
