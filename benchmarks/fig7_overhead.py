"""Paper Fig. 7 reproduction: overhead of the *formal* translation.

The paper's claim: OpenCL generated through the formal DPIA translation is
within 5% of the ad-hoc ICFP'15 generator across scal/asum/dot/gemv.  Our
setting: the hand-written jnp implementation (XLA's native lowering) plays
the ad-hoc generator; the DPIA Stage I-III pipeline plays the formal path.
We compare (a) compiled wall time on CPU and (b) HLO dot-FLOPs parity.

The DPIA->Pallas backend is also timed in interpret mode for completeness,
but interpret mode is an emulation — its wall time is NOT a kernel speed
claim (the Pallas kernels' TPU validity is covered by the dry-run/tests).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_counter import analyze_text
from repro.kernels import dpia_blas, ref

SIZES = {"small": 1 << 20, "large": 1 << 22}
GEMV_SIZES = {"small": (1024, 1024), "large": (2048, 2048)}


def _time(fn, args, iters=10) -> float:
    fn(*args)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _flops(fn, args) -> float:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_text(txt).flops


def cases(rng) -> List[Dict]:
    out = []
    for label, n in SIZES.items():
        x = jnp.asarray(rng.randn(n), "float32")
        y = jnp.asarray(rng.randn(n), "float32")
        a = jnp.float32(1.5)
        out += [
            # scal strategy = whole-block (picked by strategy search over
            # block sizes; see EXPERIMENTS.md Perf 'fig7/scal' iterations)
            dict(op="scal", size=label,
                 hand=lambda a, x: ref.scal(a, x),
                 build=lambda n=n: dpia_blas.wholeblock_scal(n),
                 args=(a, x)),
            dict(op="asum", size=label,
                 hand=lambda x: ref.asum(x),
                 build=lambda n=n: dpia_blas.strategy_asum(n),
                 args=(x,)),
            dict(op="dot", size=label,
                 hand=lambda x, y: ref.dot(x, y),
                 build=lambda n=n: dpia_blas.strategy_dot(n),
                 args=(x, y)),
        ]
    for label, (m, n) in GEMV_SIZES.items():
        A = jnp.asarray(rng.randn(m, n), "float32")
        v = jnp.asarray(rng.randn(n), "float32")
        out.append(dict(op="gemv", size=label,
                        hand=lambda A, v: ref.gemv(A, v),
                        build=lambda m=m, n=n: dpia_blas.strategy_gemv(m, n),
                        args=(A, v)))
    return out


def run(csv_rows: List[str]) -> None:
    rng = np.random.RandomState(0)
    print("# Fig.7: formal-translation overhead "
          "(DPIA pipeline vs hand-written, CPU wall time + HLO flops)")
    from repro import compiler
    for c in cases(rng):
        hand_fn = jax.jit(c["hand"])
        prog = compiler.Program.from_builder(c["build"], name=c["op"])
        dpia_fn = prog.check().lower().compile("jnp")

        got = dpia_fn(*c["args"])
        want = hand_fn(*c["args"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

        t_hand = _time(hand_fn, c["args"])
        t_dpia = _time(dpia_fn, c["args"])
        f_hand = _flops(c["hand"], c["args"])
        f_dpia = _flops(dpia_fn, c["args"])
        ratio = t_dpia / t_hand
        fl = (f_dpia / f_hand) if f_hand else float("nan")
        name = f"fig7/{c['op']}/{c['size']}"
        csv_rows.append(f"{name}/hand,{t_hand:.1f},")
        csv_rows.append(f"{name}/dpia,{t_dpia:.1f},time_ratio={ratio:.3f}"
                        f";flops_ratio={fl:.3f}")
        print(f"  {c['op']:5s} {c['size']:5s} hand={t_hand:9.1f}us "
              f"dpia={t_dpia:9.1f}us  ratio={ratio:5.2f}  "
              f"flops_ratio={fl:.3f}")
