"""Failure-domain tests: host-group partitioning, the checksummed scheduler
journal + replay, mesh shrink descriptors / elastic re-mesh, checkpoint
manifest self-healing, shrunk-mesh re-tuning, and the forced-8-device
host-loss drill (survivors token-identical, evacuees re-decode, one
``degraded(mesh(...))`` provenance origin + one ``host_lost`` flight dump
per event)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import artefacts
from repro.mesh import strategy as ms
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve import domains
from repro.serve.domains import (FailureDomains, JournalState,
                                 SchedulerJournal, replay)
from repro.serve.engine import ContinuousEngine, Request
from repro.testing import faults


def tiny_cfg(**kw):
    base = dict(name="dom-t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, dtype="float32",
                remat=False, max_seq=64)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_model():
    cfg = tiny_cfg()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def mk_requests(cfg, n=3):
    key = jax.random.PRNGKey(5)
    temps = [0.0, 0.9, 0.0, 1.3]
    return [Request(
        prompt=jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (5 + 3 * i,), 0, cfg.vocab),
        max_new_tokens=4 + 3 * i, temperature=temps[i % 4],
        top_k=(5 if i % 4 == 1 else 0)) for i in range(n)]


# ---------------------------------------------------------------------------
# host groups: pure partition/attribution logic
# ---------------------------------------------------------------------------

class TestPartition:
    def test_even_contiguous_split(self):
        assert FailureDomains.partition(8, 2) == ((0, 1, 2, 3), (4, 5, 6, 7))
        assert FailureDomains.partition(8, 4) == ((0, 1), (2, 3), (4, 5),
                                                  (6, 7))
        assert FailureDomains.partition(4, 1) == ((0, 1, 2, 3),)

    def test_uneven_split_rejected(self):
        with pytest.raises(ValueError, match="evenly divide"):
            FailureDomains.partition(8, 3)
        with pytest.raises(ValueError, match="hosts"):
            FailureDomains.partition(8, 0)

    def test_slots_for_full_mesh(self):
        groups = FailureDomains.partition(8, 2)
        alive = [True, True]
        # 8 slots over 8 positions: one slot per position, contiguous
        assert FailureDomains.slots_for(groups, alive, 0, 8) == [0, 1, 2, 3]
        assert FailureDomains.slots_for(groups, alive, 1, 8) == [4, 5, 6, 7]
        # 16 slots over 8 positions: two per position
        assert FailureDomains.slots_for(groups, alive, 1, 16) == list(
            range(8, 16))

    def test_slots_for_after_loss_reranks(self):
        """After host 1 of 4 dies, the surviving positions re-rank and
        host 2's slots shift — attribution must track the live placement."""
        groups = FailureDomains.partition(8, 4)
        alive = [True, False, True, True]
        # positions alive: 0,1 (host0) 4,5 (host2) 6,7 (host3) -> ranks 0..5
        assert FailureDomains.slots_for(groups, alive, 2, 12) == [4, 5, 6, 7]
        assert FailureDomains.slots_for(groups, alive, 1, 12) == []

    def test_slots_for_indivisible_rejected(self):
        groups = FailureDomains.partition(4, 2)
        with pytest.raises(ValueError, match="divisible"):
            FailureDomains.slots_for(groups, [True, False], 0, 7)

    def test_single_process_mesh_partitions_by_hosts_arg(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1)
        assert dom.n_hosts == 1
        assert dom.alive_positions() == [0]
        assert dom.describe()["losses"] == 0

    def test_all_hosts_lost_is_unservable(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1)
        with pytest.raises(RuntimeError, match="all 1 hosts lost"):
            dom.mark_lost(0)

    def test_mark_lost_idempotent_and_counts(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1)
        dom.groups = FailureDomains.partition(4, 2)   # pretend 2 hosts
        dom.alive = [True, True]
        dom.mark_lost(1)
        dom.mark_lost(1)
        assert dom.n_losses == 1
        assert dom.alive_hosts() == [0]

    def test_poll_is_none_without_fault_plan(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1)
        assert dom.poll() is None

    def test_slow_escalates_to_lost_at_threshold(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1, slow_threshold=3)
        with faults.inject("mesh.host_slow(host=0, times=3, value=0.01)"):
            e1 = dom.poll()
            e2 = dom.poll()
            e3 = dom.poll()
        assert (e1.kind, e2.kind, e3.kind) == ("slow", "slow", "lost")
        assert e1.delay_s == pytest.approx(0.01)
        assert "escalated" in e3.cause

    def test_collective_timeout_names_presumed_host(self):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        dom = FailureDomains(mesh, hosts=1)
        dom.groups = FailureDomains.partition(4, 2)
        dom.alive = [True, True]
        with faults.inject("collective.timeout(value=0)"):
            ev = dom.poll()
        assert ev.kind == "lost" and ev.host == 0
        with faults.inject("collective.timeout"):
            ev = dom.poll()
        assert ev.host == 1   # default scapegoat: the last alive host


# ---------------------------------------------------------------------------
# mesh shrink: descriptors + elastic re-mesh
# ---------------------------------------------------------------------------

class TestShrink:
    def test_shrink_descriptor_halves_to_fit(self):
        assert ms.shrink_descriptor("data=8", 4) == "data=4"
        assert ms.shrink_descriptor("data=4", 2) == "data=2"
        assert ms.shrink_descriptor("data=8", 5) == "data=4"
        assert ms.shrink_descriptor("data=8", 8) == "data=8"
        assert ms.shrink_descriptor("single", 1) == "single"

    def test_shrink_descriptor_named_axis(self):
        assert ms.shrink_descriptor("data=4,model=2", 4,
                                    axis="data") == "data=2,model=2"
        with pytest.raises(ValueError, match="not in descriptor"):
            ms.shrink_descriptor("data=4", 2, axis="model")

    def test_shrink_descriptor_impossible(self):
        with pytest.raises(ValueError, match="not enough devices"):
            ms.shrink_descriptor("data=2,model=2", 1, axis="data")
        with pytest.raises(ValueError, match="n_devices"):
            ms.shrink_descriptor("data=2", 0)

    def test_elastic_remesh_descriptor_on_one_device(self):
        from repro.ft.resilience import elastic_remesh
        mesh = elastic_remesh("data=8")
        assert dict(mesh.shape) == {"data": 1}
        # legacy tuple form still accepted
        mesh = elastic_remesh((4, 1), ("data", "model"))
        assert dict(mesh.shape) == {"data": 1, "model": 1}
        with pytest.raises(TypeError):
            elastic_remesh("data=8", ("data",))   # descriptor + axis_names


# ---------------------------------------------------------------------------
# checksummed journal records (ft.artefacts)
# ---------------------------------------------------------------------------

class TestJournalRecords:
    def test_roundtrip_and_checksums(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        artefacts.append_record(p, {"kind": "submit", "rid": 0})
        artefacts.append_record(p, {"kind": "progress", "rid": 0,
                                    "tokens": [1, 2, 3]})
        recs, clean = artefacts.read_records(p)
        assert clean and len(recs) == 2
        assert recs[1]["tokens"] == [1, 2, 3]

    def test_missing_file_reads_empty_clean(self, tmp_path):
        recs, clean = artefacts.read_records(str(tmp_path / "nope.jsonl"))
        assert recs == [] and clean

    def test_torn_tail_recovers_to_last_complete_record(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        artefacts.append_record(p, {"kind": "submit", "rid": 0})
        artefacts.append_record(p, {"kind": "progress", "rid": 0,
                                    "tokens": [7]})
        with open(p, "a") as f:
            f.write('{"kind": "progress", "rid": 0, "tok')   # crash mid-write
        recs, clean = artefacts.read_records(p)
        assert not clean
        assert [r["kind"] for r in recs] == ["submit", "progress"]

    def test_flipped_bit_fails_checksum(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        artefacts.append_record(p, {"kind": "progress", "rid": 0,
                                    "tokens": [7]})
        artefacts.append_record(p, {"kind": "terminal", "rid": 0,
                                    "state": "ok"})
        lines = open(p).read().splitlines()
        lines[0] = lines[0].replace('"tokens":[7]', '"tokens":[8]')
        with open(p, "w") as f:
            f.write("\n".join(lines) + "\n")
        recs, clean = artefacts.read_records(p)
        # the tampered record AND everything after it are dropped: a
        # journal's order is part of its meaning
        assert recs == [] and not clean


class TestSchedulerJournal:
    def test_fold_to_state(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        j = SchedulerJournal(p)
        j.record_submit(0, [1, 2, 3], max_new=4, temperature=0.0, top_k=0,
                        stream=0)
        j.record_submit(1, [4, 5], max_new=2, temperature=0.9, top_k=5,
                        stream=1)
        j.record_progress(0, [10, 11])
        j.record_progress(0, [10, 11, 12])      # delta append
        j.record_progress(0, [10, 11, 12])      # no new tokens: no record
        j.record_terminal(1, "cancelled", "caller")
        j.record_terminal(1, "cancelled", "again")   # deduped
        state = SchedulerJournal.load(p)
        assert state.clean
        assert state.requests[0]["emitted"] == [10, 11, 12]
        assert state.requests[0]["prompt"] == [1, 2, 3]
        assert state.requests[1]["stream"] == 1
        assert state.terminals == {1: ("cancelled", "caller")}
        assert sorted(state.live()) == [0]

    def test_evacuate_resets_emitted_snapshot(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        j = SchedulerJournal(p)
        j.record_submit(0, [1], max_new=4, temperature=0.0, top_k=0,
                        stream=0)
        j.record_progress(0, [10, 11])
        j.record_evacuate(0, host=1)
        # after evacuation the request re-decodes from its prompt: the
        # journal writer's snapshot resets so the re-emitted tokens are
        # re-recorded from the first token
        j.record_progress(0, [10, 11, 12])
        state = SchedulerJournal.load(p)
        assert state.evacuations == 1
        assert state.requests[0]["emitted"] == [10, 11, 12]

    def test_shrink_records_collected(self, tmp_path):
        p = str(tmp_path / "j.jsonl")
        j = SchedulerJournal(p)
        j.record_shrink("data=8", "data=4", host=1, cause="drill")
        state = SchedulerJournal.load(p)
        assert len(state.shrinks) == 1
        assert state.shrinks[0]["frm"] == "data=8"
        assert state.shrinks[0]["to"] == "data=4"


# ---------------------------------------------------------------------------
# journal replay: token identity in a fresh engine
# ---------------------------------------------------------------------------

class TestReplay:
    @staticmethod
    def _reqs(cfg, n=3):
        """Decodes long enough (16 tokens, chunk=4) that nothing retires
        in the couple of boundaries before the simulated crash."""
        key = jax.random.PRNGKey(5)
        temps = [0.0, 0.9, 0.0]
        return [Request(
            prompt=jax.random.randint(jax.random.fold_in(key, 100 + i),
                                      (5 + 3 * i,), 0, cfg.vocab),
            max_new_tokens=16, temperature=temps[i % 3],
            top_k=(5 if i % 3 == 1 else 0)) for i in range(n)]

    def _abandon(self, model, params, reqs, jpath, key, *, chunks,
                 cancel_rid=None):
        """Drive a journaled engine partway and walk away (the crash)."""
        eng = ContinuousEngine(model, params, max_seq=64, slots=4, chunk=4,
                               journal=jpath)
        with eng._options_scope():
            eng._run_key = key
            for i, r in enumerate(reqs):
                eng.submit(r, stream=i)
            for _ in range(chunks):
                if eng.sched.idle:
                    break
                eng.step_chunk()
            if cancel_rid is not None:
                eng.cancel(cancel_rid, "raced with the crash")
        return eng

    def test_replay_matches_fault_free_oracle(self, dense_model, tmp_path):
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(7)
        reqs = self._reqs(cfg, 3)
        oracle = ContinuousEngine(model, params, max_seq=64, slots=4,
                                  chunk=4).run(reqs, key=key)
        jpath = str(tmp_path / "j.jsonl")
        self._abandon(model, params, reqs, jpath, key, chunks=2)
        fresh = ContinuousEngine(model, params, max_seq=64, slots=4, chunk=4)
        got = replay(jpath, fresh, key=key)
        assert sorted(got) == [0, 1, 2]
        for rid, toks in got.items():
            assert toks == oracle[rid], rid

    def test_replay_mid_prefill_submit_only(self, dense_model, tmp_path):
        """Crash before the first boundary: the journal holds bare submits
        (no progress); replay still owes — and reproduces — every token."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(9)
        reqs = self._reqs(cfg, 2)
        oracle = ContinuousEngine(model, params, max_seq=64, slots=4,
                                  chunk=4).run(reqs, key=key)
        jpath = str(tmp_path / "j.jsonl")
        self._abandon(model, params, reqs, jpath, key, chunks=0)
        state = SchedulerJournal.load(jpath)
        assert all(r["emitted"] == [] for r in state.requests.values())
        fresh = ContinuousEngine(model, params, max_seq=64, slots=4, chunk=4)
        got = replay(jpath, fresh, key=key)
        assert [got[i] for i in range(2)] == oracle

    def test_replay_skips_cancel_raced_request(self, dense_model, tmp_path):
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(11)
        reqs = self._reqs(cfg, 3)
        oracle = ContinuousEngine(model, params, max_seq=64, slots=4,
                                  chunk=4).run(reqs, key=key)
        jpath = str(tmp_path / "j.jsonl")
        self._abandon(model, params, reqs, jpath, key, chunks=1,
                      cancel_rid=1)
        state = SchedulerJournal.load(jpath)
        assert state.terminals[1][0] == "cancelled"
        fresh = ContinuousEngine(model, params, max_seq=64, slots=4, chunk=4)
        got = replay(jpath, fresh, key=key)
        # the cancelled request is terminal — replay owes it nothing
        assert sorted(got) == [0, 2]
        assert got[0] == oracle[0] and got[2] == oracle[2]

    def test_duplicate_replay_is_idempotent(self, dense_model, tmp_path):
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(13)
        reqs = self._reqs(cfg, 3)
        jpath = str(tmp_path / "j.jsonl")
        self._abandon(model, params, reqs, jpath, key, chunks=2)
        a = replay(jpath, ContinuousEngine(model, params, max_seq=64,
                                           slots=4, chunk=4), key=key)
        b = replay(jpath, ContinuousEngine(model, params, max_seq=64,
                                           slots=4, chunk=4), key=key)
        assert a == b

    def test_replay_survives_torn_tail(self, dense_model, tmp_path):
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(15)
        reqs = self._reqs(cfg, 2)
        oracle = ContinuousEngine(model, params, max_seq=64, slots=4,
                                  chunk=4).run(reqs, key=key)
        jpath = str(tmp_path / "j.jsonl")
        self._abandon(model, params, reqs, jpath, key, chunks=1)
        with open(jpath, "a") as f:
            f.write('{"kind": "termi')     # crash tore the last write
        state = SchedulerJournal.load(jpath)
        assert not state.clean
        got = replay(state, ContinuousEngine(model, params, max_seq=64,
                                             slots=4, chunk=4), key=key)
        assert [got[i] for i in range(2)] == oracle


# ---------------------------------------------------------------------------
# checkpoint manifests: checksummed, quarantined, fall back on restore
# ---------------------------------------------------------------------------

class TestCheckpointManifests:
    def _mgr(self, tmp_path):
        from repro.ckpt.manager import CheckpointManager
        return CheckpointManager(str(tmp_path / "ckpt"), keep=5,
                                 async_save=False)

    def test_corrupt_manifest_falls_back_to_older_step(self, tmp_path):
        mgr = self._mgr(tmp_path)
        state = {"w": np.arange(4, dtype=np.float32)}
        mgr.save(1, state, extra={"tokens": 10})
        mgr.save(2, {"w": np.arange(4, dtype=np.float32) * 2},
                 extra={"tokens": 20})
        manifest = os.path.join(mgr.dir, "step_0000000002", "manifest.json")
        faults.corrupt_json_file(manifest, "garbage")
        got = mgr.restore_latest(state)
        assert got is not None
        step, restored, extra = got
        assert step == 1 and extra == {"tokens": 10}
        np.testing.assert_array_equal(restored["w"], np.arange(4))
        # the corrupt manifest was quarantined, not deleted
        assert os.path.isdir(manifest + ".quarantine")
        # and its step no longer advertises itself
        assert mgr.all_steps() == [1]

    def test_stale_checksum_detected(self, tmp_path):
        """A manifest whose payload changed after checksumming (silent
        bitrot / manual edit) must not restore."""
        mgr = self._mgr(tmp_path)
        state = {"w": np.zeros(2, dtype=np.float32)}
        mgr.save(1, state)
        manifest = os.path.join(mgr.dir, "step_0000000001", "manifest.json")
        faults.corrupt_json_file(manifest, "stale")
        assert mgr.restore_latest(state) is None

    def test_clean_roundtrip(self, tmp_path):
        mgr = self._mgr(tmp_path)
        state = {"w": np.arange(6, dtype=np.float32)}
        mgr.save(3, state, extra={"step_time": 0.5})
        step, restored, extra = mgr.restore_latest(state)
        assert step == 3 and extra == {"step_time": 0.5}
        np.testing.assert_array_equal(restored["w"], state["w"])


# ---------------------------------------------------------------------------
# re-tuning for a shrunk mesh descriptor
# ---------------------------------------------------------------------------

class TestRetuneForMesh:
    def test_fills_cache_rows_for_descriptor(self, dense_model,
                                             tuning_cache):
        from repro import autotune
        cfg, _, _ = dense_model
        n = domains.retune_for_mesh(cfg, "data=2", max_seq=64,
                                    batch_sizes=(1, 8), cache=tuning_cache)
        assert n > 0
        # the descriptor is part of the cache key: a tune for the same
        # shrunk mesh now comes straight from cache
        shapes = list(autotune.model_kernel_shapes(cfg, max_seq=64,
                                                   batch_sizes=(1, 8)))
        hit = False
        for kernel, shape in shapes:
            try:
                r = autotune.tune(kernel, backend="shardmap", mesh="data=2",
                                  cache=tuning_cache, measure=False, **shape)
            except (ValueError, AssertionError):
                continue
            assert r.source == "cache", (kernel, r.source)
            hit = True
        assert hit


# ---------------------------------------------------------------------------
# forced-8-device host-loss drills (subprocesses; see conftest.forced_devices)
# ---------------------------------------------------------------------------

DRILL_COMMON = r"""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import ContinuousEngine, ShardedEngine, Request
from repro.serve.domains import SchedulerJournal, replay
from repro.testing import faults
from repro import obs

cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, max_seq=64)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

def reqs():
    # decodes long enough (16 tokens, chunk=4) that every request is still
    # in flight when the fault fires a few boundaries in
    rng = np.random.RandomState(1)
    spec = [(3, 0.0, 0), (9, 0.8, 4), (5, 0.0, 0), (12, 1.2, 0),
            (4, 0.0, 0), (6, 0.0, 0), (7, 0.9, 3), (8, 0.0, 0)]
    return [Request(jnp.asarray(rng.randint(0, 128, (l,)), jnp.int32),
                    max_new_tokens=16, temperature=t, top_k=k)
            for l, t, k in spec]

key = jax.random.PRNGKey(7)
oracle = ContinuousEngine(model, params, max_seq=64, slots=8,
                          chunk=4).run(reqs(), key=key)

def mk_sharded(**kw):
    mesh = jax.make_mesh((8,), ("data",))
    return ShardedEngine(model, params, max_seq=64, slots=8, chunk=4,
                         mesh=mesh, hosts=2, **kw)
"""


DRILL_HOST_LOSS = DRILL_COMMON + r"""
# -- clean run first: silent (zero dumps, zero degradations, zero losses) --
sh0 = mk_sharded()
assert sh0.run(reqs(), key=key) == oracle
assert obs.flight_dumps() == [], [d["reason"] for d in obs.flight_dumps()]
st = sh0.stats()
assert st["resilience"]["host_losses"] == 0, st["resilience"]
assert st["mesh"]["descriptor"] == "data=8", st["mesh"]
assert st["mesh"]["hosts"]["alive"] == [0, 1], st["mesh"]["hosts"]
assert sh0.sched.n_evacuations == 0
print("CLEAN_OK")

# -- elastic remesh on the real 8-device platform --------------------------
from repro.ft.resilience import elastic_remesh
assert dict(elastic_remesh("data=16").shape) == {"data": 8}
assert dict(elastic_remesh("data=8").shape) == {"data": 8}
print("REMESH_OK")

# -- host 1 dies mid-decode ------------------------------------------------
obs.flight_clear()
from repro.autotune import TuningCache
tmp = tempfile.mkdtemp()
jpath = os.path.join(tmp, "journal.jsonl")
tc = TuningCache(os.path.join(tmp, "tc.json"))
sh = mk_sharded(journal=jpath, tuning_cache=tc)
with faults.inject("mesh.host_lost(host=1, after=3)") as plan:
    got = sh.run(reqs(), key=key)
assert plan[0].fired == 1, plan[0].fired
# survivors retired token-identical; evacuees re-admitted on the shrunk
# mesh and completed bit-identical to the fault-free oracle
assert got == oracle, "tokens diverged from the fault-free oracle"
st = sh.stats()
assert st["mesh"]["descriptor"] == "data=4", st["mesh"]
assert st["mesh"]["hosts"]["alive"] == [0], st["mesh"]["hosts"]
assert st["mesh"]["hosts"]["lost"] == [1]
assert st["resilience"]["host_losses"] == 1
assert sh.sched.n_evacuations == 4, sh.sched.n_evacuations

# exactly ONE flight dump for the event, reason host_lost (the generic
# degradation dump is suppressed on this path)
dumps = obs.flight_dumps()
reasons = [d["reason"] for d in dumps]
assert reasons.count("host_lost") == 1, reasons
assert "degradation" not in reasons, reasons
assert dumps[[i for i, r in enumerate(reasons)
              if r == "host_lost"][0]]["ctx"]["to"] == "data=4"

# the shrink is a recorded strategy: provenance origin degraded(mesh(...))
assert "degraded(mesh(data=8)->mesh(data=4))" in obs.explain(), \
    obs.explain(kind="mesh")

# the autotuner re-ranked candidates for the shrunk descriptor
assert any("data=4" in k for k in tc._mem), sorted(tc._mem)[:5]

# the journal recorded the whole story, checksummed
state = SchedulerJournal.load(jpath)
assert state.clean
assert len(state.shrinks) == 1
assert state.shrinks[0]["frm"] == "data=8"
assert state.shrinks[0]["to"] == "data=4"
assert state.shrinks[0]["host"] == 1
assert state.evacuations == 4
assert sorted(state.terminals) == list(range(8))
assert all(s == "ok" for s, _ in state.terminals.values())
for rid in range(8):
    assert state.requests[rid]["emitted"] == oracle[rid], rid
print("LOSS_OK")
"""


DRILL_TIMEOUT_SLOW = DRILL_COMMON + r"""
# -- collective timeout: presumed-dead host (default: last alive) ----------
with faults.inject("collective.timeout(after=2)"):
    sh = mk_sharded()
    got = sh.run(reqs(), key=key)
assert got == oracle
st = sh.stats()
assert st["mesh"]["descriptor"] == "data=4", st["mesh"]
assert st["mesh"]["hosts"]["lost"] == [1], st["mesh"]["hosts"]
print("TIMEOUT_OK")

# -- straggler escalation: slow strikes, then lost (note host 0 this time:
# the shrunk mesh is the TAIL half, exercising the re-rank of positions) --
obs.flight_clear()
sh2 = mk_sharded(host_slow_threshold=2)
with faults.inject("mesh.host_slow(host=0, times=2, value=0.0)"):
    got = sh2.run(reqs(), key=key)
assert got == oracle
st = sh2.stats()
assert st["mesh"]["hosts"]["lost"] == [0], st["mesh"]["hosts"]
assert st["mesh"]["descriptor"] == "data=4"
reasons = [d["reason"] for d in obs.flight_dumps()]
assert reasons.count("host_lost") == 1, reasons
print("TIMEOUT_SLOW_OK")
"""


DRILL_REPLAY = DRILL_COMMON + r"""
# -- crash AFTER surviving a host loss: the journal replays the survivors
# and evacuees alike, in a fresh single-device engine, to token identity --
import tempfile, os
jpath = os.path.join(tempfile.mkdtemp(), "j.jsonl")
sh = mk_sharded(journal=jpath)
with faults.inject("mesh.host_lost(host=1, after=1)"):
    with sh._options_scope():
        sh._run_key = key
        for i, r in enumerate(reqs()):
            sh.submit(r, stream=i)
        for _ in range(3):
            sh.step_chunk()
# walk away mid-flight (the engine crash); a fresh unsharded engine owes
# every live request its tokens
state = SchedulerJournal.load(jpath)
assert state.clean
assert len(state.shrinks) == 1
assert len(state.live()) == 8, sorted(state.live())
cont = ContinuousEngine(model, params, max_seq=64, slots=8, chunk=4)
got = replay(jpath, cont, key=key)
assert sorted(got) == list(range(8))
for rid, toks in got.items():
    assert toks == oracle[rid], rid
print("REPLAY_DRILL_OK")
"""


@pytest.mark.slow
def test_host_loss_drill_subprocess(forced_devices):
    """Acceptance: on a forced-8-device mesh split into 2 hosts, killing
    host 1 mid-decode evacuates its slots, shrinks the mesh data=8->data=4,
    records the shrink as provenance ``degraded(mesh(...))`` + exactly one
    ``host_lost`` flight dump + a checksummed journal, re-tunes for the new
    descriptor — and every request retires token-identical to the
    fault-free oracle.  A clean run stays silent."""
    r = forced_devices(DRILL_HOST_LOSS)
    for marker in ("CLEAN_OK", "REMESH_OK", "LOSS_OK"):
        assert marker in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_timeout_and_straggler_drill_subprocess(forced_devices):
    """Collective timeouts and straggler escalation take the same survival
    path; losing host 0 (the leading half) exercises position re-ranking."""
    r = forced_devices(DRILL_TIMEOUT_SLOW)
    assert "TIMEOUT_SLOW_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_journal_replay_after_host_loss_subprocess(forced_devices):
    """A journal written through a host loss replays every live request to
    token identity in a fresh engine on a different (single-device)
    topology."""
    r = forced_devices(DRILL_REPLAY)
    assert "REPLAY_DRILL_OK" in r.stdout, r.stdout + r.stderr
