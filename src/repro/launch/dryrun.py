import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis, and record roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun.json

Results are cached incrementally in the JSON (safe to re-run / resume).
"""

import argparse
import json
import time
import traceback
from typing import Dict

import numpy as np


def _mesh(multi_pod: bool):
    import jax
    from jax.sharding import Mesh
    if multi_pod:
        devs = np.array(jax.devices()[:512]).reshape(2, 16, 16)
        return Mesh(devs, ("pod", "data", "model"))
    devs = np.array(jax.devices()[:256]).reshape(16, 16)
    return Mesh(devs, ("data", "model"))


def lower_cell(arch: str, shape: str, multi_pod: bool) -> Dict:
    """Lower + compile one cell; returns the roofline/record dict."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as PS

    from repro.analysis import hlo as hlo_mod
    from repro.configs import config
    from repro.launch import specs as S
    from repro.sharding import rules
    from repro.train.step import make_train_step, state_specs

    t0 = time.time()
    cfg = config(arch)
    ok, why = S.shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = _mesh(multi_pod)
    from repro.sharding import ctx
    ctx.set_mesh(mesh)
    chips = int(np.prod(list(mesh.shape.values())))
    model = S.model_for(cfg, shape)
    cfg = model.cfg
    info = S.SHAPES[shape]
    kind = info["kind"]
    named = lambda spec: jax.tree_util.tree_map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, PS))

    if kind == "train":
        state_sds = S.train_state_sds(model)
        st_spec = state_specs(state_sds, mesh, cfg)
        step_fn, _, _ = make_train_step(model, mesh)
        batch_sds, batch_spec = S.input_specs(cfg, shape, mesh)
        fn = jax.jit(step_fn,
                     in_shardings=(named(st_spec), named(batch_spec)),
                     out_shardings=(named(st_spec), None),
                     donate_argnums=(0,))
        lowered = fn.lower(state_sds, batch_sds)
        tokens_per_step = info["batch"] * info["seq"]
        model_flops = 6.0 * cfg.active_param_count() * tokens_per_step
    else:
        params = S.params_sds(model)
        p_spec = rules.params_specs(params, mesh, cfg)
        cache = S.cache_sds(model, shape)
        c_spec = rules.cache_specs(cfg, mesh, cache)
        data_sds, data_spec = S.input_specs(cfg, shape, mesh)
        if kind == "prefill":
            def prefill_step(params, tokens, cache):
                return model.prefill(params, tokens, cache)
            fn = jax.jit(prefill_step,
                         in_shardings=(named(p_spec),
                                       named(data_spec["tokens"]),
                                       named(c_spec)),
                         out_shardings=(None, named(c_spec)),
                         donate_argnums=(2,))
            lowered = fn.lower(params, data_sds["tokens"], cache)
            tokens_per_step = info["batch"] * info["seq"]
            model_flops = 2.0 * cfg.active_param_count() * tokens_per_step
        else:
            def serve_step(params, token, cache, pos):
                return model.decode_step(params, token, cache, pos)
            fn = jax.jit(serve_step,
                         in_shardings=(named(p_spec),
                                       named(data_spec["token"]),
                                       named(c_spec), None),
                         out_shardings=(None, named(c_spec)),
                         donate_argnums=(2,))
            lowered = fn.lower(params, data_sds["token"], cache,
                               data_sds["pos"])
            tokens_per_step = info["batch"]
            model_flops = 2.0 * cfg.active_param_count() * tokens_per_step

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    rec: Dict = {"status": "ok", "chips": chips,
                 "lower_s": round(t_lower, 1),
                 "compile_s": round(t_compile, 1)}
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}

    roof = hlo_mod.analyze(compiled, chips=chips, model_flops=model_flops)
    rec["roofline"] = roof.row()
    rec["tokens_per_step"] = tokens_per_step
    rec["params"] = cfg.param_count()
    rec["active_params"] = cfg.active_param_count()
    return rec


def main() -> None:
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.launch.specs import SHAPES
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp)
                except Exception as e:
                    rec = {"status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" flops={r['flops']:.3g}"
                             f" coll={r['coll_bytes']:.3g}B"
                             f" bottleneck={r['bottleneck']}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[done]   {key}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
