"""zamba2-2.7b [hybrid] — 54L d=2560 32H (kv=32) ff=10240 vocab=32000,
ssm_state=64; Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]"""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, ssm_state=64,
        attn_every=6)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=4, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=256, ssm_state=8,
                               attn_every=2, dtype="float32", max_seq=64)
