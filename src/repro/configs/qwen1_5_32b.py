"""qwen1.5-32b [dense] — 64L d=5120 40H (kv=40) ff=27392 vocab=152064,
QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]"""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
        n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064, qkv_bias=True,
        fsdp=True)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=160, vocab=256,
                               dtype="float32", fsdp=False, max_seq=64)
