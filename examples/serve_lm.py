"""Serving example: batched requests against a small dense LM through the
fused decode fast path — per-request sampling runs inside the jitted
on-device chunk, and the continuous-batching engine streams the same
requests through a fixed set of device slots with token-identical output.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import BatchedEngine, ContinuousEngine, Request


def main():
    cfg = ModelConfig(name="lm-serve", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=2, d_ff=768,
                      vocab=1024, dtype="float32", remat=False, max_seq=256)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)

    prompts = [jax.random.randint(jax.random.fold_in(key, i), (8 + 2 * i,),
                                  0, cfg.vocab) for i in range(6)]
    # each request brings its OWN sampling knobs
    reqs = [Request(prompt=p, max_new_tokens=24,
                    temperature=0.8 if i % 2 else 0.0, top_k=8 if i % 2 else 0)
            for i, p in enumerate(prompts)]

    engine = BatchedEngine(model, params, max_seq=128, chunk=8)
    t0 = time.time()
    outs = engine.run(reqs, key=jax.random.PRNGKey(7))
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    print(f"static batch={len(reqs)}  {n} tokens in {dt:.2f}s  "
          f"({n/dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"request[{i}] ({len(prompts[i])} prompt toks) -> {o[:16]}")

    # the same traffic through 3 continuous-batching slots: admissions and
    # retirements happen at chunk boundaries; tokens are identical
    cont = ContinuousEngine(model, params, max_seq=128, slots=3, chunk=8)
    t0 = time.time()
    outs2 = cont.run(reqs, key=jax.random.PRNGKey(7))
    dt = time.time() - t0
    n2 = sum(len(o) for o in outs2)
    print(f"continuous slots=3  {n2} tokens in {dt:.2f}s  ({n2/dt:.1f} tok/s)"
          f"  token-identical to static: {outs2 == outs}")


if __name__ == "__main__":
    main()
