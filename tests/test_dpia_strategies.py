"""Oracle-equality property tests for every strategy rewrite (the file
src/repro/core/dpia/strategies.py's docstring promises), plus the
repro.autotune subsystem: cache round-trip, cost-model monotonicity,
deterministic search, and the tuned-vs-default acceptance property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.dpia import interp, phrases as P, strategies
from repro.core.dpia.types import Arr, Num
from repro import autotune
from repro.autotune import TuningCache, cache as cache_mod, cost, space
from repro.kernels import dpia_blas, ref


def oracle_eq(e1, e2, env, rtol=1e-5):
    np.testing.assert_allclose(np.asarray(interp.interp(e1, env)),
                               np.asarray(interp.interp(e2, env)),
                               rtol=rtol, atol=1e-6)


# ---------------------------------------------------------------------------
# rewrite oracle equality
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), b=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2 ** 16))
def test_split_join_oracle(n, b, seed):
    rng = np.random.RandomState(seed)
    xs = P.var_exp("xs", Arr(n, Num()))
    m = P.Map(lambda x: P.add(P.mul(x, x), P.lit(2.0)), xs)
    env = {"xs": jnp.asarray(rng.randn(n), "float32")}
    oracle_eq(m, strategies.split_join(m, b), env)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 16, 64]), b=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2 ** 16))
def test_blocked_reduce_oracle(n, b, seed):
    rng = np.random.RandomState(seed)
    xs = P.var_exp("xs", Arr(n, Num()))
    r = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0), xs)
    env = {"xs": jnp.asarray(rng.randn(n), "float32")}
    oracle_eq(r, strategies.blocked_reduce(r, b), env, rtol=1e-4)
    oracle_eq(r, strategies.blocked_reduce(r, b, partial_level=P.GRID(0)),
              env, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 32]), seed=st.integers(0, 2 ** 16))
def test_fuse_map_into_reduce_oracle(n, seed):
    rng = np.random.RandomState(seed)
    xs = P.var_exp("xs", Arr(n, Num()))
    r = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                 P.Map(lambda x: P.mul(x, x), xs))
    env = {"xs": jnp.asarray(rng.randn(n), "float32")}
    oracle_eq(r, strategies.fuse_map_into_reduce(r), env, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), w=st.sampled_from([4, 8]),
       seed=st.integers(0, 2 ** 16))
def test_vectorize_oracle(n, w, seed):
    rng = np.random.RandomState(seed)
    xs = P.var_exp("xs", Arr(n, Num()))
    m = P.Map(lambda x: P.mul(x, P.lit(3.0)), xs, level=P.SEQ)
    env = {"xs": jnp.asarray(rng.randn(n), "float32")}
    oracle_eq(m, strategies.vectorize(m, w), env)


def test_rewrite_chain_compiles_and_matches(rng):
    """The quickstart chain (fuse + blocked_reduce) through the pipeline."""
    n = 256
    expr, argv = dpia_blas.naive_dot(n)
    fused = strategies.fuse_map_into_reduce(expr)
    blocked = strategies.blocked_reduce(fused, 64, partial_level=P.GRID(0),
                                        combine=lambda x, a: P.add(a, x))
    ax = jnp.asarray(rng.randn(n), "float32")
    ay = jnp.asarray(rng.randn(n), "float32")
    from repro import compiler
    fn = compiler.Program(blocked, argv).check().lower().compile("jnp")
    np.testing.assert_allclose(np.asarray(fn(ax, ay)),
                               np.asarray(ref.dot(ax, ay)), rtol=1e-4)


# ---------------------------------------------------------------------------
# search: determinism + empty input
# ---------------------------------------------------------------------------

def test_search_empty_raises_clear_error():
    with pytest.raises(ValueError, match="empty candidate list"):
        strategies.search([], lambda c: 0.0)


def test_search_breaks_ties_deterministically():
    a, b, c = P.lit(1.0), P.lit(2.0), P.lit(3.0)
    # all costs equal: earliest candidate wins, on every permutation's order
    assert strategies.search([a, b, c], lambda _: 7.0) is a
    assert strategies.search([c, a, b], lambda _: 7.0) is c
    # NaN costs never win
    costs = {id(a): float("nan"), id(b): 1.0, id(c): 1.0}
    assert strategies.search([a, b, c], lambda x: costs[id(x)]) is b


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_monotone_in_problem_size():
    """Same strategy, growing n -> non-decreasing predicted seconds."""
    prev = 0.0
    for n in (1024, 2048, 4096, 8192, 16384):
        e, _ = dpia_blas.strategy_dot(n, block=512)
        s = cost.predicted_seconds(e)
        assert s >= prev, (n, s, prev)
        prev = s


def test_cost_prefers_blocked_over_sequential_dot():
    n = 8192
    naive, _ = dpia_blas.naive_dot(n)
    blocked, _ = dpia_blas.strategy_dot(n, block=2048)
    assert cost.predicted_seconds(blocked) < cost.predicted_seconds(naive)


def test_cost_penalises_vmem_overflow():
    small = cost.CostEstimate(vmem_peak=2 ** 20)
    big = cost.CostEstimate(vmem_peak=2 ** 30)
    assert big.seconds() > small.seconds()


# ---------------------------------------------------------------------------
# tuning cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_persistence(tmp_path):
    path = str(tmp_path / "autotune.json")
    key = cache_mod.make_key("dot", {"n": 4096})
    rec = {"kernel": "dot", "params": {"block": 4096, "leaf": "vpu"},
           "source": "measured", "measured_us": 12.5}
    c1 = TuningCache(path)
    assert c1.get(key) is None
    c1.put(key, rec)
    assert c1.get(key) == rec
    # a fresh instance reads the same record back from disk
    c2 = TuningCache(path)
    assert c2.get(key) == rec
    assert key in c2 and len(c2) == 1


def test_cache_survives_corruption(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json!!")
    c = TuningCache(str(path))
    assert c.get("anything") is None
    c.put("k", {"params": {}})      # and it can still write afterwards
    assert TuningCache(str(path)).get("k") == {"params": {}}


def test_second_tune_is_served_from_cache_without_research(
        tuning_cache, monkeypatch):
    r1 = autotune.tune("dot", n=1024, cache=tuning_cache, measure=False)
    assert r1.source == "analytic"

    def boom(*a, **k):
        raise AssertionError("re-searched despite cache hit")
    monkeypatch.setattr(space, "enumerate_space", boom)
    monkeypatch.setattr(autotune.measure, "rank_by_cost", boom)
    r2 = autotune.tune("dot", n=1024, cache=tuning_cache, measure=False)
    assert r2.source == "cache" and r2.params == r1.params


# ---------------------------------------------------------------------------
# tune(): acceptance — tuned beats (or ties) the default, then caches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,shape", [
    ("dot", dict(n=4096)),
    ("matmul", dict(m=512, k=512, n=512)),
])
def test_tuned_no_worse_than_default_and_cached(kernel, shape, tuning_cache,
                                                monkeypatch):
    res = autotune.tune(kernel, cache=tuning_cache, measure=True, top_k=3,
                        iters=3, **shape)
    assert res.source == "measured"
    assert res.measured_us is not None
    default_key = space.params_key(space.default_params(kernel, **shape))
    # the default strategy is always measured alongside the top-k, and the
    # winner is the measured minimum -> tuned <= default by construction
    assert default_key in res.timings
    assert res.measured_us <= res.timings[default_key]

    # second call: persistent-cache hit, no re-search, same params
    def boom(*a, **k):
        raise AssertionError("re-searched despite measured cache entry")
    monkeypatch.setattr(autotune.measure, "measure_candidates", boom)
    monkeypatch.setattr(autotune.measure, "rank_by_cost", boom)
    res2 = autotune.tune(kernel, cache=tuning_cache, measure=True, **shape)
    assert res2.source == "cache"
    assert res2.params == res.params
    # ... including from a fresh cache object over the same file
    res3 = autotune.tune(kernel, cache=TuningCache(tuning_cache.path),
                         measure=True, **shape)
    assert res3.source == "cache" and res3.params == res.params


def test_tuned_strategies_stay_correct(tuning_cache, rng):
    """Strategy preservation: whatever the tuner picks computes the spec."""
    for kernel, shape, args, want in [
        ("dot", dict(n=2048),
         (jnp.asarray(rng.randn(2048), "float32"),
          jnp.asarray(rng.randn(2048), "float32")), None),
        ("rmsnorm", dict(rows=32, d=256),
         (jnp.asarray(rng.randn(32, 256), "float32"),
          jnp.asarray(rng.randn(256), "float32")), None),
        ("softmax", dict(rows=16, d=128),
         (jnp.asarray(rng.randn(16, 128), "float32"),), None),
    ]:
        res = autotune.tune(kernel, cache=tuning_cache, measure=False, **shape)
        cand = space.candidate_from_params(kernel, res.params, **shape)
        fn = cand.program().check().lower().compile("jnp")
        got = np.asarray(fn(*args))
        want = {"dot": lambda: ref.dot(*args),
                "rmsnorm": lambda: ref.rmsnorm(*args),
                "softmax": lambda: ref.softmax(args[0])}[kernel]()
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-3,
                                   atol=1e-5)


def test_tune_expr_path_and_autotuned_decorator(tuning_cache, rng):
    n = 512
    expr, argv = dpia_blas.naive_dot(n)
    res = autotune.tune(expr, arg_vars=argv, cache=tuning_cache,
                        measure=False)
    assert res.kernel.startswith("expr:")
    assert res.n_candidates > 1
    res2 = autotune.tune(expr, arg_vars=argv, cache=tuning_cache,
                         measure=False)
    assert res2.source == "cache"

    @autotune.autotuned("dot", cache=tuning_cache)
    def tuned_dot(x, y):
        """sum_i x_i * y_i"""

    x = jnp.asarray(rng.randn(n), "float32")
    y = jnp.asarray(rng.randn(n), "float32")
    np.testing.assert_allclose(np.asarray(tuned_dot(x, y)),
                               np.asarray(ref.dot(x, y)), rtol=1e-4)
    assert len(tuned_dot.compiled) == 1


def test_tune_empty_space_raises(tuning_cache):
    with pytest.raises(ValueError, match="unknown kernel"):
        autotune.tune("conv3d", cache=tuning_cache, n=7)


def test_softmax_strategy_oracle(rng):
    rows, d = 8, 64
    naive, _ = dpia_blas.naive_softmax(rows, d)
    strat, _ = dpia_blas.strategy_softmax(rows, d, row_block=4)
    env = {"xs": jnp.asarray(rng.randn(rows, d), "float32")}
    oracle_eq(naive, strat, env)
    np.testing.assert_allclose(
        np.asarray(interp.interp(naive, env)),
        np.asarray(ref.softmax(env["xs"])), rtol=1e-5, atol=1e-6)
