"""Expert-parallel all-to-all dispatch (models/moe_ep.py) vs the GSPMD MoE:
same outputs, flowing gradients (subprocess: needs a multi-device mesh)."""
import subprocess
import sys

import pytest

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}  # host-platform test: skip TPU probing

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import smoke_config
from repro.models import ffn, moe_ep
from repro.sharding import ctx

cfg = smoke_config("dbrx_132b")                       # 4 experts top-2
cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # no drops
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
ctx.set_mesh(mesh)
assert moe_ep.applicable(cfg, mesh)

p = ffn.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), "float32")

ref_out, _ = ffn.moe(p, cfg, x)
got_out, got_aux = jax.jit(lambda p, x: moe_ep.moe_ep(p, cfg, x))(p, x)
np.testing.assert_allclose(np.asarray(got_out), np.asarray(ref_out),
                           rtol=3e-3, atol=3e-3)
assert np.isfinite(float(got_aux))

g = jax.jit(jax.grad(lambda p, x: moe_ep.moe_ep(p, cfg, x)[0].sum()))(p, x)
assert float(jnp.abs(g.w_gate).sum()) > 0
assert float(jnp.abs(g.router).sum()) > 0

# the HLO must contain all-to-all (the whole point)
txt = jax.jit(lambda p, x: moe_ep.moe_ep(p, cfg, x)).lower(p, x).compile().as_text()
assert "all-to-all" in txt
print("MOE_EP_OK")
"""


@pytest.mark.slow
def test_moe_ep_matches_gspmd_moe():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600, env=ENV)
    assert "MOE_EP_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-2500:]
