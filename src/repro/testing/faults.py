"""Deterministic fault injection for the serving/compiler spine.

The resilience claim (docs/resilience.md) is that a fault *degrades* the
strategy instead of crashing the engine: a NaN request is quarantined, a
failed executor build falls down the degradation ladder, a corrupt artefact
file is quarantined and rebuilt.  Those paths are only trustworthy if they
are exercised — this module makes every fault a *scheduled, replayable
event* instead of something a test monkeypatches ad hoc.

A fault is ``(site, match, after, times, value)``: it fires at a named
injection **site** (a ``faults.should_fire("site", **ctx)`` call compiled
into the production code path), for the occurrences whose context matches
``match`` (fnmatch patterns over the ctx values), skipping the first
``after`` matches and firing ``times`` times (``-1``: every time).
``value`` is a free payload (e.g. seconds for ``serve.slow_chunk``).

Activation is scoped and composable::

    from repro.testing import faults
    with faults.inject("serve.nan_prefill(req_id=1); serve.chunk_error"):
        engine.run(requests)

or process-wide via the ``REPRO_FAULTS`` environment variable (same spec
string), so CI and benches replay exact failure schedules without code.

Spec grammar (semicolon-separated faults)::

    site                          fire on the first matching occurrence
    site(k=v, k2=v2)              ctx match (fnmatch patterns: k=*dot*)
    site(times=3)                 fire on the first three occurrences
    site(after=2)                 skip the first two occurrences
    site(times=-1)                fire on every occurrence
    site(value=0.25)              payload (float if it parses, else str)

Sites wired into the tree (see docs/resilience.md for the fault model):

    serve.nan_prefill   ctx req_id — poison a request's admission logits
    serve.nan_decode    ctx req_id — poison a slot's KV cache after admit
    serve.chunk_error   ctx req_ids — raise a transient error before the
                        decode chunk (req_ids: comma-joined active ids)
    serve.slow_chunk    ctx req_ids — sleep ``value`` s before the chunk
    serve.pool_exhausted  admission sees a block-starved pool (deferral)
    serve.pool_corrupt  damage the KV block pool (validate() then catches)
    executor.build      ctx key — raise InjectedFault in executor staging
    artefact.corrupt    ctx what, path — a JSON artefact reads as corrupt
    mesh.host_lost      ctx host, axis — a failure domain's devices vanish
                        at the chunk boundary (ShardedEngine hosts=)
    mesh.host_slow      ctx host — a straggling host; ``value`` is the
                        simulated delay in seconds; escalates to lost
                        after ``slow_threshold`` consecutive firings
    collective.timeout  ctx axis — a cross-host collective hangs; ``value``
                        (int) names the presumed-dead host, default last

When no plan is active (no ``inject`` scope, no ``REPRO_FAULTS``),
``should_fire`` is two dict lookups — the sites cost nothing in
production.  All firing decisions are counted (``faults.injected``) and
event-logged through ``repro.obs`` so a faulted run's trace shows exactly
which faults fired where.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import os
import threading
from typing import Dict, Iterable, List, Optional, Union

__all__ = ["Fault", "InjectedFault", "parse_spec", "inject", "active",
           "should_fire", "raise_if", "corrupt_json_file", "corrupt_pool",
           "ENV_VAR"]

ENV_VAR = "REPRO_FAULTS"

_META_KEYS = ("times", "after", "value")


class InjectedFault(RuntimeError):
    """The deterministic failure raised at an injected fault site."""


@dataclasses.dataclass
class Fault:
    """One scheduled fault (see module docstring for the semantics)."""
    site: str
    match: Dict[str, str] = dataclasses.field(default_factory=dict)
    after: int = 0
    times: int = 1              # -1: fire on every matching occurrence
    value: Optional[object] = None
    # runtime accounting (mutated under the module lock)
    seen: int = 0
    fired: int = 0

    def matches(self, ctx: Dict[str, object]) -> bool:
        return all(fnmatch.fnmatchcase(str(ctx.get(k)), pat)
                   for k, pat in self.match.items())

    def describe(self) -> str:
        args = [f"{k}={v}" for k, v in sorted(self.match.items())]
        if self.after:
            args.append(f"after={self.after}")
        if self.times != 1:
            args.append(f"times={self.times}")
        if self.value is not None:
            args.append(f"value={self.value}")
        return self.site + (f"({', '.join(args)})" if args else "")


def _parse_value(v: str):
    v = v.strip()
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def parse_spec(spec: str) -> List[Fault]:
    """Parse a ``REPRO_FAULTS``-style spec string into a fault plan."""
    plan: List[Fault] = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        site, args = part, ""
        if "(" in part:
            if not part.endswith(")"):
                raise ValueError(f"malformed fault {part!r}: missing ')'")
            site, args = part[:-1].split("(", 1)
        site = site.strip()
        if not site:
            raise ValueError(f"malformed fault {part!r}: empty site")
        f = Fault(site=site)
        for kv in args.split(","):
            kv = kv.strip()
            if not kv:
                continue
            if "=" not in kv:
                raise ValueError(f"malformed fault arg {kv!r} in {part!r} "
                                 f"(expected k=v)")
            k, v = (s.strip() for s in kv.split("=", 1))
            if k == "times":
                f.times = int(v)
            elif k == "after":
                f.after = int(v)
            elif k == "value":
                f.value = _parse_value(v)
            else:
                f.match[k] = v
        plan.append(f)
    return plan


# ---------------------------------------------------------------------------
# activation: an inject() stack + the env plan
# ---------------------------------------------------------------------------

_lock = threading.Lock()
_stack: List[List[Fault]] = []
_env_raw: Optional[str] = None
_env_plan: List[Fault] = []


def _env() -> List[Fault]:
    """The plan parsed from ``REPRO_FAULTS`` (re-parsed when it changes;
    firing counters persist for the lifetime of one env value)."""
    global _env_raw, _env_plan
    raw = os.environ.get(ENV_VAR) or None
    if raw != _env_raw:
        _env_raw = raw
        _env_plan = parse_spec(raw) if raw else []
    return _env_plan


def active() -> bool:
    """True when any fault plan (scoped or env) is in effect."""
    return bool(_stack) or bool(os.environ.get(ENV_VAR))


@contextlib.contextmanager
def inject(*faults: Union[str, Fault, Iterable[Fault]]):
    """Activate a fault plan for the dynamic extent of the ``with`` block.

    Arguments may be spec strings (parsed with :func:`parse_spec`),
    :class:`Fault` objects, or iterables of them; plans nest (all active
    plans are consulted, innermost first).  Yields the plan so callers can
    read ``fault.fired`` counts afterwards."""
    plan: List[Fault] = []
    for f in faults:
        if isinstance(f, str):
            plan.extend(parse_spec(f))
        elif isinstance(f, Fault):
            plan.append(f)
        else:
            plan.extend(f)
    with _lock:
        _stack.append(plan)
    try:
        yield plan
    finally:
        with _lock:
            _stack.remove(plan)


def should_fire(site: str, **ctx) -> Optional[Fault]:
    """The fault scheduled to fire at this occurrence of ``site``, or None.

    Deterministic: every call with a matching context advances the fault's
    occurrence counter, so a given call sequence always fires the same
    schedule.  Near-free when no plan is active."""
    if not _stack and not os.environ.get(ENV_VAR):
        return None
    with _lock:
        for plan in (*reversed(_stack), _env()):
            for f in plan:
                if f.site != site or not f.matches(ctx):
                    continue
                n = f.seen
                f.seen += 1
                if n < f.after:
                    continue
                if f.times >= 0 and n >= f.after + f.times:
                    continue
                f.fired += 1
                break
            else:
                continue
            break
        else:
            return None
    from repro import obs
    obs.counter("faults.injected").inc()
    # the site ctx (req_id / req_ids / key / path) rides into the event so
    # traces and flight dumps attribute each firing to the request it hit
    obs.event("faults.injected", site=site, fault=f.describe(),
              **{k: str(v) for k, v in ctx.items()})
    return f


def raise_if(site: str, **ctx) -> None:
    """Raise :class:`InjectedFault` when a fault is scheduled here."""
    f = should_fire(site, **ctx)
    if f is not None:
        raise InjectedFault(f"injected fault at {site} "
                            f"({f.describe()}; occurrence {f.seen})")


# ---------------------------------------------------------------------------
# deterministic damage helpers (benches/tests corrupt state through these so
# "corruption" is one reproducible operation, not a hand-rolled mutation)
# ---------------------------------------------------------------------------

def corrupt_json_file(path: str, mode: str = "garbage") -> str:
    """Deterministically corrupt a JSON artefact file in place.

    ``garbage`` overwrites with non-JSON bytes; ``truncate`` drops the
    second half (syntactically broken); ``stale`` rewrites one top-level
    string value without refreshing the embedded checksum (semantically
    broken: valid JSON, failed integrity check).  Returns ``path``."""
    with open(path) as f:
        text = f.read()
    if mode == "garbage":
        out = "{ not json at all\x00"
    elif mode == "truncate":
        out = text[: len(text) // 2]
    elif mode == "stale":
        import json
        doc = json.loads(text)
        doc["version"] = "corrupted"
        out = json.dumps(doc)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    with open(path, "w") as f:
        f.write(out)
    return path


def corrupt_pool(pool) -> str:
    """Deterministically damage a ``repro.serve.paged.BlockPool`` so its
    ``validate()`` fails: double-book one block id (the bit-flip model).
    Returns a description of the damage."""
    if pool._free:
        b = pool._free[-1]
        pool._free.append(b)
        return f"duplicated free block {b}"
    for owner, blocks in pool._owned.items():
        if blocks:
            pool._free.append(blocks[0])
            return f"freed block {blocks[0]} still owned by {owner}"
    pool._free.append(pool.n_blocks + 1)
    return "appended out-of-range block id"
