"""AdamW with global-norm clipping, cosine schedule, and optional 8-bit
moments (per-tensor-scaled int8) for 100B+ configs — the optimizer-state
memory trick that lets grok-1-314b train on 16 GB/chip meshes.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # () float32 per-tensor scale


def _quantize(x) -> QTensor:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    return QTensor(jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8),
                   scale)


def _dequantize(t: QTensor):
    return t.q.astype(jnp.float32) * t.scale


def init(params, *, use_8bit: bool = False) -> Dict:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if use_8bit else z

    return {
        "m": jax.tree_util.tree_map(zero_like, params),
        "v": jax.tree_util.tree_map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / max(warmup, 1), 1.0)
    prog = jnp.clip((step_f - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(params, grads, state: Dict, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: Optional[float] = 1.0, use_8bit: bool = False):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_f = _dequantize(m) if use_8bit else m
        v_f = _dequantize(v) if use_8bit else v
        m_new = b1 * m_f + (1 - b1) * g
        v_new = b2 * v_f + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        p32 = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p_new = p32 - lr * (delta + wd * p32)
        m_out = _quantize(m_new) if use_8bit else m_new
        v_out = _quantize(v_new) if use_8bit else v_new
        return p_new.astype(p.dtype), m_out, v_out

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    is_q = lambda x: isinstance(x, QTensor)  # noqa: E731
    flat_m = jax.tree_util.tree_leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree_util.tree_leaves(state["v"], is_leaf=is_q)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm}
