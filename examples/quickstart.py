"""Quickstart: the paper's pipeline end to end on dot product.

1. Write the functional spec (paper eq. (1)).
2. Derive a TPU strategy by semantics-preserving rewrites (paper eq. (2)).
3. Stage the pipeline explicitly with ``repro.compiler.Program``:
   ``check()`` (SCIR race-freedom) -> ``lower()`` (Stage I -> II) ->
   ``compile(backend)`` (Stage III via the backend registry).
4. Run all registered single-host backends against the mathematical reading.
5. Let the autotuner pick the strategy instead (repro.autotune): searched
   once, then served from the persistent tuning cache.
6. Scope kernel dispatch with ``compiler.options`` (thread-local — the
   replacement for the old process-global ``set_default_impl``).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.core.dpia import interp, phrases as P, strategies
from repro.core.dpia.pretty import show
from repro.core.dpia.types import Arr, Num

N = 8192

# -- 1. functional specification (the mathematical reading) ------------------
xs = P.var_exp("xs", Arr(N, Num()))
ys = P.var_exp("ys", Arr(N, Num()))
dot_spec = P.Reduce(
    lambda x, a: P.add(a, x), P.lit(0.0),
    P.Map(lambda z: P.mul(P.Fst(z), P.Snd(z)), P.Zip(xs, ys)))
print("== functional spec ==")
print(show(dot_spec), "\n")

# -- 2. a strategy: fuse, block for the grid, VPU-reduce each block ----------
# Strategies are rewrites (expr -> expr); Program.lower applies them and
# translates the result to imperative DPIA (Stage I -> II).
def tpu_strategy(e):
    fused = strategies.fuse_map_into_reduce(e)
    return strategies.blocked_reduce(fused, 2048, partial_level=P.GRID(0),
                                     combine=lambda x, a: P.add(a, x))

prog = compiler.Program(dot_spec, [xs, ys], name="dot").lower(tpu_strategy)
print("== strategy (after rewrites) ==")
print(show(prog.expr), "\n")

# -- 3. the staged pipeline: SCIR check, then inspect the imperative form ----
prog.check()                     # well-typed + data-race free, or it raises
print("== imperative DPIA (stage II) ==")
print(prog.show()[:800], "...\n")

# -- 4. execute via every registered single-host backend against the oracle --
rng = np.random.RandomState(0)
ax = jnp.asarray(rng.randn(N), "float32")
ay = jnp.asarray(rng.randn(N), "float32")
oracle = interp.interp(dot_spec, {"xs": ax, "ys": ay})

for backend in compiler.backend_names():
    if compiler.get_backend(backend).requires:
        continue                 # e.g. shardmap needs a mesh
    fn = prog.check().lower().compile(backend)
    got = fn(ax, ay)
    np.testing.assert_allclose(got, oracle, rtol=1e-4)
    print(f"backend {backend:8s}: {float(got):+.6f}  == oracle OK")
print(f"oracle (vmap reading):  {float(oracle):+.6f}")

# -- 5. or let the autotuner derive the strategy ------------------------------
# tune() consumes Programs: the candidate space comes from rewriting the
# program's functional spec, exactly as we rewrote it by hand above.
from repro import autotune

spec_prog = compiler.Program(dot_spec, [xs, ys], name="dot-spec")
res = autotune.tune(spec_prog, backend="jnp", top_k=3, iters=3)
print(f"\n== autotuned strategy ==\n{res.params}  "
      f"({res.source}, {res.n_candidates} candidates"
      + (f", {res.measured_us:.0f} us" if res.measured_us else "") + ")")
res2 = autotune.tune(spec_prog, backend="jnp")
print(f"second tune call: served from {res2.source} "
      f"({autotune.default_cache().path})")

# -- 6. scoped kernel dispatch (no process globals) ---------------------------
from repro.kernels import ops

with compiler.options(backend="dpia-jnp", autotune=False):
    scoped = ops.dot(ax, ay)     # the whole model zoo dispatches like this
np.testing.assert_allclose(scoped, oracle, rtol=1e-4)
print(f"\nops.dot under options(backend='dpia-jnp'): {float(scoped):+.6f} OK")
