"""Semantics-preserving strategy rewrites (Steuwer et al. 2015 layer).

The paper assumes parallelisation strategies are *derived* at the functional
level by semantics-preserving rewriting and only then compiled.  These are the
rewrite rules we use, each a function Expr -> Expr whose oracle-equality is
property-tested (tests/test_dpia_strategies.py):

  split_join   map f xs            = join (map (map f) (split b xs))
  blocked_reduce (assoc f, unit z)
               reduce f z xs       = reduce f z (map (reduce f z) (split b xs))
  fuse_map_into_reduce
               reduce f z (map g xs) = reduce (λx a. f (g x) a) z xs
  vectorize    map (scalar op) xs  = asScalar (map (vector op) (asVector w xs))
  distribute   assign mesh/grid/seq levels to maps/reduces
  stage_vmem   wrap an expression so its materialisation lands in VMEM

plus a tiny exhaustive strategy search used by the benchmarks (the analogue
of the ICFP'15 stochastic search, feasible here because our kernels have a
small, structured strategy space).
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional

from . import phrases as P
from .types import Arr, Num, Pair, Vec


def split_join(m: P.Map, b: int) -> P.Phrase:
    """map f xs  ->  join (map[level] (map f) (split b xs))."""
    d = P.exp_data(m.e)
    assert isinstance(d, Arr) and d.n % b == 0
    return P.Join(P.Map(
        lambda blk: P.Map(m.f, blk, level=P.SEQ, space=m.space),
        P.Split(b, m.e),
        level=m.level))


def blocked_reduce(r: P.Reduce, b: int, *,
                   partial_level: Optional[P.Par] = None,
                   combine=None) -> P.Phrase:
    """reduce f z xs -> reduce g z (map (reduce f z) (split b xs)).

    ``g`` (``combine``) merges per-block partials; it defaults to ``f`` when
    the reducer is homogeneous (d1 == d2).  Caller asserts associativity of
    the combine with unit z (the rewrite system's semantic side condition,
    as in the paper's provenance)."""
    d = P.exp_data(r.e)
    assert isinstance(d, Arr) and d.n % b == 0
    g = combine or r.f
    return P.Reduce(
        g, r.init,
        P.Map(lambda blk: P.Reduce(r.f, r.init, blk, level=P.SEQ),
              P.Split(b, r.e),
              level=partial_level or P.PAR),
        level=r.level)


def fuse_map_into_reduce(r: P.Reduce) -> P.Phrase:
    """reduce f z (map g xs) -> reduce (λx a. f (g x) a) z xs."""
    m = r.e
    assert isinstance(m, P.Map), "reduce input is not a map"
    return P.Reduce(lambda x, a: r.f(m.f(x), a), r.init, m.e, level=r.level)


def vectorize(m: P.Map, w: int) -> P.Phrase:
    """map f xs -> asScalar (map f_vec (asVector w xs)) for pointwise f.

    Our UnOp/BinOp are already elementwise at vector types, so ``f`` applied
    to a vector element *is* f_vec — the paper's asVector story (section 6.2),
    with w = TPU lane width rather than OpenCL's float4."""
    d = P.exp_data(m.e)
    assert isinstance(d, Arr) and isinstance(d.elem, Num) and d.n % w == 0
    return P.AsScalar(P.Map(m.f, P.AsVector(w, m.e), level=m.level))


def with_level(e: P.Phrase, level: P.Par) -> P.Phrase:
    """Assign an execution level to the outermost map/reduce."""
    if isinstance(e, P.Map):
        return P.Map(e.f, e.e, level=level, space=e.space)
    if isinstance(e, P.Reduce):
        return P.Reduce(e.f, e.init, e.e, level=level)
    raise TypeError("with_level: not a map/reduce")


def stage_vmem(e: P.Phrase) -> P.Phrase:
    """toVMEM wrapper: materialise the value in VMEM (paper's toLocal)."""
    return P.ToMem(P.VMEM, e)


# ---------------------------------------------------------------------------
# strategy enumeration / search (the ICFP'15 search, miniaturised).
# The real autotuner lives in repro.autotune (generalised spaces, analytic
# cost model, measured refinement, persistent cache); these entry points are
# kept as thin compatibility shims over it.
# ---------------------------------------------------------------------------

def enumerate_dot_strategies(n: int, blocks: Iterable[int] = (256, 1024, 2048),
                             lanes: Iterable[int] = (128,)) -> List[dict]:
    """Strategy space for dot-product-like reductions of length n.

    Compatibility shim: delegates to ``repro.autotune.space`` (which holds
    the generalised per-kernel spaces) and preserves the seed's output
    format of ``{"block": b, "vector": w|None}`` dicts."""
    from repro.autotune import space as _space
    return _space.dot_param_grid(n, blocks=blocks, lanes=lanes)


def search(candidates: List[P.Phrase], cost_fn: Callable[[P.Phrase], float]
           ) -> P.Phrase:
    """Pick the candidate strategy minimising ``cost_fn`` (compiled cost).

    Deterministic: NaN costs are treated as +inf, and ties (including the
    all-infinite case) are broken by earliest position in ``candidates``,
    so a fixed candidate order always yields the same winner."""
    if not candidates:
        raise ValueError(
            "strategies.search: empty candidate list — enumerate a "
            "non-empty strategy space first (see repro.autotune.space; "
            "e.g. no block size divides the input extent)")
    best, best_c = candidates[0], float("inf")
    for c in candidates:
        cost = cost_fn(c)
        if cost == cost and cost < best_c:  # NaN-safe strict improvement
            best, best_c = c, cost
    return best
