"""repro.autotune — cost-model-guided strategy autotuner.

The paper's thesis is that parallelisation strategies are chosen at the
functional level and compiled strategy-preservingly; this package chooses
them *automatically*.  It generalises the seed's dot-only exhaustive search
(ICFP'15 style, cf. ELEVATE arXiv:2002.02268) into a real autotuner:

  space    — strategy-space enumeration over the DPIA rewrites
             (split_join / blocked_reduce / fuse_map_into_reduce /
             vectorize / level assignment) for dot/reduce, map, matmul,
             rmsnorm and softmax-like kernels
  cost     — analytical roofline cost model (FLOPs, HBM/VMEM bytes,
             grid/loop overhead) ranking candidates without executing,
             plus an HLO-derived refinement via repro.analysis.hlo_counter
  measure  — compile-and-time refinement of the analytic top-k through the
             stage1 -> stage2 -> stage3 pipeline (jnp / pallas-interpret)
  cache    — persistent on-disk JSON tuning cache keyed by
             (kernel, shape, dtype, backend, mesh), with in-process memo
  api      — ``tune(...)`` / ``get_tuned(...)`` / ``@autotuned`` entry points

See docs/autotune.md for the cache format and the strategy-space tables.
"""
from . import api, cache, cost, measure, space  # noqa: F401
from .api import (  # noqa: F401
    TuneResult, autotuned, get_tuned, model_kernel_shapes, pick_kv_layout,
    tune, warm_for_model,
)
from .cache import TuningCache, default_cache  # noqa: F401
from .cost import (  # noqa: F401
    HW_PRESETS, CostEstimate, HwModel, KvLayoutCost, estimate, hw_model,
    kv_layout_cost, xla_cost,
)
from .space import Candidate, candidate_from_params, default_params, enumerate_space  # noqa: F401
