"""Distributed train step: remat (per-block, in the model), microbatch
gradient accumulation (compute/comm overlap: one all-reduce per window),
optional bf16 gradient compression, AdamW, sharding constraints (DP/FSDP/TP/
SP per sharding/rules.py).

``make_train_step`` returns a jitted function with explicit in/out shardings
so the same step lowers for 1 device (tests), 256 (single pod), or 512
(multi-pod) — the dry-run lowers exactly this function.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.models.transformer import Model
from repro.optim import adamw
from repro.sharding import rules


def make_train_state(model: Model, key, *, use_8bit: bool = False) -> Dict:
    params = model.init_params(key)
    opt = adamw.init(params, use_8bit=use_8bit)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def state_specs(state: Dict, mesh: Mesh, cfg):
    pspec = rules.params_specs(state["params"], mesh, cfg)

    # m/v have the same tree structure as params (possibly QTensor leaves)
    def mirror(spec, leaf):
        if isinstance(leaf, adamw.QTensor):
            return adamw.QTensor(spec, PS())
        return spec

    m_spec = jax.tree_util.tree_map(
        mirror, pspec,
        state["opt"]["m"],
        is_leaf=_is_ps)
    v_spec = jax.tree_util.tree_map(
        mirror, pspec,
        state["opt"]["v"],
        is_leaf=_is_ps)
    return {
        "params": pspec,
        "opt": {"m": m_spec, "v": v_spec, "step": PS()},
        "step": PS(),
    }


def _is_ps(x):
    return isinstance(x, PS)


def make_train_step(model: Model, mesh: Mesh, *, microbatches: int = 1,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, grad_bf16: bool = True,
                    donate: bool = True):
    """Build the jitted, sharded train step."""
    from repro.sharding import ctx
    ctx.set_mesh(mesh)
    cfg = model.cfg
    batch_spec = {
        "tokens": rules.batch_specs(mesh),
        "labels": rules.batch_specs(mesh),
    }

    def loss_fn(params, batch):
        # SP constraint on the embedding output is applied inside the model
        # boundary via activation sharding of inputs; XLA propagates.
        return model.loss(params, batch)

    def step_fn(state, batch):
        params = state["params"]

        if microbatches > 1:
            def micro(carry, mb):
                gacc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                if grad_bf16:
                    # accumulate in bf16: halves the all-reduce bytes (the
                    # DCN-crossing collective for the 'pod' axis)
                    g = jax.tree_util.tree_map(
                        lambda x: x.astype(jnp.bfloat16), g)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return gacc, loss

            mb_batch = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape,
                                    jnp.bfloat16 if grad_bf16
                                    else jnp.float32), params)
            gsum, losses = jax.lax.scan(micro, zeros, mb_batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) / microbatches, gsum)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        lr = adamw.cosine_schedule(state["step"], base_lr=base_lr,
                                   warmup=warmup, total=total_steps)
        new_params, new_opt, metrics = adamw.update(
            params, grads, state["opt"], lr=lr, use_8bit=cfg.opt_8bit)
        # in-graph NaN/inf guard: a poisoned step applies NO update (works
        # with donated buffers — the old state is still readable in-graph)
        good = jnp.isfinite(loss) & jnp.isfinite(metrics["grad_norm"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        new_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(good, n, o), new_state, state)
        metrics = dict(metrics, loss=loss, lr=lr,
                       applied=good.astype(jnp.int32))
        return new_state, metrics

    dummy_state_spec = None  # resolved at lower time by caller

    def jit_with(state_spec):
        return jax.jit(
            step_fn,
            in_shardings=(rules.named(mesh, state_spec),
                          rules.named(mesh, batch_spec)),
            out_shardings=(rules.named(mesh, state_spec), None),
            donate_argnums=(0,) if donate else (),
        )

    return step_fn, jit_with, batch_spec
