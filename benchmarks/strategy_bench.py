"""Strategy-mining benchmark: do mined abstractions pay for themselves?

The pipeline under test (all of it repro.strategy):

  1. warm a tuning corpus — tune the reduce/map kernels at several shapes;
     every winner's derivation (``StrategyTrace``) lands in the cache;
  2. mine the corpus — anti-unify winning traces into parameter-holed
     ``Abstraction`` s, persisted beside the cache;
  3. tune a NEW shape with the abstractions seeding the search, and count
     candidate evaluations until the incumbent-best strategy is reached:
     ``seeded_order`` must need no more evals than plain enumeration
     (asserted: seeded <= unseeded, and strictly fewer when the winner's
     derivation matches a mined abstraction);
  4. replay the winner's trace on the naive spec and require the rebuilt
     term to be structurally identical (fingerprint) to the winner —
     derivations are deterministic, not descriptive;
  5. the generic space on the fused RMSNorm->matmul term (an op with no
     hand-written space anywhere in the repo) must be non-trivial: the
     strategy language covers terms the params vocabulary never met.

Usage:
  PYTHONPATH=src python benchmarks/strategy_bench.py [--smoke] [--out FILE]

Writes BENCH_strategy.json (``--out`` to override) and prints a summary.
The output embeds the winning ``strategy_trace``, so
``validate_trace.py --strategy BENCH_strategy.json`` checks its schema.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

os.environ.setdefault("JAX_PLATFORMS", "cpu")

CORPUS = [
    ("dot", {"n": 1024}), ("dot", {"n": 2048}),
    ("asum", {"n": 1024}), ("asum", {"n": 2048}),
    ("scal", {"n": 1024}), ("scal", {"n": 2048}),
    ("rmsnorm", {"rows": 64, "d": 128}),
    ("rmsnorm", {"rows": 128, "d": 128}),
]
CORPUS_FULL = CORPUS + [
    ("dot", {"n": 8192}), ("asum", {"n": 8192}), ("scal", {"n": 8192}),
    ("softmax", {"rows": 64, "d": 128}),
    ("softmax", {"rows": 128, "d": 256}),
]


def warm_corpus(cache_path: str, smoke: bool) -> int:
    from repro import autotune
    n = 0
    for kernel, shape in (CORPUS if smoke else CORPUS_FULL):
        autotune.tune(kernel, cache=cache_path, measure=False, **shape)
        n += 1
    return n


def mine_corpus(cache_path: str):
    from repro.autotune.cache import TuningCache
    from repro.strategy import mine
    abstractions = mine.mine(TuningCache(cache_path))
    assert abstractions, "mining the warmed corpus produced no abstractions"
    mine.save_abstractions(mine.abstractions_path(cache_path), abstractions)
    return abstractions


def evals_to_best(kernel: str, shape: dict, abstractions) -> dict:
    """Candidate evaluations until the incumbent-best strategy is reached,
    with and without abstraction seeding.

    Incumbent best = the analytic-rank winner for the (new) shape; the
    "evaluation order" is the space's enumeration order, against
    ``seeded_order`` of the same list.  Seeding must never be worse, and is
    strictly better whenever the winner instantiates a mined abstraction
    (non-matching candidates ahead of it — the naive spec, at least — are
    deferred)."""
    from repro.autotune import measure as measure_mod
    from repro.autotune import space as space_mod
    from repro.strategy import mine
    cands = space_mod.enumerate_space(kernel, **shape)
    best = measure_mod.rank_by_cost(cands)[0][0]
    unseeded = [c.params for c in cands].index(best.params) + 1
    seeded_cands = mine.seeded_order(cands, abstractions)
    seeded = [c.params for c in seeded_cands].index(best.params) + 1
    doc = best.trace_doc()
    hit = bool(doc) and any(mine.matches(a, doc) for a in abstractions)
    assert seeded <= unseeded, (seeded, unseeded)
    if hit:
        assert seeded < unseeded, \
            f"winner matches an abstraction but seeding saved nothing " \
            f"({seeded} vs {unseeded})"
    return {"kernel": kernel, "shape": shape, "winner": dict(best.params),
            "evals_unseeded": unseeded, "evals_seeded": seeded,
            "winner_matches_abstraction": hit, "strategy_trace": doc}


def replay_identity(kernel: str, shape: dict, winner_params: dict) -> None:
    """A recorded derivation replays to the exact same term (fingerprint)."""
    from repro import strategy as st
    from repro.autotune import space as space_mod
    cand = space_mod.candidate_from_params(kernel, winner_params, **shape)
    doc = cand.trace_doc()
    assert doc is not None
    spec, _ = st.spec_builder(kernel, **shape)()
    res = st.replay(doc, spec)
    assert res.ok, res.reason
    expr, _ = cand.build()
    assert st.fingerprint(res.phrase) == st.fingerprint(expr), \
        "replayed derivation diverged from the winner's term"


def fused_demo(smoke: bool) -> dict:
    """The generic space on the fused RMSNorm->matmul term."""
    from repro import strategy as st
    rows, d, n = (32, 64, 32) if smoke else (64, 128, 64)
    expr, _ = st.fused_rmsnorm_matmul(rows, d, n)
    space = st.generic_space(expr, blocks=(8, 16, 32), tiles=(16, 32, 64))
    assert len(space) >= 2, "generic space degenerated to the identity"
    rewrites = sorted({str(p.get("rewrite")) for p, _, _ in space})
    return {"rows": rows, "d": d, "n": n, "n_candidates": len(space),
            "rewrites": rewrites}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus + shapes (CI)")
    ap.add_argument("--out", default="BENCH_strategy.json")
    ap.add_argument("--cache", default=None,
                    help="tuning-cache path (default: a fresh temp file)")
    args = ap.parse_args()

    cache_path = args.cache or os.path.join(
        tempfile.mkdtemp(prefix="strategy_bench_"), "tuning_cache.json")

    t0 = time.perf_counter()
    corpus_n = warm_corpus(cache_path, args.smoke)
    t_warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    abstractions = mine_corpus(cache_path)
    t_mine = time.perf_counter() - t0

    new_shapes = ([("dot", {"n": 4096}), ("asum", {"n": 4096})] if args.smoke
                  else [("dot", {"n": 16384}), ("asum", {"n": 16384}),
                        ("scal", {"n": 16384})])
    seeding = [evals_to_best(k, s, abstractions) for k, s in new_shapes]
    for row in seeding:
        replay_identity(row["kernel"], row["shape"], row["winner"])

    fused = fused_demo(args.smoke)

    doc = {
        "smoke": bool(args.smoke),
        "corpus": {"tunes": corpus_n, "cache": cache_path,
                   "warm_s": round(t_warm, 3)},
        "mining": {"n_abstractions": len(abstractions),
                   "mine_s": round(t_mine, 3),
                   "abstractions": [a.describe() for a in abstractions]},
        "seeding": seeding,
        "fused_rmsnorm_matmul": fused,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)

    print(f"strategy_bench: corpus={corpus_n} tunes ({t_warm:.2f}s), "
          f"mined {len(abstractions)} abstraction(s) ({t_mine:.2f}s)")
    print(f"  top: {abstractions[0].describe()}")
    for row in seeding:
        print(f"  {row['kernel']} {row['shape']}: evals to best "
              f"{row['evals_seeded']} seeded vs {row['evals_unseeded']} "
              f"unseeded (match={row['winner_matches_abstraction']})")
    print(f"  fused rmsnorm@matmul generic space: "
          f"{fused['n_candidates']} candidates, rewrites={fused['rewrites']}")
    print(f"  wrote {args.out}")


if __name__ == "__main__":
    main()
