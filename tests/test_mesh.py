"""repro.mesh: descriptors + MeshStrategy, mesh-keyed tuning cache
(regression for the hardcoded mesh="single" keys), collective-aware cost
ranking, mesh resolution through compiler.options, and — in forced-8-device
subprocesses — shardmap op dispatch oracle equality and ShardedEngine
token-identity / zero-recompile acceptance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import autotune, compiler
from repro import mesh as mesh_mod
from repro.autotune import cost
from repro.kernels import dpia_blas, ops


# ---------------------------------------------------------------------------
# descriptors + MeshStrategy (no devices needed)
# ---------------------------------------------------------------------------

class TestDescriptor:
    def test_none_is_single(self):
        assert mesh_mod.descriptor(None) == "single"
        assert mesh_mod.parse_descriptor("single") == {}
        assert mesh_mod.parse_descriptor("") == {}

    def test_mesh_object_round_trip(self):
        m = jax.make_mesh((1,), ("data",))
        d = mesh_mod.descriptor(m)
        assert d == "data=1"
        assert mesh_mod.parse_descriptor(d) == {"data": 1}

    def test_string_passthrough_and_order(self):
        d = "pod=2,data=16,model=16"
        assert mesh_mod.descriptor(d) == d
        assert mesh_mod.parse_descriptor(d) == {"pod": 2, "data": 16,
                                                "model": 16}

    def test_malformed_descriptor_raises(self):
        with pytest.raises(ValueError, match="malformed"):
            mesh_mod.parse_descriptor("data8")

    def test_non_mesh_raises(self):
        with pytest.raises(TypeError, match="Mesh"):
            mesh_mod.descriptor(42)


class TestMeshStrategy:
    def test_validate_ok(self):
        s = mesh_mod.MeshStrategy("data", op="reduce", extent=512)
        assert s.validate({"data": 8}) is s
        assert s.shards({"data": 8}) == 8
        assert s.describe() == "reduce[mesh(data)]"

    def test_validate_missing_axis(self):
        with pytest.raises(ValueError, match="not in mesh"):
            mesh_mod.MeshStrategy("model").validate({"data": 8})

    def test_validate_indivisible_extent(self):
        with pytest.raises(ValueError, match="not divisible"):
            mesh_mod.MeshStrategy("data", extent=100).validate({"data": 8})

    def test_bad_op_rejected(self):
        with pytest.raises(ValueError, match="map.*reduce"):
            mesh_mod.MeshStrategy("data", op="scan")

    def test_params_round_trip(self):
        s = mesh_mod.MeshStrategy("data", op="map", extent=64)
        assert s.params() == {"mesh_axis": "data"}
        back = mesh_mod.MeshStrategy.from_params(s.params(), extent=64)
        assert back.axis == "data"
        assert mesh_mod.MeshStrategy.from_params({"block": 128}) is None


class TestMeshSpace:
    def test_space_only_dividing_axes(self):
        cands = mesh_mod.mesh_space("dot", {"data": 8, "model": 3}, n=1024)
        assert cands, "1024 % 8 == 0 must yield candidates"
        assert all(c.params_dict["mesh_axis"] == "data" for c in cands)

    def test_space_empty_when_nothing_divides(self):
        assert mesh_mod.mesh_space("dot", {"data": 7}, n=64) == []
        assert mesh_mod.mesh_space("dot", {}, n=64) == []

    def test_default_params_and_rebuild(self):
        axes = {"data": 8}
        p = mesh_mod.default_mesh_params("matmul", axes, m=64, k=32, n=16)
        assert p["mesh_axis"] == "data"
        cand = mesh_mod.mesh_candidate_from_params("matmul", p, axes,
                                                   m=64, k=32, n=16)
        expr, argv = cand.build()
        assert len(argv) == 2

    def test_default_params_raises_unshardable(self):
        with pytest.raises(ValueError, match="no mesh axis"):
            mesh_mod.default_mesh_params("dot", {"data": 7}, n=64)

    def test_rebuild_requires_mesh_axis(self):
        with pytest.raises(ValueError, match="mesh_axis"):
            mesh_mod.mesh_candidate_from_params("dot", {"block": 128},
                                                {"data": 8}, n=1024)


# ---------------------------------------------------------------------------
# mesh-keyed tuning cache (satellite: no more hardcoded mesh="single")
# ---------------------------------------------------------------------------

class TestMeshKeyedTuning:
    def test_keys_differ_between_single_and_mesh(self, tuning_cache):
        r1 = autotune.tune("dot", n=1024, mesh="single", measure=False,
                           cache=tuning_cache)
        r2 = autotune.tune("dot", n=1024, mesh="data=8", backend="shardmap",
                           measure=False, cache=tuning_cache)
        assert r1.key != r2.key
        assert r1.key.endswith("|single")
        assert r2.key.endswith("|data=8")
        assert r2.params["mesh_axis"] == "data"
        # both entries live side by side in the persistent cache
        keys = tuning_cache.keys()
        assert r1.key in keys and r2.key in keys

    def test_mesh_params_round_trip_through_cache(self, tuning_cache):
        r1 = autotune.tune("rmsnorm", rows=64, d=32, mesh="data=8",
                           backend="shardmap", measure=False,
                           cache=tuning_cache)
        r2 = autotune.tune("rmsnorm", rows=64, d=32, mesh="data=8",
                           backend="shardmap", measure=False,
                           cache=tuning_cache)
        assert r2.source == "cache"
        assert r2.params == r1.params
        # and the descriptor itself survives in the cache record
        rec = tuning_cache.get(r1.key)
        assert rec["mesh"] == "data=8"

    def test_same_backend_different_mesh_not_shared(self, tuning_cache):
        """The regression: jnp-backend tunings on different meshes must not
        silently share one cache entry."""
        r1 = autotune.tune("dot", n=2048, measure=False, cache=tuning_cache)
        r2 = autotune.tune("dot", n=2048, mesh="data=8", measure=False,
                           cache=tuning_cache)
        assert r1.key != r2.key

    def test_descriptor_only_measure_degrades_to_analytic(self, tuning_cache):
        """measure=True with only a descriptor (no concrete mesh in scope)
        cannot compile shardmap candidates — the search must settle on a
        stable analytic record instead of failing or retrying forever."""
        r = autotune.tune("dot", n=1024, backend="shardmap", mesh="data=8",
                          measure=True, cache=tuning_cache)
        assert r.source == "analytic"
        r2 = autotune.tune("dot", n=1024, backend="shardmap", mesh="data=8",
                           measure=True, cache=tuning_cache)
        assert r2.source == "cache"  # the analytic record is the answer

    def test_ops_tuned_lookup_uses_context_descriptor(self, tuning_cache):
        """kernels.ops._tuned must key by the active mesh descriptor."""
        opts = compiler.CompileOptions(backend="dpia-jnp",
                                       tuning_cache=tuning_cache)
        ops.clear_caches()
        params = ops._tuned("dot", "jnp", opts, n=1024)
        assert params is not None
        assert any(k.endswith("|single") for k in tuning_cache.keys())


# ---------------------------------------------------------------------------
# collective-aware cost ranking
# ---------------------------------------------------------------------------

class TestCollectiveCost:
    def test_big_problem_prefers_mesh(self):
        e_mesh, _ = mesh_mod.mesh_dot(1 << 20, "data", 8)
        e_one, _ = dpia_blas.strategy_dot(1 << 20)
        assert (cost.predicted_seconds(e_mesh)
                < cost.predicted_seconds(e_one))

    def test_small_problem_refuses_mesh(self):
        e_mesh, _ = mesh_mod.mesh_dot(512, "data", 8)
        e_one, _ = dpia_blas.strategy_dot(512, block=512)
        assert (cost.predicted_seconds(e_mesh)
                > cost.predicted_seconds(e_one))

    def test_mesh_reduce_charges_collective(self):
        e_mesh, _ = mesh_mod.mesh_dot(1024, "data", 8)
        est = cost.estimate(e_mesh)
        assert est.collective_steps > 0 and est.ici_bytes > 0
        # a sharded map alone (softmax) needs no collective
        e_map, _ = mesh_mod.mesh_softmax(64, 32, axis="data", shards=8)
        assert cost.estimate(e_map).collective_steps == 0

    def test_collective_terms_survive_add_and_scale(self):
        a = cost.CostEstimate(ici_bytes=8.0, collective_steps=2.0)
        b = (a + a).scaled(2.0)
        assert b.ici_bytes == 32.0 and b.collective_steps == 8.0
        assert b.seconds() > cost.CostEstimate().seconds()


# ---------------------------------------------------------------------------
# mesh resolution through options / dispatch fallback (single device)
# ---------------------------------------------------------------------------

class TestMeshResolution:
    def test_options_carry_mesh_to_shardmap_compile(self, rng):
        """Program.compile('shardmap') resolves the mesh from the active
        options scope — on a 1-device mesh, right here in-process."""
        m1 = jax.make_mesh((1,), ("data",))
        expr, argv = mesh_mod.mesh_dot(64, "data", 1)
        x = jnp.asarray(rng.randn(64), "float32")
        y = jnp.asarray(rng.randn(64), "float32")
        with compiler.options(mesh=m1):
            fn = compiler.Program(expr, argv).compile("shardmap")
        np.testing.assert_allclose(np.asarray(fn(x, y)),
                                   float(jnp.dot(x, y)), rtol=1e-5)

    def test_shardmap_impl_is_valid_options_backend(self):
        opts = compiler.CompileOptions(backend="dpia-shardmap")
        assert opts.dpia_backend == "shardmap"
        assert opts.mesh_descriptor() == "single"

    def test_no_mesh_falls_back_with_warning(self, rng, tuning_cache):
        ops.clear_caches()
        x = jnp.asarray(rng.randn(256), "float32")
        y = jnp.asarray(rng.randn(256), "float32")
        with pytest.warns(RuntimeWarning, match="no mesh"):
            got = ops.dot(x, y, impl="dpia-shardmap",
                          options=compiler.CompileOptions(
                              backend="dpia-shardmap",
                              tuning_cache=tuning_cache))
        np.testing.assert_allclose(np.asarray(got), float(jnp.dot(x, y)),
                                   rtol=1e-4)

    def test_sharded_engine_requires_mesh(self):
        from repro.serve.engine import ShardedEngine
        with pytest.raises(ValueError, match="needs a mesh"):
            ShardedEngine(object(), {}, mesh=None)


# ---------------------------------------------------------------------------
# forced-8-device acceptance (subprocesses; see conftest.forced_devices)
# ---------------------------------------------------------------------------

SHARD_OPS = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compiler
from repro.kernels import ops

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(1024), "float32")
y = jnp.asarray(rng.randn(1024), "float32")
X = jnp.asarray(rng.randn(16, 64), "float32")
w = jnp.asarray(rng.randn(64), "float32")
A = jnp.asarray(rng.randn(32, 48), "float32")
B = jnp.asarray(rng.randn(48, 24), "float32")

with compiler.options(backend="dpia-shardmap", mesh=mesh):
    pairs = [
        ("dot", ops.dot(x, y), ops.dot(x, y, impl="xla")),
        ("asum", ops.asum(x), ops.asum(x, impl="xla")),
        ("scal", ops.scal(2.5, x), ops.scal(2.5, x, impl="xla")),
        ("matmul", ops.matmul(A, B), ops.matmul(A, B, impl="xla")),
        ("rmsnorm", ops.rmsnorm(X, w), ops.rmsnorm(X, w, impl="xla")),
        ("softmax", ops.softmax(X), ops.softmax(X, impl="xla")),
    ]
for name, got, want in pairs:
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3, err_msg=name)

# every one of the six went through a mesh-keyed shardmap executor
mesh_keys = [k for k in compiler.executor_cache().keys()
             if "|shardmap|data=8|" in k]
assert len(mesh_keys) == 6, mesh_keys

# the all-reduce in the lowered dot is dictated by the strategy: exactly one
from repro import mesh as mesh_mod
expr, argv = mesh_mod.mesh_dot(1024, "data", 8)
fn = compiler.Program(expr, argv).compile("shardmap", mesh=mesh)
import re
hlo = jax.jit(fn).lower(x, y).compile().as_text()
n_ar = len(re.findall(r"=\s*\S+\s+all-reduce(?:-start)?\(", hlo))
assert n_ar == 1, f"expected ONE all-reduce, found {n_ar}"

# mesh executors never reach the AOT store (they cannot be rebuilt without
# a mesh) and a store containing only single-device programs loads cleanly
import tempfile
d = tempfile.mkdtemp()
store = compiler.executor_cache()
n_written = store.save_aot(d)
fresh = compiler.ExecutorCache()
assert fresh.load_aot(d) == n_written
assert not any("|shardmap|" in k for k in fresh.keys()), fresh.keys()

# measured refinement DOES run for the mesh space when the concrete mesh
# matches the descriptor
from repro import autotune
r = autotune.tune("dot", n=1024, backend="shardmap", mesh=mesh,
                  measure=True, top_k=2, iters=2, force=True)
assert r.source == "measured", r.source
assert r.params.get("mesh_axis") == "data", r.params
print("MESH_OPS_OK")
"""


ENGINE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import ContinuousEngine, ShardedEngine, Request

cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, max_seq=64)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

def reqs():
    rng = np.random.RandomState(1)
    spec = [(3, 7, 0.0, 0), (9, 5, 0.8, 4), (5, 12, 0.0, 0),
            (12, 3, 1.2, 0), (4, 9, 0.0, 0)]
    return [Request(jnp.asarray(rng.randint(0, 128, (l,)), jnp.int32),
                    max_new_tokens=m, temperature=t, top_k=k)
            for l, m, t, k in spec]

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(7)
cont = ContinuousEngine(model, params, max_seq=64, slots=8, chunk=4)
want = cont.run(reqs(), key=key)
sh = ShardedEngine(model, params, max_seq=64, slots=8, chunk=4, mesh=mesh)
got = sh.run(reqs(), key=key)
assert got == want, (got, want)

# zero recompiles after warm-up: more traffic, same single chunk compile
n0 = sh.decode_cache_misses()
assert sh.run(reqs(), key=key) == want
assert sh.decode_cache_misses() == n0 == 1, (n0, sh.decode_cache_misses())

# the decode state really is sharded over the mesh
assert len(sh.tokens.sharding.device_set) == 8, sh.tokens.sharding
print("SHARDED_ENGINE_OK")
"""


def test_shardmap_ops_match_oracle_subprocess(forced_devices):
    """Acceptance: all six tuned ops dispatch through dpia-shardmap on a
    forced-8-device CPU mesh and match the single-device oracle, with
    mesh-keyed executors and the strategy-dictated single all-reduce."""
    r = forced_devices(SHARD_OPS)
    assert "MESH_OPS_OK" in r.stdout, r.stdout + r.stderr


def test_sharded_engine_token_identical_subprocess(forced_devices):
    """Acceptance: ShardedEngine decode is token-identical to
    ContinuousEngine on a 1-axis mesh and reports zero recompiles after
    warm-up."""
    r = forced_devices(ENGINE)
    assert "SHARDED_ENGINE_OK" in r.stdout, r.stdout + r.stderr
