"""Stage III (jnp backend): purely imperative DPIA -> executable JAX.

This is the analogue of the paper's Fig. 6 translation to parallel pseudo-C,
re-targeted at JAX: commands become store transformers (the store is a dict of
buffer pytrees), acceptors resolve to (root, index-path) l-values exactly as
in Fig. 6b, and expressions are evaluated by the functional interpreter
(Fig. 6c).  ``for``/``parfor`` become ``lax.fori_loop`` (the reference
execution order; the Pallas backend gives parfor its parallel reading).

The index-path discipline mirrors the paper: acceptor combinators transform an
accumulated path of indices / ``fst|snd`` projections / dynamic slices until
an identifier is reached, at which point the path is applied to the buffer.
Because buffers are struct-of-arrays pytrees, ``splitAcc``/``joinAcc``/
``asScalarAcc`` are reshape re-views rather than flat-index arithmetic.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from . import phrases as P
from . import stage2
from .interp import interp
from .types import AccT, Arr, ExpT, Idx, Pair, VarT, Vec, zero_value

Store = Dict[str, object]

FST, SND = "fst", "snd"


# ---------------------------------------------------------------------------
# l-value writes: set_path + acceptor resolution (Fig. 6b)
# ---------------------------------------------------------------------------

def _cast_like(buf, value):
    return jax.tree_util.tree_map(
        lambda b, v: jnp.asarray(v, b.dtype).reshape(b.shape), buf, value)


def set_path(buf, path: Sequence, value):  # noqa: C901
    """Functionally update ``buf`` at ``path`` with ``value``.

    Path components: integer (possibly traced) indices, ('ds', start, size)
    dynamic slices along the leading axis, and 'fst'/'snd' pair projections.
    """
    if not path:
        return _cast_like(buf, value)
    if isinstance(buf, tuple):
        for k, comp in enumerate(path):
            if comp in (FST, SND):
                b = 0 if comp == FST else 1
                rest = list(path[:k]) + list(path[k + 1:])
                parts = list(buf)
                parts[b] = set_path(buf[b], rest, value)
                return tuple(parts)
        # whole-pair write: value must be a matching tuple
        return tuple(set_path(bi, path, vi) for bi, vi in zip(buf, value))
    comp, rest = path[0], path[1:]
    if isinstance(comp, tuple) and comp[0] == "ds":
        _, start, size = comp
        sub = jax.lax.dynamic_slice_in_dim(buf, start, size, axis=0)
        sub = set_path(sub, rest, value)
        return jax.lax.dynamic_update_slice_in_dim(buf, sub, start, axis=0)
    if comp in (FST, SND):
        raise TypeError("pair projection applied to a non-pair buffer")
    # integer index
    if not rest:
        return buf.at[comp].set(jnp.asarray(value, buf.dtype))
    sub = set_path(buf[comp], rest, value)
    return buf.at[comp].set(sub)


def _reshape_leading(value, old: Tuple[int, ...], new: Tuple[int, ...]):
    """Re-view the leading axes of every leaf of ``value``."""
    def fix(l):
        return l.reshape(tuple(new) + l.shape[len(old):])
    return jax.tree_util.tree_map(fix, value)


def fold_acc(a: P.Phrase, idxs: List, value, eval_i, leaf):  # noqa: C901
    """Resolve an acceptor phrase down to its root, threading the index path
    (Fig. 6b discipline).  ``eval_i`` evaluates index expressions; ``leaf`` is
    called as ``leaf(root_phrase, idxs, value)`` at a Var / AccPart root.
    Shared by the jnp and Pallas backends."""
    if isinstance(a, P.Var):
        assert isinstance(a.t, AccT), f"write through non-acceptor {a.t}"
        return leaf(a, idxs, value)
    if isinstance(a, P.AccPart):
        v = a.v
        if isinstance(v, P.VView):
            return fold_acc(v.acc, idxs, value, eval_i, leaf)
        assert isinstance(v, P.Var) and isinstance(v.t, VarT)
        return leaf(a, idxs, value)
    if isinstance(a, P.IdxAcc):
        i = eval_i(a.i)
        return fold_acc(a.a, [i] + idxs, value, eval_i, leaf)
    if isinstance(a, P.SplitAcc):
        # self: acc[(m*n).d]; inner: acc[m.n.d]
        n = a.n
        if idxs:
            i, rest = idxs[0], idxs[1:]
            if isinstance(i, tuple) and i[0] == "ds":
                _, s0, sz = i
                if isinstance(s0, int) and isinstance(sz, int) \
                        and s0 % n == 0 and sz % n == 0 and not rest:
                    return fold_acc(
                        a.a, [("ds", s0 // n, sz // n)],
                        _reshape_leading(value, (sz,), (sz // n, n)),
                        eval_i, leaf)
                raise TypeError(
                    "splitAcc: unaligned slice writes across chunks")
            return fold_acc(a.a, [i // n, i % n] + rest, value, eval_i, leaf)
        inner_d = P.acc_data(a.a)
        assert isinstance(inner_d, Arr)
        m = inner_d.n
        return fold_acc(a.a, [], _reshape_leading(value, (m * n,), (m, n)),
                        eval_i, leaf)
    if isinstance(a, P.JoinAcc):
        # self: acc[k.m.d]; inner: acc[(k*m).d]
        m = a.m
        if len(idxs) >= 2:
            i, j, rest = idxs[0], idxs[1], idxs[2:]
            if isinstance(i, tuple) or isinstance(j, tuple):
                raise TypeError("joinAcc: mixed slice/index writes unsupported")
            return fold_acc(a.a, [i * m + j] + rest, value, eval_i, leaf)
        if len(idxs) == 1:
            i = idxs[0]
            if isinstance(i, tuple) and i[0] == "ds":
                _, s0, sz = i
                return fold_acc(
                    a.a, [("ds", s0 * m, sz * m)],
                    _reshape_leading(value, (sz, m), (sz * m,)),
                    eval_i, leaf)
            return fold_acc(a.a, [("ds", i * m, m)], value, eval_i, leaf)
        d = P.acc_data(a)
        assert isinstance(d, Arr)
        k = d.n
        return fold_acc(a.a, [], _reshape_leading(value, (k, m), (k * m,)),
                        eval_i, leaf)
    if isinstance(a, P.TransposeAcc):
        # self: acc[n.m.d]; inner: acc[m.n.d] — swap leading index pair.
        if len(idxs) >= 2:
            i, j, rest = idxs[0], idxs[1], idxs[2:]
            return fold_acc(a.a, [j, i] + rest, value, eval_i, leaf)
        if len(idxs) == 1:
            raise TypeError("transposeAcc: single-index (column) writes "
                            "unsupported; write whole or per-element")
        value_t = jax.tree_util.tree_map(lambda l: jnp.swapaxes(l, 0, 1), value)
        return fold_acc(a.a, [], value_t, eval_i, leaf)
    if isinstance(a, P.PairAcc1):
        return fold_acc(a.a, [FST] + idxs, value, eval_i, leaf)
    if isinstance(a, P.PairAcc2):
        return fold_acc(a.a, [SND] + idxs, value, eval_i, leaf)
    if isinstance(a, P.ZipAcc1):
        return fold_acc(a.a, [FST] + idxs, value, eval_i, leaf)
    if isinstance(a, P.ZipAcc2):
        return fold_acc(a.a, [SND] + idxs, value, eval_i, leaf)
    if isinstance(a, P.AsScalarAcc):
        # self: acc[(m*w).num]; inner: acc[m.num<w>]
        inner_d = P.acc_data(a.a)
        assert isinstance(inner_d, Arr) and isinstance(inner_d.elem, Vec)
        m, w = inner_d.n, inner_d.elem.n
        if idxs:
            i, rest = idxs[0], idxs[1:]
            if isinstance(i, tuple) and i[0] == "ds":
                _, s0, sz = i
                if isinstance(s0, int) and isinstance(sz, int) \
                        and s0 % w == 0 and sz % w == 0 and not rest:
                    return fold_acc(
                        a.a, [("ds", s0 // w, sz // w)],
                        _reshape_leading(value, (sz,), (sz // w, w)),
                        eval_i, leaf)
                raise TypeError("asScalarAcc: unaligned slice write")
            return fold_acc(a.a, [i // w, i % w] + rest, value, eval_i, leaf)
        return fold_acc(a.a, [], _reshape_leading(value, (m * w,), (m, w)),
                        eval_i, leaf)
    if isinstance(a, P.AsVectorAcc):
        # self: acc[m.num<w>]; inner: acc[(m*w).num]
        w = a.w
        if len(idxs) >= 2:
            i, j, rest = idxs[0], idxs[1], idxs[2:]
            if isinstance(i, tuple) or isinstance(j, tuple):
                raise TypeError("asVectorAcc: mixed slice/index unsupported")
            return fold_acc(a.a, [i * w + j] + rest, value, eval_i, leaf)
        if len(idxs) == 1:
            i = idxs[0]
            if isinstance(i, tuple) and i[0] == "ds":
                _, s0, sz = i
                return fold_acc(
                    a.a, [("ds", s0 * w, sz * w)],
                    _reshape_leading(value, (sz, w), (sz * w,)),
                    eval_i, leaf)
            return fold_acc(a.a, [("ds", i * w, w)], value, eval_i, leaf)
        d = P.acc_data(a)
        assert isinstance(d, Arr)
        m = d.n
        return fold_acc(a.a, [], _reshape_leading(value, (m, w), (m * w,)),
                        eval_i, leaf)
    raise TypeError(f"fold_acc: unhandled acceptor {type(a).__name__}")


def write_acc(a: P.Phrase, idxs: List, value, env, store: Store) -> Store:
    """Resolve an acceptor phrase and write ``value`` into the store."""
    def leaf(root, path, val):
        name = root.name if isinstance(root, P.Var) else root.v.name
        new_store = dict(store)
        new_store[name] = set_path(new_store[name], path, val)
        return new_store

    return fold_acc(a, idxs, value,
                    lambda i: interp(i, env, store), leaf)


def acc_root(a: P.Phrase) -> str:
    """Root identifier of an acceptor chain."""
    if isinstance(a, P.Var):
        return a.name
    if isinstance(a, P.AccPart):
        if isinstance(a.v, P.VView):
            return acc_root(a.v.acc)
        assert isinstance(a.v, P.Var)
        return a.v.name
    inner = getattr(a, "a", None)
    if isinstance(inner, P.Phrase):
        return acc_root(inner)
    raise TypeError(f"acc_root: {type(a).__name__}")


# ---------------------------------------------------------------------------
# Static analysis: which store buffers does a command write?
# ---------------------------------------------------------------------------

def written_roots(p: P.Phrase, bound: Set[str] = frozenset()) -> Set[str]:  # noqa: C901
    out: Set[str] = set()

    def go(q: P.Phrase, bnd: Set[str]) -> None:
        if isinstance(q, P.Assign):
            r = acc_root(q.a)
            if r not in bnd:
                out.add(r)
            return
        if isinstance(q, P.SeqC):
            go(q.c1, bnd)
            go(q.c2, bnd)
            return
        if isinstance(q, P.Skip):
            return
        if isinstance(q, P.New):
            v = P.Var(P.fresh("v"), VarT(q.d))
            go(q.f(v), bnd | {v.name})
            return
        if isinstance(q, P.For):
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            go(q.f(i), bnd)
            return
        if isinstance(q, P.ParFor):
            r = acc_root(q.a)
            if r not in bnd:
                out.add(r)
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            o = P.Var(P.fresh("o"), AccT(q.d))
            go(q.f(i, o), bnd | {o.name})
            return
        if isinstance(q, (P.MapI, P.ReduceI)):
            go(stage2.expand(q), bnd)
            return
        raise TypeError(f"written_roots: not a command {type(q).__name__}")

    go(p, set(bound))
    return out


# ---------------------------------------------------------------------------
# Command execution (store-passing)
# ---------------------------------------------------------------------------

_UNROLL_DEFAULT = 8


def exec_comm(p: P.Phrase, env: Dict, store: Store) -> Store:  # noqa: C901
    if isinstance(p, P.Skip):
        return store
    if isinstance(p, P.SeqC):
        return exec_comm(p.c2, env, exec_comm(p.c1, env, store))
    if isinstance(p, P.Assign):
        value = interp(p.e, env, store)
        return write_acc(p.a, [], value, env, store)
    if isinstance(p, P.New):
        v = P.Var(P.fresh("buf"), VarT(p.d))
        store2 = dict(store)
        store2[v.name] = zero_value(p.d)
        store3 = exec_comm(p.f(v), env, store2)
        store3 = dict(store3)
        del store3[v.name]
        return store3
    if isinstance(p, P.For):
        return _run_loop(p.n, lambda i: p.f(i), env, store,
                         unroll=p.unroll or p.n <= _UNROLL_DEFAULT)
    if isinstance(p, P.ParFor):
        # Reference (sequential) execution order; parallel semantics is the
        # Pallas/shard_map backend's job.  Race freedom was checked upstream,
        # so orders agree.
        return _run_loop(p.n, lambda i: p.f(i, P.IdxAcc(p.a, i)), env, store,
                         unroll=p.n <= _UNROLL_DEFAULT)
    if isinstance(p, (P.MapI, P.ReduceI)):
        return exec_comm(stage2.expand(p), env, store)
    raise TypeError(f"exec_comm: not a command: {type(p).__name__}")


def _run_loop(n: int, mk_body, env: Dict, store: Store, unroll: bool) -> Store:
    i_probe = P.Var(P.fresh("i"), ExpT(Idx(n)))
    body_phrase = mk_body(i_probe)
    roots = sorted(r for r in written_roots(body_phrase) if r in store)

    if unroll:
        for k in range(n):
            env2 = {**env, i_probe.name: jnp.asarray(k, "int32")}
            store = exec_comm(body_phrase, env2, store)
        return store

    carry0 = tuple(store[r] for r in roots)

    def body(k, carry):
        st = dict(store)
        st.update(dict(zip(roots, carry)))
        env2 = {**env, i_probe.name: k}
        st2 = exec_comm(body_phrase, env2, st)
        return tuple(st2[r] for r in roots)

    final = jax.lax.fori_loop(0, n, body, carry0)
    out = dict(store)
    out.update(dict(zip(roots, final)))
    return out


# ---------------------------------------------------------------------------
# Whole-pipeline driver
# ---------------------------------------------------------------------------

def compile_expr(expr: P.Phrase, arg_vars, *, check: bool = True,
                 lowered=None):
    """Functional expression -> python callable via Stages I-III (jnp).

    Returns ``fn(*arrays) -> value`` suitable for jax.jit.  ``lowered``
    optionally supplies an already-translated ``(command, out_var)`` pair
    (the staged repro.compiler path) so Stage I/II is not redone here.
    """
    from . import check as chk
    from . import stage1

    if lowered is not None:
        cmd, out = lowered
        d = out.t.d
    else:
        d = P.exp_data(expr)
        out = P.Var("out#", AccT(d))
        cmd = stage2.expand(stage1.translate(expr, out))
    if check:
        P.type_of(cmd)
        chk.check_race_free(cmd)
    names = [v.name for v in arg_vars]
    out_name = out.name

    def fn(*args):
        env = dict(zip(names, args))
        store: Store = {out_name: zero_value(d)}
        store = exec_comm(cmd, env, store)
        return store[out_name]

    return fn


# self-register as a Stage III target (see repro.compiler.backends)
from repro.compiler.backends import Backend as _Backend  # noqa: E402
from repro.compiler.backends import register_backend as _register  # noqa: E402

_register(_Backend(
    name="jnp", compile=compile_expr, accepts=("check", "lowered"),
    description="imperative DPIA -> executable JAX (lax.fori_loop reference "
                "order)"),
    aliases=("dpia-jnp",), overwrite=True)
