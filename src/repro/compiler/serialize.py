"""JSON round-tripping for DPIA phrases — the AOT persistence format.

``Program.export()`` persists *lowered* imperative commands (the Stage I->II
output) so a later process can jump straight to Stage III without redoing
translation, expansion, or the SCIR check.  The on-disk form is plain JSON:
human-inspectable, diff-able, and versioned.

HOAS binders (the callable fields of ``Map``/``Reduce``/``New``/``For``/
``ParFor``/``MapI``/``ReduceI``) are handled the same way the pretty printer
and the checker handle them: at *save* time each binder is instantiated with
fresh, typed ``Var``s and its body is serialised with those names free; at
*load* time the binder becomes a substitution closure — applying it
deserialises the body with the actual arguments bound in the environment, so
beta reduction stays ordinary function application, exactly as in the live
AST.

Serialisation is total over the phrase grammar of ``phrases.py``; an unknown
node (e.g. from a future grammar extension) raises ``SerializeError`` rather
than silently writing a partial document.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

from repro.core.dpia import phrases as P
from repro.core.dpia.types import (
    AccT, Arr, CommT, DataType, ExpT, Idx, Num, Pair, PhraseType, Vec, VarT,
)

__all__ = [
    "SerializeError", "FORMAT_VERSION",
    "data_to_doc", "data_from_doc", "ptype_to_doc", "ptype_from_doc",
    "phrase_to_doc", "phrase_from_doc", "var_to_doc", "var_from_doc",
]

FORMAT_VERSION = 1


class SerializeError(ValueError):
    pass


# ---------------------------------------------------------------------------
# data types
# ---------------------------------------------------------------------------

def data_to_doc(d: DataType) -> dict:
    if isinstance(d, Num):
        return {"t": "num", "dtype": d.dtype}
    if isinstance(d, Idx):
        return {"t": "idx", "n": d.n}
    if isinstance(d, Arr):
        return {"t": "arr", "n": d.n, "elem": data_to_doc(d.elem)}
    if isinstance(d, Pair):
        return {"t": "pair", "fst": data_to_doc(d.fst),
                "snd": data_to_doc(d.snd)}
    if isinstance(d, Vec):
        return {"t": "vec", "n": d.n, "dtype": d.dtype}
    raise SerializeError(f"not a serialisable data type: {d!r}")


def data_from_doc(doc: dict) -> DataType:
    t = doc["t"]
    if t == "num":
        return Num(doc["dtype"])
    if t == "idx":
        return Idx(int(doc["n"]))
    if t == "arr":
        return Arr(int(doc["n"]), data_from_doc(doc["elem"]))
    if t == "pair":
        return Pair(data_from_doc(doc["fst"]), data_from_doc(doc["snd"]))
    if t == "vec":
        return Vec(int(doc["n"]), doc["dtype"])
    raise SerializeError(f"unknown data-type tag {t!r}")


def ptype_to_doc(t: PhraseType) -> dict:
    if isinstance(t, ExpT):
        return {"p": "exp", "d": data_to_doc(t.d)}
    if isinstance(t, AccT):
        return {"p": "acc", "d": data_to_doc(t.d)}
    if isinstance(t, VarT):
        return {"p": "var", "d": data_to_doc(t.d)}
    if isinstance(t, CommT):
        return {"p": "comm"}
    raise SerializeError(f"not a serialisable phrase type: {t!r}")


def ptype_from_doc(doc: dict) -> PhraseType:
    p = doc["p"]
    if p == "exp":
        return ExpT(data_from_doc(doc["d"]))
    if p == "acc":
        return AccT(data_from_doc(doc["d"]))
    if p == "var":
        return VarT(data_from_doc(doc["d"]))
    if p == "comm":
        return CommT()
    raise SerializeError(f"unknown phrase-type tag {p!r}")


def var_to_doc(v: P.Var) -> dict:
    return {"name": v.name, "t": ptype_to_doc(v.t)}


def var_from_doc(doc: dict) -> P.Var:
    return P.Var(doc["name"], ptype_from_doc(doc["t"]))


# ---------------------------------------------------------------------------
# strategy levels
# ---------------------------------------------------------------------------

def _par_to_doc(level: P.Par) -> dict:
    return {"kind": level.kind, "axis": level.axis}


def _par_from_doc(doc: dict) -> P.Par:
    return P.Par(doc["kind"], doc["axis"])


# ---------------------------------------------------------------------------
# phrases
# ---------------------------------------------------------------------------

def _elem_of(e: P.Phrase) -> DataType:
    d = P.exp_data(e)
    if not isinstance(d, Arr):
        raise SerializeError(f"binder input is not an array: {d!r}")
    return d.elem


def _fn_to_doc(f: Callable, binder_types: Sequence[PhraseType]) -> dict:
    vs = [P.Var(P.fresh("s"), t) for t in binder_types]
    return {"params": [var_to_doc(v) for v in vs],
            "body": phrase_to_doc(f(*vs))}


def _fn_from_doc(doc: dict, env: Dict[str, P.Phrase]) -> Callable:
    names = [p["name"] for p in doc["params"]]
    body = doc["body"]
    outer = dict(env)

    def f(*args: P.Phrase) -> P.Phrase:
        inner = dict(outer)
        inner.update(zip(names, args))
        return phrase_from_doc(body, inner)

    return f


def phrase_to_doc(p: P.Phrase) -> dict:  # noqa: C901 - structural dispatch
    if isinstance(p, P.Var):
        return {"n": "Var", "name": p.name, "t": ptype_to_doc(p.t)}
    if isinstance(p, P.Lit):
        return {"n": "Lit", "value": p.value, "d": data_to_doc(p.d)}
    if isinstance(p, P.UnOp):
        return {"n": "UnOp", "op": p.op, "e": phrase_to_doc(p.e)}
    if isinstance(p, P.BinOp):
        return {"n": "BinOp", "op": p.op, "a": phrase_to_doc(p.a),
                "b": phrase_to_doc(p.b)}
    if isinstance(p, P.Map):
        return {"n": "Map", "level": _par_to_doc(p.level), "space": p.space,
                "e": phrase_to_doc(p.e),
                "f": _fn_to_doc(p.f, [ExpT(_elem_of(p.e))])}
    if isinstance(p, P.Reduce):
        return {"n": "Reduce", "level": _par_to_doc(p.level),
                "init": phrase_to_doc(p.init), "e": phrase_to_doc(p.e),
                "f": _fn_to_doc(p.f, [ExpT(_elem_of(p.e)),
                                      ExpT(P.exp_data(p.init))])}
    if isinstance(p, P.Zip):
        return {"n": "Zip", "a": phrase_to_doc(p.a), "b": phrase_to_doc(p.b)}
    if isinstance(p, P.Split):
        return {"n": "Split", "size": p.n, "e": phrase_to_doc(p.e)}
    if isinstance(p, P.Join):
        return {"n": "Join", "e": phrase_to_doc(p.e)}
    if isinstance(p, P.PairE):
        return {"n": "PairE", "a": phrase_to_doc(p.a), "b": phrase_to_doc(p.b)}
    if isinstance(p, P.Fst):
        return {"n": "Fst", "e": phrase_to_doc(p.e)}
    if isinstance(p, P.Snd):
        return {"n": "Snd", "e": phrase_to_doc(p.e)}
    if isinstance(p, P.IdxE):
        return {"n": "IdxE", "e": phrase_to_doc(p.e), "i": phrase_to_doc(p.i)}
    if isinstance(p, P.AsVector):
        return {"n": "AsVector", "w": p.w, "e": phrase_to_doc(p.e)}
    if isinstance(p, P.AsScalar):
        return {"n": "AsScalar", "e": phrase_to_doc(p.e)}
    if isinstance(p, P.Transpose):
        return {"n": "Transpose", "e": phrase_to_doc(p.e)}
    if isinstance(p, P.DotBlock):
        return {"n": "DotBlock", "a": phrase_to_doc(p.a),
                "b": phrase_to_doc(p.b), "acc_dtype": p.acc_dtype}
    if isinstance(p, P.FullReduce):
        return {"n": "FullReduce", "op": p.op, "e": phrase_to_doc(p.e)}
    if isinstance(p, P.ToMem):
        return {"n": "ToMem", "space": p.space, "e": phrase_to_doc(p.e)}
    if isinstance(p, P.Skip):
        return {"n": "Skip"}
    if isinstance(p, P.SeqC):
        return {"n": "SeqC", "c1": phrase_to_doc(p.c1),
                "c2": phrase_to_doc(p.c2)}
    if isinstance(p, P.Assign):
        return {"n": "Assign", "a": phrase_to_doc(p.a),
                "e": phrase_to_doc(p.e)}
    if isinstance(p, P.New):
        return {"n": "New", "d": data_to_doc(p.d), "space": p.space,
                "f": _fn_to_doc(p.f, [VarT(p.d)])}
    if isinstance(p, P.For):
        return {"n": "For", "size": p.n, "unroll": p.unroll,
                "f": _fn_to_doc(p.f, [ExpT(Idx(p.n))])}
    if isinstance(p, P.ParFor):
        return {"n": "ParFor", "size": p.n, "d": data_to_doc(p.d),
                "level": _par_to_doc(p.level), "a": phrase_to_doc(p.a),
                "f": _fn_to_doc(p.f, [ExpT(Idx(p.n)), AccT(p.d)])}
    if isinstance(p, P.AccPart):
        return {"n": "AccPart", "v": phrase_to_doc(p.v)}
    if isinstance(p, P.ExpPart):
        return {"n": "ExpPart", "v": phrase_to_doc(p.v)}
    if isinstance(p, P.VView):
        return {"n": "VView", "acc": phrase_to_doc(p.acc),
                "exp": phrase_to_doc(p.exp)}
    if isinstance(p, P.IdxAcc):
        return {"n": "IdxAcc", "a": phrase_to_doc(p.a),
                "i": phrase_to_doc(p.i)}
    if isinstance(p, P.SplitAcc):
        return {"n": "SplitAcc", "size": p.n, "a": phrase_to_doc(p.a)}
    if isinstance(p, P.JoinAcc):
        return {"n": "JoinAcc", "m": p.m, "a": phrase_to_doc(p.a)}
    if isinstance(p, P.PairAcc1):
        return {"n": "PairAcc1", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.PairAcc2):
        return {"n": "PairAcc2", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.ZipAcc1):
        return {"n": "ZipAcc1", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.ZipAcc2):
        return {"n": "ZipAcc2", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.TransposeAcc):
        return {"n": "TransposeAcc", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.AsScalarAcc):
        return {"n": "AsScalarAcc", "a": phrase_to_doc(p.a)}
    if isinstance(p, P.AsVectorAcc):
        return {"n": "AsVectorAcc", "w": p.w, "a": phrase_to_doc(p.a)}
    if isinstance(p, P.MapI):
        return {"n": "MapI", "size": p.n, "d1": data_to_doc(p.d1),
                "d2": data_to_doc(p.d2), "level": _par_to_doc(p.level),
                "e": phrase_to_doc(p.e), "a": phrase_to_doc(p.a),
                "f": _fn_to_doc(p.f, [ExpT(p.d1), AccT(p.d2)])}
    if isinstance(p, P.ReduceI):
        return {"n": "ReduceI", "size": p.n, "d1": data_to_doc(p.d1),
                "d2": data_to_doc(p.d2), "init": phrase_to_doc(p.init),
                "e": phrase_to_doc(p.e),
                "f": _fn_to_doc(p.f, [ExpT(p.d1), ExpT(p.d2), AccT(p.d2)]),
                "k": _fn_to_doc(p.k, [ExpT(p.d2)])}
    raise SerializeError(f"not a serialisable phrase: {type(p).__name__}")


def phrase_from_doc(doc: dict, env: Dict[str, P.Phrase] = None  # noqa: C901
                    ) -> P.Phrase:
    env = env if env is not None else {}
    n = doc["n"]
    if n == "Var":
        bound = env.get(doc["name"])
        if bound is not None:
            return bound
        return P.Var(doc["name"], ptype_from_doc(doc["t"]))
    if n == "Lit":
        return P.Lit(doc["value"], data_from_doc(doc["d"]))
    if n == "UnOp":
        return P.UnOp(doc["op"], phrase_from_doc(doc["e"], env))
    if n == "BinOp":
        return P.BinOp(doc["op"], phrase_from_doc(doc["a"], env),
                       phrase_from_doc(doc["b"], env))
    if n == "Map":
        return P.Map(_fn_from_doc(doc["f"], env),
                     phrase_from_doc(doc["e"], env),
                     level=_par_from_doc(doc["level"]), space=doc["space"])
    if n == "Reduce":
        return P.Reduce(_fn_from_doc(doc["f"], env),
                        phrase_from_doc(doc["init"], env),
                        phrase_from_doc(doc["e"], env),
                        level=_par_from_doc(doc["level"]))
    if n == "Zip":
        return P.Zip(phrase_from_doc(doc["a"], env),
                     phrase_from_doc(doc["b"], env))
    if n == "Split":
        return P.Split(int(doc["size"]), phrase_from_doc(doc["e"], env))
    if n == "Join":
        return P.Join(phrase_from_doc(doc["e"], env))
    if n == "PairE":
        return P.PairE(phrase_from_doc(doc["a"], env),
                       phrase_from_doc(doc["b"], env))
    if n == "Fst":
        return P.Fst(phrase_from_doc(doc["e"], env))
    if n == "Snd":
        return P.Snd(phrase_from_doc(doc["e"], env))
    if n == "IdxE":
        return P.IdxE(phrase_from_doc(doc["e"], env),
                      phrase_from_doc(doc["i"], env))
    if n == "AsVector":
        return P.AsVector(int(doc["w"]), phrase_from_doc(doc["e"], env))
    if n == "AsScalar":
        return P.AsScalar(phrase_from_doc(doc["e"], env))
    if n == "Transpose":
        return P.Transpose(phrase_from_doc(doc["e"], env))
    if n == "DotBlock":
        return P.DotBlock(phrase_from_doc(doc["a"], env),
                          phrase_from_doc(doc["b"], env),
                          acc_dtype=doc["acc_dtype"])
    if n == "FullReduce":
        return P.FullReduce(doc["op"], phrase_from_doc(doc["e"], env))
    if n == "ToMem":
        return P.ToMem(doc["space"], phrase_from_doc(doc["e"], env))
    if n == "Skip":
        return P.Skip()
    if n == "SeqC":
        return P.SeqC(phrase_from_doc(doc["c1"], env),
                      phrase_from_doc(doc["c2"], env))
    if n == "Assign":
        return P.Assign(phrase_from_doc(doc["a"], env),
                        phrase_from_doc(doc["e"], env))
    if n == "New":
        return P.New(data_from_doc(doc["d"]), _fn_from_doc(doc["f"], env),
                     space=doc["space"])
    if n == "For":
        return P.For(int(doc["size"]), _fn_from_doc(doc["f"], env),
                     unroll=bool(doc["unroll"]))
    if n == "ParFor":
        return P.ParFor(int(doc["size"]), data_from_doc(doc["d"]),
                        phrase_from_doc(doc["a"], env),
                        _fn_from_doc(doc["f"], env),
                        level=_par_from_doc(doc["level"]))
    if n == "AccPart":
        return P.AccPart(phrase_from_doc(doc["v"], env))
    if n == "ExpPart":
        return P.ExpPart(phrase_from_doc(doc["v"], env))
    if n == "VView":
        return P.VView(phrase_from_doc(doc["acc"], env),
                       phrase_from_doc(doc["exp"], env))
    if n == "IdxAcc":
        return P.IdxAcc(phrase_from_doc(doc["a"], env),
                        phrase_from_doc(doc["i"], env))
    if n == "SplitAcc":
        return P.SplitAcc(int(doc["size"]), phrase_from_doc(doc["a"], env))
    if n == "JoinAcc":
        return P.JoinAcc(int(doc["m"]), phrase_from_doc(doc["a"], env))
    if n == "PairAcc1":
        return P.PairAcc1(phrase_from_doc(doc["a"], env))
    if n == "PairAcc2":
        return P.PairAcc2(phrase_from_doc(doc["a"], env))
    if n == "ZipAcc1":
        return P.ZipAcc1(phrase_from_doc(doc["a"], env))
    if n == "ZipAcc2":
        return P.ZipAcc2(phrase_from_doc(doc["a"], env))
    if n == "TransposeAcc":
        return P.TransposeAcc(phrase_from_doc(doc["a"], env))
    if n == "AsScalarAcc":
        return P.AsScalarAcc(phrase_from_doc(doc["a"], env))
    if n == "AsVectorAcc":
        return P.AsVectorAcc(int(doc["w"]), phrase_from_doc(doc["a"], env))
    if n == "MapI":
        return P.MapI(int(doc["size"]), data_from_doc(doc["d1"]),
                      data_from_doc(doc["d2"]), _fn_from_doc(doc["f"], env),
                      phrase_from_doc(doc["e"], env),
                      phrase_from_doc(doc["a"], env),
                      level=_par_from_doc(doc["level"]))
    if n == "ReduceI":
        return P.ReduceI(int(doc["size"]), data_from_doc(doc["d1"]),
                         data_from_doc(doc["d2"]),
                         _fn_from_doc(doc["f"], env),
                         phrase_from_doc(doc["init"], env),
                         phrase_from_doc(doc["e"], env),
                         _fn_from_doc(doc["k"], env))
    raise SerializeError(f"unknown phrase tag {n!r}")
