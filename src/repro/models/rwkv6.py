"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Faithful structure per the paper (arXiv:2404.05892): token-shift lerps with a
data-dependent LoRA for the decay w_t = exp(-exp(w0 + lora(x))), matrix-valued
per-head WKV state S in R^{hd x hd}:

    S_t = diag(w_t) S_{t-1} + k_t^T (x' v_t)
    o_t = r_t (S_{t-1} + u k_t^T v_t)

plus squared-ReLU channel-mix.  Recurrence = lax.scan over time; constant
state per layer: (shift (b,d) x2, wkv (b, nh, hd, hd)) — hence long_500k.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense

HD = 64
LORA = 64


class Rwkv6Params(NamedTuple):
    # time mix
    mix_r: jax.Array      # (d,)
    mix_k: jax.Array
    mix_v: jax.Array
    mix_w: jax.Array
    w_r: jax.Array        # (d, d)
    w_k: jax.Array
    w_v: jax.Array
    w_o: jax.Array
    w0: jax.Array         # (d,) decay base
    w_lora_a: jax.Array   # (d, LORA)
    w_lora_b: jax.Array   # (LORA, d)
    u: jax.Array          # (nh, hd) bonus
    # channel mix
    cmix_r: jax.Array     # (d,)
    cmix_k: jax.Array
    cw_r: jax.Array       # (d, d)
    cw_k: jax.Array       # (d, f)
    cw_v: jax.Array       # (f, d)


class Rwkv6State(NamedTuple):
    tshift: jax.Array     # (b, d) last token (time-mix)
    cshift: jax.Array     # (b, d) last token (channel-mix)
    wkv: jax.Array        # (b, nh, hd, hd) float32


def nheads(cfg: ModelConfig) -> int:
    return cfg.d_model // HD


def init_rwkv6(key, cfg: ModelConfig) -> Rwkv6Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    halves = lambda: jnp.full((d,), 0.5, cfg.dtype)  # noqa: E731
    return Rwkv6Params(
        mix_r=halves(), mix_k=halves(), mix_v=halves(), mix_w=halves(),
        w_r=init_dense(ks[0], d, d, cfg.dtype),
        w_k=init_dense(ks[1], d, d, cfg.dtype),
        w_v=init_dense(ks[2], d, d, cfg.dtype),
        w_o=init_dense(ks[3], d, d, cfg.dtype),
        w0=jnp.full((d,), -0.6, jnp.float32),
        w_lora_a=init_dense(ks[4], d, LORA, "float32", scale=0.01),
        w_lora_b=init_dense(ks[5], LORA, d, "float32", scale=0.01),
        u=jnp.zeros((nheads(cfg), HD), jnp.float32),
        cmix_r=halves(), cmix_k=halves(),
        cw_r=init_dense(ks[6], d, d, cfg.dtype),
        cw_k=init_dense(ks[7], d, f, cfg.dtype),
        cw_v=init_dense(jax.random.fold_in(key, 99), f, d, cfg.dtype),
    )


def init_state(cfg: ModelConfig, batch: int) -> Rwkv6State:
    d, nh = cfg.d_model, nheads(cfg)
    return Rwkv6State(
        tshift=jnp.zeros((batch, d), cfg.dtype),
        cshift=jnp.zeros((batch, d), cfg.dtype),
        wkv=jnp.zeros((batch, nh, HD, HD), jnp.float32))


def _len_mask(lengths, b, s):
    """(b, s) bool: True at real-token positions of a RIGHT-padded batch."""
    return jnp.arange(s)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]


def _last_real(x, lengths):
    """x[:, lengths-1, :] — the last REAL token per row (right padding)."""
    idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def time_mix(p: Rwkv6Params, cfg: ModelConfig, x, state: Rwkv6State = None,
             lengths=None):
    """x: (b, s, d) -> (y, new_state pieces).  state=None => fresh sequence
    (zero states derived from x so they inherit x's sharding).

    ``lengths`` ((b,) int32, optional) marks the real prompt length per row
    of a RIGHT-padded batch: wkv state updates are masked off at padded
    positions and the returned token-shift is the last *real* token, so the
    state after a padded prefill is bitwise the unpadded state (padding
    invariance for the recurrent family)."""
    b, s, d = x.shape
    nh = nheads(cfg)
    tshift0 = x[:, 0, :] * 0 if state is None else state.tshift
    prev = jnp.concatenate([tshift0[:, None, :], x[:, :-1, :]], axis=1)

    def lerp(mix):
        return x + (prev - x) * mix

    r = jnp.einsum("bsd,de->bse", lerp(p.mix_r), p.w_r)
    k = jnp.einsum("bsd,de->bse", lerp(p.mix_k), p.w_k)
    v = jnp.einsum("bsd,de->bse", lerp(p.mix_v), p.w_v)
    # data-dependent decay (Finch)
    wx = lerp(p.mix_w).astype(jnp.float32)
    lora = jnp.tanh(wx @ p.w_lora_a) @ p.w_lora_b
    w = jnp.exp(-jnp.exp(p.w0 + lora))                     # (b, s, d) in (0,1)

    rh = r.reshape(b, s, nh, HD).astype(jnp.float32)
    kh = k.reshape(b, s, nh, HD).astype(jnp.float32)
    vh = v.reshape(b, s, nh, HD).astype(jnp.float32)
    wh = w.reshape(b, s, nh, HD)

    def step(S, inp):
        r_t, k_t, v_t, w_t, m_t = inp                      # (b, nh, hd) / (b,)
        kv = k_t[..., :, None] * v_t[..., None, :]         # (b, nh, hd, hd)
        o = jnp.einsum("bhi,bhij->bhj", r_t, S + p.u[..., None] * kv)
        S_new = w_t[..., :, None] * S + kv
        S = jnp.where(m_t[:, None, None, None], S_new, S)
        return S, o

    mask = (_len_mask(lengths, b, s) if lengths is not None
            else jnp.ones((b, s), bool))
    seq = tuple(a.transpose(1, 0, 2, 3) for a in (rh, kh, vh, wh))
    seq = seq + (mask.transpose(1, 0),)
    if state is None:  # sharding-inheriting zero state
        wkv0 = (kh[:, 0][..., :, None] * vh[:, 0][..., None, :]) * 0
    else:
        wkv0 = state.wkv
    S_final, os = jax.lax.scan(step, wkv0, seq)
    y = os.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", y, p.w_o)
    tshift = x[:, -1, :] if lengths is None else _last_real(x, lengths)
    return y, tshift, S_final


def channel_mix(p: Rwkv6Params, cfg: ModelConfig, x, state: Rwkv6State = None,
                lengths=None):
    cshift0 = x[:, 0, :] * 0 if state is None else state.cshift
    prev = jnp.concatenate([cshift0[:, None, :], x[:, :-1, :]], axis=1)
    xr = x + (prev - x) * p.cmix_r
    xk = x + (prev - x) * p.cmix_k
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p.cw_r))
    k = jnp.einsum("bsd,df->bsf", xk, p.cw_k)
    k = jnp.square(jax.nn.relu(k))
    cshift = x[:, -1, :] if lengths is None else _last_real(x, lengths)
    return r * jnp.einsum("bsf,fd->bsd", k, p.cw_v), cshift


def forward(p: Rwkv6Params, cfg: ModelConfig, x, state: Rwkv6State = None
            ) -> Tuple[jax.Array, jax.Array, Rwkv6State]:
    """One rwkv6 layer applied to pre-normed inputs happens in transformer.py;
    here: (time_mix_out, channel_mix callable parts) composed by the caller."""
    raise NotImplementedError("composed in transformer.py")
