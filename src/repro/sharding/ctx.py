"""Mesh context: lets model-internal code place sharding constraints without
threading the mesh through every call signature.

Set by the train/serve/dry-run builders (``set_mesh``); model code calls
``constraint(x, *axes)`` with logical axis names — axes absent from the
current mesh are dropped, and with no mesh set the call is the identity, so
single-device tests and CPU smoke runs are unaffected.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _filter(axis, mesh: Optional[Mesh] = None):
    """Drop axis names absent from ``mesh`` (or the context mesh).

    Safe with no mesh set: every name filters to None rather than touching
    ``_MESH.shape`` on None."""
    mesh = mesh if mesh is not None else _MESH
    if axis is None or mesh is None:
        return None
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.shape)
        return kept if kept else None
    return axis if axis in mesh.shape else None


def dp_axes():
    mesh = _MESH
    if mesh is None:
        return None
    kept = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return kept or None


def constraint(x, *axes):
    """with_sharding_constraint against the context mesh (identity if none).

    ``axes`` are per-dimension axis names (str / tuple / None); dims not
    divisible by their axis size fall back to None.
    """
    mesh = _MESH  # snapshot: set_mesh(None) mid-call must not crash us
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        ax = _filter(ax, mesh)
        if ax is not None:
            size = int(np.prod([mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))]))
            if dim % size != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PS(*spec)))
