"""Stage I: acceptor-passing and continuation-passing translations (Fig. 5).

``acceptor(E, A)`` produces a command equivalent to ``A :=_d E``;
``continuation(E, C)`` produces a command equivalent to ``C(E)``.
The two are mutually recursive exactly as in the paper; because binders are
HOAS, the "no administrative redexes" property of the paper's one-pass
formulation holds by construction.

Deviations from Fig. 5 (documented in DESIGN.md section 8):
  * ``Assign`` is kept at compound data types as a block operation (the TPU VPU
    leaf) instead of always expanding through ``mapI``; the paper's expansion
    of ``:=_d`` is available as :func:`expand_assign` and is applied by the
    imperative backends where needed.
  * ``ToMem`` (the paper's toGlobal/toLocal/toPrivate of section 6.2) threads a
    ``space`` parameter into the continuation translation; it steers where
    ``new`` allocates when a map result is materialised.
  * extra leaf primitives (DotBlock/FullReduce/As{Vector,Scalar}) follow the
    same clause shapes as the paper's first-order operators / split / join.
"""
from __future__ import annotations

from typing import Callable

from . import phrases as P
from .types import Arr, Pair, Vec


def acceptor(e: P.Phrase, a: P.Phrase) -> P.Phrase:  # noqa: C901
    """A(E)_d(A): a command with the effect of ``A :=_d E`` (Fig. 5a)."""
    if isinstance(e, (P.Var, P.Lit, P.ExpPart)):
        return P.Assign(a, e)
    if isinstance(e, P.UnOp):
        return continuation(e.e, lambda x: P.Assign(a, P.UnOp(e.op, x)))
    if isinstance(e, P.BinOp):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: P.Assign(a, P.BinOp(e.op, x, y))))
    if isinstance(e, P.Map):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr)
        x0 = P.Var(P.fresh("xe"), P.ExpT(d.elem))
        d2 = P.exp_data(e.f(x0))
        return continuation(
            e.e,
            lambda x: P.MapI(
                d.n, d.elem, d2,
                lambda xe, o: acceptor(e.f(xe), o),
                x, a, level=e.level))
    if isinstance(e, P.Reduce):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr)
        d2 = P.exp_data(e.init)
        return continuation(
            e.e,
            lambda x: continuation(
                e.init,
                lambda y: P.ReduceI(
                    d.n, d.elem, d2,
                    lambda xe, ye, o: acceptor(e.f(xe, ye), o),
                    y, x,
                    lambda r: P.Assign(a, r))))
    if isinstance(e, P.Zip):
        return P.SeqC(acceptor(e.a, P.ZipAcc1(a)), acceptor(e.b, P.ZipAcc2(a)))
    if isinstance(e, P.Split):
        return acceptor(e.e, P.SplitAcc(e.n, a))
    if isinstance(e, P.Join):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr) and isinstance(d.elem, Arr)
        return acceptor(e.e, P.JoinAcc(d.elem.n, a))
    if isinstance(e, P.PairE):
        return P.SeqC(acceptor(e.a, P.PairAcc1(a)), acceptor(e.b, P.PairAcc2(a)))
    if isinstance(e, P.Fst):
        return continuation(e.e, lambda x: P.Assign(a, P.Fst(x)))
    if isinstance(e, P.Snd):
        return continuation(e.e, lambda x: P.Assign(a, P.Snd(x)))
    if isinstance(e, P.IdxE):
        return continuation(
            e.e, lambda x: continuation(
                e.i, lambda j: P.Assign(a, P.IdxE(x, j))))
    if isinstance(e, P.AsVector):
        return acceptor(e.e, P.AsScalarAcc(a))
    if isinstance(e, P.AsScalar):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr) and isinstance(d.elem, Vec)
        return acceptor(e.e, P.AsVectorAcc(d.elem.n, a))
    if isinstance(e, P.Transpose):
        return acceptor(e.e, P.TransposeAcc(a))
    if isinstance(e, P.DotBlock):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: P.Assign(a, P.DotBlock(x, y, e.acc_dtype))))
    if isinstance(e, P.FullReduce):
        return continuation(e.e, lambda x: P.Assign(a, P.FullReduce(e.op, x)))
    if isinstance(e, P.ToMem):
        # In acceptor position the target storage already exists; the space
        # annotation only matters for the continuation translation.
        return acceptor(e.e, a)
    raise TypeError(f"acceptor translation: unhandled {type(e).__name__}")


def continuation(e: P.Phrase,
                 c: Callable[[P.Phrase], P.Phrase],
                 space: str = P.HBM) -> P.Phrase:  # noqa: C901
    """C(E)_d(C): a command with the effect of ``C(E)`` (Fig. 5b)."""
    if isinstance(e, (P.Var, P.Lit, P.ExpPart)):
        return c(e)
    if isinstance(e, P.UnOp):
        return continuation(e.e, lambda x: c(P.UnOp(e.op, x)), space)
    if isinstance(e, P.BinOp):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: c(P.BinOp(e.op, x, y)), space), space)
    if isinstance(e, P.Map):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr)
        x0 = P.Var(P.fresh("xe"), P.ExpT(d.elem))
        d2 = P.exp_data(e.f(x0))
        out_space = e.space or space
        # new (n.d2) (λtmp. A(map ..)(tmp.1); C(tmp.2))   — the deliberate
        # materialisation point: no implicit fusion (paper section 2.2).
        return P.New(
            Arr(d.n, d2),
            lambda tmp: P.SeqC(
                acceptor(e, P.AccPart(tmp)),
                c(P.ExpPart(tmp))),
            space=out_space)
    if isinstance(e, P.Reduce):
        d = P.exp_data(e.e)
        assert isinstance(d, Arr)
        d2 = P.exp_data(e.init)
        return continuation(
            e.e,
            lambda x: continuation(
                e.init,
                lambda y: P.ReduceI(
                    d.n, d.elem, d2,
                    lambda xe, ye, o: acceptor(e.f(xe, ye), o),
                    y, x, c),
                space),
            space)
    if isinstance(e, P.Zip):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: c(P.Zip(x, y)), space), space)
    if isinstance(e, P.Split):
        return continuation(e.e, lambda x: c(P.Split(e.n, x)), space)
    if isinstance(e, P.Join):
        return continuation(e.e, lambda x: c(P.Join(x)), space)
    if isinstance(e, P.PairE):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: c(P.PairE(x, y)), space), space)
    if isinstance(e, P.Fst):
        return continuation(e.e, lambda x: c(P.Fst(x)), space)
    if isinstance(e, P.Snd):
        return continuation(e.e, lambda x: c(P.Snd(x)), space)
    if isinstance(e, P.IdxE):
        return continuation(
            e.e, lambda x: continuation(
                e.i, lambda j: c(P.IdxE(x, j)), space), space)
    if isinstance(e, P.AsVector):
        return continuation(e.e, lambda x: c(P.AsVector(e.w, x)), space)
    if isinstance(e, P.AsScalar):
        return continuation(e.e, lambda x: c(P.AsScalar(x)), space)
    if isinstance(e, P.Transpose):
        return continuation(e.e, lambda x: c(P.Transpose(x)), space)
    if isinstance(e, P.DotBlock):
        return continuation(
            e.a, lambda x: continuation(
                e.b, lambda y: c(P.DotBlock(x, y, e.acc_dtype)), space), space)
    if isinstance(e, P.FullReduce):
        return continuation(e.e, lambda x: c(P.FullReduce(e.op, x)), space)
    if isinstance(e, P.ToMem):
        return continuation(e.e, c, space=e.space)
    raise TypeError(f"continuation translation: unhandled {type(e).__name__}")


def expand_assign(a: P.Phrase, e: P.Phrase) -> P.Phrase:
    """The paper's generalised assignment ``:=_d`` by induction on d
    (section 4.1): arrays via mapI, pairs componentwise, scalars directly."""
    d = P.acc_data(a)
    if isinstance(d, Arr):
        return P.MapI(d.n, d.elem, d.elem,
                      lambda x, o: expand_assign(o, x), e, a)
    if isinstance(d, Pair):
        return P.SeqC(expand_assign(P.PairAcc1(a), P.Fst(e)),
                      expand_assign(P.PairAcc2(a), P.Snd(e)))
    return P.Assign(a, e)


def translate(e: P.Phrase, out: P.Phrase) -> P.Phrase:
    """Whole Stage-I entry point: A(E)(out)."""
    return acceptor(e, out)
