"""Backend equivalence: Pallas (interpret) and hoisting vs the jnp backend and
the functional oracle; mesh backend in a subprocess (needs >1 device)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpia import hoist, interp, phrases as P, stage1, stage2
from repro.core.dpia import stage3_jnp, stage3_pallas
from repro.core.dpia.types import Arr, Num
from repro.kernels import dpia_blas


def both_backends(expr, argv, args, rtol=2e-3):
    want = interp.interp(expr, {v.name: a for v, a in zip(argv, args)})
    for backend in ("jnp", "pallas"):
        from repro import compiler
        fn = compiler.Program(expr, argv).check().lower().compile(backend)
        got = fn(*args)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=rtol, atol=rtol,
                                   err_msg=f"backend={backend}")


class TestPallasBackend:
    def test_grid_dot(self, rng):
        expr, argv = dpia_blas.strategy_dot(1024, block=128)
        args = (jnp.asarray(rng.randn(1024), "float32"),
                jnp.asarray(rng.randn(1024), "float32"))
        both_backends(expr, argv, args)

    def test_grid_scal(self, rng):
        expr, argv = dpia_blas.strategy_scal(512, block=64)
        args = (jnp.float32(3.5), jnp.asarray(rng.randn(512), "float32"))
        both_backends(expr, argv, args)

    def test_grid_matmul(self, rng):
        expr, argv = dpia_blas.strategy_matmul(64, 64, 32, bm=16, bk=32)
        args = (jnp.asarray(rng.randn(64, 64), "float32"),
                jnp.asarray(rng.randn(64, 32), "float32"))
        both_backends(expr, argv, args)

    def test_rmsnorm(self, rng):
        expr, argv = dpia_blas.strategy_rmsnorm(16, 64, row_block=4)
        args = (jnp.asarray(rng.randn(16, 64), "float32"),
                jnp.asarray(rng.randn(64), "float32"))
        both_backends(expr, argv, args)

    def test_vectorised_scal(self, rng):
        """asVector strategy (paper section 6.2/6.3) through both backends."""
        alpha = P.var_exp("alpha", Num())
        xs = P.var_exp("xs", Arr(256, Num()))
        e = P.AsScalar(P.Join(P.Map(
            lambda blk: P.mul(alpha, blk),
            P.Split(4, P.AsVector(8, xs)), level=P.GRID(0))))
        args = (jnp.float32(1.5), jnp.asarray(rng.randn(256), "float32"))
        both_backends(e, [alpha, xs], args)


class TestHoist:
    def test_paper_64_example_semantics(self, rng):
        """Section 6.4: hoisting multiplies extents and preserves semantics."""
        xs = P.var_exp("xs", Arr(64, Num()))
        out = P.var_acc("out", Arr(16, Num()))
        prog = P.ParFor(16, Num(), out, lambda i, o: P.New(
            Arr(4, Num()),
            lambda tmp: P.SeqC(
                P.For(4, lambda j: P.Assign(
                    P.IdxAcc(P.AccPart(tmp), j),
                    P.IdxE(P.IdxE(P.Split(4, xs), i), j))),
                P.Assign(o, P.FullReduce("add", P.ExpPart(tmp)))),
            space=P.HBM))
        hoisted = hoist.hoist(prog)
        # structure: top-level New of the multiplied extent
        assert isinstance(hoisted, P.New)
        assert hoisted.d == Arr(16, Arr(4, Num()))
        env = {"xs": jnp.asarray(rng.randn(64), "float32")}
        s1 = stage3_jnp.exec_comm(prog, env, {"out": jnp.zeros(16)})
        s2 = stage3_jnp.exec_comm(hoisted, env, {"out": jnp.zeros(16)})
        np.testing.assert_allclose(s1["out"], s2["out"], rtol=1e-5)

    def test_reg_news_not_hoisted(self):
        out = P.var_acc("out", Arr(8, Num()))
        xs = P.var_exp("xs", Arr(8, Num()))
        prog = P.ParFor(8, Num(), out, lambda i, o: P.New(
            Num(), lambda v: P.SeqC(
                P.Assign(P.AccPart(v), P.IdxE(xs, i)),
                P.Assign(o, P.ExpPart(v))), space=P.REG))
        assert hoist.hoist(prog) is prog  # no hoistable items -> unchanged


MESH_TEST = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.dpia import interp, stage3_shardmap
from repro.kernels import dpia_blas

mesh = jax.make_mesh((8,), ("data",))
expr, argv = dpia_blas.mesh_dot(8 * 64, "data", 8, block=64)
rng = np.random.RandomState(0)
ax = jnp.asarray(rng.randn(512), "float32")
ay = jnp.asarray(rng.randn(512), "float32")
want = interp.interp(expr, {"xs": ax, "ys": ay})
fn = jax.jit(stage3_shardmap.compile_expr_shardmap(expr, argv, mesh))
got = fn(ax, ay)
np.testing.assert_allclose(got, want, rtol=1e-4)
hlo = jax.jit(fn).lower(ax, ay).compile().as_text()
# count all-reduce *instructions* (opcode position), not raw substrings:
# XLA names the instruction %all-reduce.N, which a plain count double-counts
import re
n_ar = len(re.findall(r"=\s*\S+\s+all-reduce(?:-start)?\(", hlo))
assert n_ar == 1, f"strategy dictates exactly ONE all-reduce, found {n_ar}"
print("MESH_OK")
"""


@pytest.mark.slow
def test_mesh_backend_subprocess():
    """Distributed dot: correct result AND exactly the collective schedule the
    strategy dictates (one all-reduce) — strategy preservation at mesh level."""
    # JAX_PLATFORMS=cpu: this is a *host-platform* multi-device test; without
    # it, images with libtpu installed try (and stall on) TPU init and lower
    # the collective asynchronously, breaking the schedule assertion below.
    r = subprocess.run([sys.executable, "-c", MESH_TEST],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr
