"""Sharded checkpointing: atomic, async, mesh-agnostic, with retention.

Format: one .npz per checkpoint step (flattened path->array) + manifest.json
(step, data state, config fingerprint).  Writes go to a temp dir + atomic
rename; an async mode runs the serialisation on a worker thread so the train
loop overlaps I/O with compute.  Arrays are stored as host (fully replicated)
values with their *logical* pytree paths — restore re-places them under any
mesh (elastic re-mesh: restore onto a different topology than the save).

Manifests go through the checksummed atomic store in ``repro.ft.artefacts``
— the same self-healing write path the tuning cache and scheduler journals
use.  A corrupt manifest is quarantined (``manifest.json.quarantine/``) and
its step vanishes from ``all_steps()``; ``restore_latest`` falls back to
the newest step that still verifies instead of crashing the resume path.
"""
from __future__ import annotations

import logging
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ft import artefacts

log = logging.getLogger("repro.ckpt")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_k(k) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(_k(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, *, extra: Optional[Dict] = None) -> None:
        flat = _flatten(state)   # device_get on the train thread (cheap copy)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}))
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Dict) -> None:
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            artefacts.save_json(os.path.join(tmp, "manifest.json"),
                                {"step": step, "extra": extra})
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.startswith(".tmp"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template) -> Tuple[Any, Dict]:
        path = os.path.join(self.dir, f"step_{step:010d}")
        manifest = artefacts.load_json(os.path.join(path, "manifest.json"),
                                       what="checkpoint manifest")
        if manifest is None:
            # missing or corrupt: corrupt copies are already quarantined +
            # reported by load_json, which also removes the step from
            # all_steps() (no manifest.json left) — raise so restore_latest
            # falls back to an older step
            raise ValueError(
                f"checkpoint manifest for step {step} missing or corrupt "
                f"(quarantined; see artefact.load_failed events)")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        return state, manifest.get("extra", {})

    def restore_latest(self, template) -> Optional[Tuple[int, Any, Dict]]:
        """Restore the newest checkpoint that VERIFIES — a corrupt manifest
        or damaged arrays skips back to the next older step instead of
        killing the resume (losing a few steps of progress beats losing
        the run)."""
        for step in reversed(self.all_steps()):
            try:
                state, extra = self.restore(step, template)
            except (ValueError, KeyError, OSError) as e:
                log.warning("checkpoint step %d failed to restore (%s: "
                            "%s); falling back to the previous step",
                            step, type(e).__name__, e)
                continue
            return step, state, extra
        return None
