"""Stage III backend registry — the ``"jnp" | "pallas" | "shardmap"`` string
matrix as *data* instead of if/elif chains.

A :class:`Backend` wraps one Stage III code generator (functional/imperative
DPIA -> executable callable).  The built-in generators in
``repro.core.dpia.stage3_*`` self-register on import; user code can register
additional targets with :func:`register_backend` and they become valid
everywhere a backend name is accepted (``Program.compile``, the kernel-layer
``dpia-<name>`` impls, option validation, error messages).

This module deliberately imports nothing from ``repro.core.dpia`` at module
level: the stage3 modules import *us* to self-register, and keeping the
registry dependency-free makes that cycle-safe.  Lookup lazily imports
``repro.core.dpia`` so the built-ins are always populated before first use.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

__all__ = [
    "Backend", "register_backend", "unregister_backend", "get_backend",
    "backend_names", "ops_impls",
]


@dataclass(frozen=True)
class Backend:
    """One Stage III target.

    ``compile(expr, arg_vars, **kw) -> callable`` produces the executable
    (un-jitted) function.  ``accepts`` names the keyword arguments the
    generator understands (``"check"``, ``"lowered"``, ``"interpret"``, ...):
    ``Program.compile`` threads options through only when accepted.
    ``requires`` names keywords the caller *must* supply (e.g. ``"mesh"``
    for the shard_map backend) — backends with requirements are excluded
    from the kernel-layer ``dpia-<name>`` impl matrix.
    """
    name: str
    compile: Callable[..., Callable]
    accepts: Tuple[str, ...] = ()
    requires: Tuple[str, ...] = ()
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}
_ALIASES: Dict[str, str] = {}
_LOCK = threading.Lock()
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Populate the registry with the stage3 built-ins (idempotent)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    # importing the package runs the stage3 modules' self-registration
    import repro.core.dpia  # noqa: F401
    _BUILTINS_LOADED = True


def register_backend(backend: Backend, *, aliases: Tuple[str, ...] = (),
                     overwrite: bool = False) -> Backend:
    """Add a Stage III backend (and optional alias names) to the registry."""
    if not isinstance(backend, Backend):
        raise TypeError(f"register_backend expects a Backend, got "
                        f"{type(backend).__name__}")
    with _LOCK:
        if backend.name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {backend.name!r} is already registered "
                             f"(pass overwrite=True to replace it)")
        _REGISTRY[backend.name] = backend
        for a in aliases:
            _ALIASES[a] = backend.name
    return backend


def unregister_backend(name: str) -> None:
    """Remove a backend (and any aliases pointing at it)."""
    with _LOCK:
        _REGISTRY.pop(name, None)
        for a in [a for a, t in _ALIASES.items() if t == name]:
            del _ALIASES[a]


def get_backend(name) -> Backend:
    """Resolve a backend by name/alias (or pass a Backend through).

    Raises ``ValueError`` naming the valid backends on an unknown name —
    the error message is the registry's contents, so it is always current.
    """
    if isinstance(name, Backend):
        return name
    _ensure_builtins()
    resolved = _ALIASES.get(name, name)
    try:
        return _REGISTRY[resolved]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{backend_names()} (aliases: {sorted(_ALIASES)})") from None


def backend_names() -> Tuple[str, ...]:
    """Registered backend names, sorted (aliases not included)."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def ops_impls() -> Tuple[str, ...]:
    """Valid kernel-layer impl names for ``repro.kernels.ops`` dispatch.

    The two native impls plus one ``dpia-<backend>`` entry per registered
    backend whose requirements the op layer can satisfy: no requirements,
    or a ``mesh`` requirement (resolvable from ``CompileOptions.mesh`` /
    the process mesh context, so ``dpia-shardmap`` IS an op-layer impl).
    Backends requiring anything else cannot be driven from the op layer
    and are excluded."""
    names = ["xla", "pallas"]
    for b in backend_names():
        if set(get_backend(b).requires) - {"mesh"}:
            continue
        names.append("dpia-" + b)
    return tuple(dict.fromkeys(names))
