"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests see the real
single CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count before importing jax."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
