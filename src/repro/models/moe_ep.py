"""Expert-parallel MoE dispatch with explicit all-to-all (shard_map island).

GSPMD cannot infer all-to-all from a scatter across a sharded expert dim —
it falls back to all-gathers of token tensors (measured: the dominant
collective term on dbrx/grok train cells, EXPERIMENTS.md section Perf).  This
module does the exchange manually:

  per (dp x model) shard: local top-k routing
    -> fixed-capacity per-destination buckets (cumsum slotting)
    -> lax.all_to_all over 'model'  (payload ~ t*k*d/shards, the EP ideal)
    -> local expert FFN (each model shard owns e/model_size experts)
    -> all_to_all back, gate-weighted combine at the source.

Requirements: mesh has a 'model' axis, n_experts % model_size == 0, and the
local token count divides evenly; otherwise callers fall back to ffn.moe
(the GSPMD path).  Differentiable (all_to_all transposes to all_to_all).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from .common import ModelConfig
from .ffn import MoeParams


def applicable(cfg: ModelConfig, mesh) -> bool:
    if mesh is None or "model" not in mesh.shape:
        return False
    return cfg.n_experts > 0 and cfg.n_experts % mesh.shape["model"] == 0


def moe_ep(p: MoeParams, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for ffn.moe with explicit EP all-to-all.  x: (b, s, d)."""
    from repro.sharding import ctx

    mesh = ctx.get_mesh()
    assert applicable(cfg, mesh)
    dp = ctx.dp_axes() or ()
    model_size = mesh.shape["model"]
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    e_local = e // model_size
    b, s, _ = x.shape

    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    # local token geometry: batch over dp, sequence over model (SP layout)
    if (b % dp_size) or (s % model_size):
        from . import ffn
        return ffn.moe(p, cfg, x)
    tl = (b // dp_size) * (s // model_size)
    cap = max(int(np.ceil(cfg.moe_capacity_factor * tl * k / model_size)), 4)

    def body(xl, router, w_gate, w_up, w_down):
        # xl: (b_l, s_l, d); weights: router (d, e) replicated,
        # w_* (e_local, d, f) — this shard's experts.
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        topk_p, topk_i = jax.lax.top_k(probs, k)               # (t, k)
        topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

        me = jax.lax.pmean(jnp.mean(probs, axis=0), "model")
        oh = jax.nn.one_hot(topk_i, e, dtype=jnp.float32)
        ce = jax.lax.pmean(jnp.mean(jnp.sum(oh, axis=1), axis=0), "model")
        aux = e * jnp.sum(me * ce) / k

        flat_e = topk_i.reshape(-1)                             # (t*k,)
        dst = flat_e // e_local                                 # dest shard
        e_loc = flat_e % e_local                                # expert @ dst
        # slot within (dst) bucket via masked cumsum
        oh_dst = jax.nn.one_hot(dst, model_size, dtype=jnp.int32)
        pos = jnp.sum(jnp.cumsum(oh_dst, axis=0) * oh_dst, axis=-1) - 1
        keep = pos < cap
        gate = topk_p.reshape(-1) * keep
        pos_c = jnp.clip(pos, 0, cap - 1)

        tok_idx = jnp.repeat(jnp.arange(t), k)
        xk = jnp.take(xt, tok_idx, axis=0)
        xk = xk * keep[:, None].astype(xt.dtype)
        send = jnp.zeros((model_size, cap, d), xt.dtype)
        send = send.at[dst, pos_c].add(xk, mode="drop")
        meta = jnp.zeros((model_size, cap), jnp.int32)
        meta = meta.at[dst, pos_c].add(
            jnp.where(keep, e_loc + 1, 0), mode="drop")

        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        meta_r = jax.lax.all_to_all(meta, "model", split_axis=0,
                                    concat_axis=0, tiled=False)

        # local expert compute
        re = (meta_r.reshape(-1) - 1)                           # (-1 = empty)
        occupied = re >= 0
        slots = recv.reshape(model_size * cap, d)
        slots = slots * occupied[:, None].astype(slots.dtype)
        if e_local == 1:
            # one expert per shard (the common at-scale case): slots feed the
            # expert directly — no zero-padded per-expert buffers
            h = jax.nn.silu(jnp.einsum("cd,df->cf", slots, w_gate[0]))
            h = h * jnp.einsum("cd,df->cf", slots, w_up[0])
            yslots = jnp.einsum("cf,fd->cd", h, w_down[0])
        else:
            re_c = jnp.clip(re, 0, e_local - 1)
            slot_pos = jnp.arange(model_size * cap)
            ebuf = jnp.zeros((e_local, model_size * cap, d), slots.dtype)
            ebuf = ebuf.at[re_c, slot_pos].add(slots, mode="drop")
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, w_gate))
            h = h * jnp.einsum("ecd,edf->ecf", ebuf, w_up)
            ybuf = jnp.einsum("ecf,efd->ecd", h, w_down)
            yslots = ybuf[re_c, slot_pos]                       # gather back
        yslots = yslots * occupied[:, None].astype(yslots.dtype)

        yback = jax.lax.all_to_all(
            yslots.reshape(model_size, cap, d), "model",
            split_axis=0, concat_axis=0, tiled=False)

        yk = yback[dst, pos_c]                                  # (t*k, d)
        yk = yk * gate[:, None].astype(yback.dtype)
        out = jnp.zeros((t, d), yback.dtype).at[tok_idx].add(yk)
        return out.reshape(bl, sl, d).astype(xl.dtype), aux

    dp_spec = dp if dp else None
    sm = shard_map(
        body, mesh=mesh,
        in_specs=(PS(dp_spec, "model", None),        # x: batch x seq(SP) x d
                  PS(None, None),                    # router replicated
                  PS("model", None, None),           # experts over model
                  PS("model", None, None),
                  PS("model", None, None)),
        out_specs=(PS(dp_spec, "model", None), PS()),
        check_rep=False)
    out, aux = sm(x, p.router, p.w_gate, p.w_up, p.w_down)
    return out, aux
