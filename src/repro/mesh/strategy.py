"""MeshStrategy — mesh placement as a first-class, cache-keyable strategy.

The paper's hierarchy assigns every ``map``/``reduce`` a level (lanes, grid,
...); our Stage III shardmap backend extends it to the *mesh* level
(``map[mesh(ax)]`` -> ``shard_map``, ``reduce[mesh(ax)]`` -> ``psum``).  This
module makes that placement declarative:

  * :class:`MeshStrategy` records which distributed level a kernel's top
    map/reduce binds to which **named mesh axis**, validated against a
    concrete ``jax.sharding.Mesh`` shape;
  * :func:`descriptor` renders a mesh as a canonical string
    (``"single"`` / ``"data=8"`` / ``"pod=2,data=16,model=16"``) — the mesh
    component of the tuning-cache and executor-cache keys, so artefacts
    tuned or compiled for different meshes can never be confused;
  * :func:`parse_descriptor` inverts it, so the autotuner can enumerate
    mesh-axis candidates from a descriptor alone (no devices needed).

Nothing here imports repro.compiler or repro.autotune at module level — the
strategy layer stays dependency-free so both can import it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

__all__ = ["MeshStrategy", "descriptor", "parse_descriptor",
           "current_descriptor", "resolve_mesh", "shrink_descriptor",
           "SINGLE"]

SINGLE = "single"


# ---------------------------------------------------------------------------
# canonical mesh descriptors (cache keys)
# ---------------------------------------------------------------------------

def descriptor(mesh) -> str:
    """Canonical string form of a mesh: ``"single"`` for None, else the
    axis-order ``name=size`` list (``"data=8"``, ``"data=2,model=4"``).

    Axis *order* is part of the descriptor — two meshes with the same axis
    sizes in a different device order are different placement targets.
    Accepts a Mesh, an already-rendered descriptor string, or None.
    """
    if mesh is None:
        return SINGLE
    if isinstance(mesh, str):
        return mesh or SINGLE
    shape = getattr(mesh, "shape", None)
    if shape is None:
        raise TypeError(f"descriptor: expected a jax Mesh, a descriptor "
                        f"string, or None, got {type(mesh).__name__}")
    if not len(shape):
        return SINGLE
    return ",".join(f"{a}={int(s)}" for a, s in shape.items())


def parse_descriptor(desc: str) -> Dict[str, int]:
    """Axis name -> size for a :func:`descriptor` string ({} for "single")."""
    if not desc or desc == SINGLE:
        return {}
    out: Dict[str, int] = {}
    for part in desc.split(","):
        name, _, size = part.partition("=")
        if not name or not size:
            raise ValueError(f"parse_descriptor: malformed component "
                             f"{part!r} in {desc!r}")
        out[name] = int(size)
    return out


def shrink_descriptor(desc: str, n_devices: int,
                      axis: Optional[str] = None) -> str:
    """The largest descriptor reachable from ``desc`` on ``n_devices``
    devices, halving one axis (``axis``, default the *leading* axis — the
    data axis by convention) until the total fits.

    Pure string->string: this is the canonical scale-down rule shared by
    elastic re-meshing after node loss (``ft.resilience.elastic_remesh``)
    and the serving failure-domain layer (``repro.serve.domains``), so a
    shrunk mesh always round-trips through :func:`parse_descriptor` and
    lands on a shape the cache keys can name.  Raises ``ValueError`` when
    even the fully-shrunk shape needs more devices than available."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    axes = parse_descriptor(descriptor(desc))
    if not axes:
        return SINGLE
    ax = axis if axis is not None else next(iter(axes))
    if ax not in axes:
        raise ValueError(f"shrink axis {ax!r} not in descriptor {desc!r}")

    def total() -> int:
        t = 1
        for s in axes.values():
            t *= s
        return t

    while total() > n_devices and axes[ax] > 1:
        axes[ax] //= 2
    if total() > n_devices:
        raise ValueError(
            f"not enough devices for {desc!r}: the fully shrunk shape "
            f"still needs {total()}, have {n_devices}")
    return ",".join(f"{a}={s}" for a, s in axes.items())


def resolve_mesh(mesh=None):
    """The concrete Mesh to compile against: an explicit argument wins, then
    the active ``compiler.options(mesh=...)`` scope, then the process mesh
    context (``repro.sharding.ctx``).  Returns None when single-device."""
    if mesh is not None:
        return mesh
    from repro.compiler import current_options
    opt_mesh = getattr(current_options(), "mesh", None)
    if opt_mesh is not None:
        return opt_mesh
    from repro.sharding import ctx
    return ctx.get_mesh()


def current_descriptor(mesh=None) -> str:
    """Descriptor of :func:`resolve_mesh` — what cache keys should carry."""
    return descriptor(resolve_mesh(mesh))


# ---------------------------------------------------------------------------
# MeshStrategy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshStrategy:
    """One mesh-level placement decision for a kernel.

    axis     named mesh axis the distributed map/reduce binds to
    op       "map"    — output stays sharded over ``axis`` (gathered by the
                        Join re-view; scal/rmsnorm/softmax/matmul row shard)
             "reduce" — per-shard partials are combined by one mesh reduce
                        (``lax.psum``; dot/asum)
    extent   the logical extent being sharded (n, rows, or m) — recorded so
             validation can check divisibility without re-deriving it
    """
    axis: str
    op: str = "map"
    extent: Optional[int] = None

    def __post_init__(self):
        if self.op not in ("map", "reduce"):
            raise ValueError(f"MeshStrategy.op must be 'map' or 'reduce', "
                             f"got {self.op!r}")

    # -- validation ----------------------------------------------------------

    def shards(self, mesh) -> int:
        """Number of shards the bound axis provides on ``mesh``."""
        axes = mesh if isinstance(mesh, dict) else dict(mesh.shape)
        if self.axis not in axes:
            raise ValueError(
                f"mesh axis {self.axis!r} not in mesh {sorted(axes)}")
        return int(axes[self.axis])

    def validate(self, mesh) -> "MeshStrategy":
        """Check this placement against a Mesh (or axis->size dict): the axis
        must exist and the sharded extent must divide evenly.  Fluent."""
        size = self.shards(mesh)
        if self.extent is not None and self.extent % size != 0:
            raise ValueError(
                f"extent {self.extent} not divisible by mesh axis "
                f"{self.axis!r} of size {size}")
        return self

    # -- canonical forms -----------------------------------------------------

    def describe(self) -> str:
        """``map[mesh(data)]`` / ``reduce[mesh(data)]`` — the strategy level
        this placement assigns, in the paper's level-annotation notation."""
        return f"{self.op}[mesh({self.axis})]"

    def params(self) -> Dict[str, object]:
        """The tuning-space params fragment this placement contributes."""
        return {"mesh_axis": self.axis}

    @classmethod
    def from_params(cls, params: Dict[str, object], *, op: str = "map",
                    extent: Optional[int] = None) -> Optional["MeshStrategy"]:
        """Rebuild from a tuned params dict; None when the params carry no
        mesh placement (a single-device candidate)."""
        ax = params.get("mesh_axis")
        if ax is None:
            return None
        return cls(axis=str(ax), op=op, extent=extent)
