"""Hand-written Pallas tiled matmul (MXU-aligned BlockSpecs).

Grid (m/bm, n/bn, k/bk) with the k dimension innermost; a float32 VMEM scratch
accumulates partial products across k steps and is flushed to the output block
on the last step — the canonical Mosaic matmul shape.  Validated against
ref.matmul in interpret mode; on real TPU the same kernel compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = lambda shape, dt: pltpu.VMEM(shape, dt)  # noqa: E731
except Exception:  # pragma: no cover
    _VMEM = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)  # noqa: E731


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool | None = None, out_dtype=None):
    """C = A @ B with (bm, bn, bk) MXU tiling.

    ``interpret=None`` auto-selects: interpret mode only on CPU hosts."""
    if interpret is None:
        from repro.compiler.options import default_interpret
        interpret = default_interpret()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shape ({m},{k})x({k},{n}) not divisible by tile ({bm},{bn},{bk})"
    out_dtype = out_dtype or a.dtype
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
