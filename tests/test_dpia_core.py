"""DPIA core: types, typing rules, SCIR race-freedom (paper sections 3, 5)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpia import check, interp, phrases as P, stage1, stage2
from repro.core.dpia.phrases import DpiaTypeError
from repro.core.dpia.check import RaceError
from repro.core.dpia.types import (Arr, ExpT, Idx, Num, Pair, Vec, arr,
                                   is_passive, AccT, CommT, FnT)


class TestTypes:
    def test_shapes(self):
        assert arr(4, 8) == Arr(4, Arr(8, Num()))

    def test_passivity(self):
        # Fig. 2: exp passive; acc/comm active; fn passive iff return passive
        assert is_passive(ExpT(Num()))
        assert not is_passive(AccT(Num()))
        assert not is_passive(CommT())
        assert is_passive(FnT(AccT(Num()), ExpT(Num())))
        assert not is_passive(FnT(ExpT(Num()), CommT()))
        assert is_passive(FnT(ExpT(Num()), CommT(), passive=True))

    def test_split_join_types(self):
        xs = P.var_exp("xs", Arr(12, Num()))
        assert P.exp_data(P.Split(4, xs)) == Arr(3, Arr(4, Num()))
        assert P.exp_data(P.Join(P.Split(4, xs))) == Arr(12, Num())

    def test_zip_type(self):
        xs = P.var_exp("xs", Arr(8, Num()))
        ys = P.var_exp("ys", Arr(8, Num()))
        assert P.exp_data(P.Zip(xs, ys)) == Arr(8, Pair(Num(), Num()))

    def test_zip_length_mismatch(self):
        xs = P.var_exp("xs", Arr(8, Num()))
        ys = P.var_exp("ys", Arr(4, Num()))
        with pytest.raises(DpiaTypeError):
            P.type_of(P.Zip(xs, ys))

    def test_split_divisibility(self):
        xs = P.var_exp("xs", Arr(10, Num()))
        with pytest.raises(DpiaTypeError):
            P.type_of(P.Split(4, xs))

    def test_asvector(self):
        xs = P.var_exp("xs", Arr(16, Num()))
        assert P.exp_data(P.AsVector(4, xs)) == Arr(4, Vec(4, "float32"))
        assert P.exp_data(P.AsScalar(P.AsVector(4, xs))) == Arr(16, Num())

    def test_map_type(self):
        xs = P.var_exp("xs", Arr(8, Num()))
        m = P.Map(lambda x: P.add(x, P.lit(1.0)), xs)
        assert P.exp_data(m) == Arr(8, Num())

    def test_assign_shape_mismatch(self):
        a = P.var_acc("a", Arr(4, Num()))
        e = P.var_exp("e", Arr(8, Num()))
        with pytest.raises(DpiaTypeError):
            P.type_of(P.Assign(a, e))


class TestRaceFreedom:
    def test_paper_racy_parfor_rejected(self):
        """The paper's section 3.3 non-typable example: every iteration writes
        the same acceptor b — a data race, rejected by passivity."""
        b = P.var_acc("b", Num())
        es = P.var_exp("es", Arr(8, Num()))
        out = P.var_acc("out", Arr(8, Num()))
        racy = P.ParFor(8, Num(), out,
                        lambda i, o: P.Assign(b, P.IdxE(es, i)))
        with pytest.raises(RaceError):
            check.check_race_free(racy)

    def test_race_free_parfor_accepted(self):
        es = P.var_exp("es", Arr(8, Num()))
        out = P.var_acc("out", Arr(8, Num()))
        ok = P.ParFor(8, Num(), out,
                      lambda i, o: P.Assign(o, P.IdxE(es, i)))
        check.check_race_free(ok)

    def test_sequential_for_may_share(self):
        """(;) and for bodies may interfere (contexts shared via Pair rule)."""
        v_acc = P.var_acc("v", Num())
        v_exp = P.var_exp("v", Num())
        c = P.For(4, lambda i: P.Assign(v_acc, P.add(v_exp, P.lit(1.0))))
        check.check_race_free(c)  # no exception

    def test_nested_parfor_inner_acceptor_only(self):
        es = P.var_exp("es", Arr(4, Arr(4, Num())))
        out = P.var_acc("out", Arr(4, Arr(4, Num())))
        ok = P.ParFor(4, Arr(4, Num()), out, lambda i, o: P.ParFor(
            4, Num(), o, lambda j, o2: P.Assign(
                o2, P.IdxE(P.IdxE(es, i), j))))
        check.check_race_free(ok)

    def test_full_translation_is_race_free(self):
        xs = P.var_exp("xs", Arr(16, Num()))
        e = P.Map(lambda x: P.mul(x, x), xs)
        cmd = stage2.expand(stage1.translate(e, P.var_acc("o", Arr(16, Num()))))
        check.check(cmd)
