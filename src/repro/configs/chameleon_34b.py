"""chameleon-34b [vlm] — 48L d=8192 64H (GQA kv=8) ff=22016 vocab=65536,
early-fusion VQ image tokens (frontend stub: ids arrive pre-tokenised)
[arXiv:2405.09818; unverified]"""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, fsdp=True)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=8,
                               n_kv_heads=2, d_ff=128, vocab=256,
                               dtype="float32", fsdp=False, max_seq=64)
