"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests see the real
single CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count before importing jax."""
import os
import tempfile

import numpy as np
import pytest

# the suite is written against the host CPU platform (see note above); on
# images that ship libtpu, keep jax from probing/initialising a TPU backend
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# keep the strategy autotuner's persistent cache out of the user's home dir
# (repro.autotune reads this env var lazily, so setting it here is enough)
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-autotune-"), "autotune.json"))


@pytest.fixture
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def tuning_cache(tmp_path):
    """A fresh, isolated persistent tuning cache."""
    from repro.autotune import TuningCache
    return TuningCache(str(tmp_path / "autotune.json"))
