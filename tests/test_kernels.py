"""Per-kernel allclose sweeps: Pallas kernels (interpret=True) and
DPIA-generated kernels vs the ref.py oracles, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dpia_blas, ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.core.dpia import interp


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 64, 64, 64),
    (256, 128, 64, 64, 64, 128),
    (64, 256, 128, 64, 128, 64),
    (128, 128, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pallas_matmul(rng, m, k, n, bm, bn, bk, dtype):
    a = jnp.asarray(rng.randn(m, k), dtype)
    b = jnp.asarray(rng.randn(k, n), dtype)
    got = matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype="float32")
    want = ref.matmul(a, b, out_dtype="float32")
    tol = 1e-4 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("rows,d,br", [(64, 128, 16), (100, 64, 32),
                                       (8, 512, 8)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pallas_rmsnorm(rng, rows, d, br, dtype):
    x = jnp.asarray(rng.randn(rows, d), dtype)
    w = jnp.asarray(rng.randn(d), dtype)
    got = rmsnorm(x, w, block_rows=br)
    want = ref.rmsnorm(x, w)
    tol = 1e-4 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(got, "float32"),
                               np.asarray(want, "float32"),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bh,bkv,s,d,bq,bk_", [
    (4, 4, 128, 64, 64, 64),     # MHA
    (8, 2, 256, 64, 64, 128),    # GQA 4:1
    (4, 1, 128, 32, 128, 32),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_pallas_flash_attention(rng, bh, bkv, s, d, bq, bk_, causal):
    q = jnp.asarray(rng.randn(bh, s, d), "float32") * 0.3
    k = jnp.asarray(rng.randn(bkv, s, d), "float32") * 0.3
    v = jnp.asarray(rng.randn(bkv, s, d), "float32")
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk_)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_pallas_flash_decode_offset(rng):
    q = jnp.asarray(rng.randn(4, 1, 64), "float32") * 0.3
    k = jnp.asarray(rng.randn(2, 256, 64), "float32") * 0.3
    v = jnp.asarray(rng.randn(2, 256, 64), "float32")
    got = flash_attention(q, k, v, causal=True, q_offset=255, bq=1, bk=64)
    want = ref.flash_attention(q, k, v, causal=True, q_offset=255)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


DPIA_CASES = [
    ("scal", lambda n: dpia_blas.strategy_scal(n, block=n // 4),
     lambda rng, n: (jnp.float32(2.5), jnp.asarray(rng.randn(n), "float32"))),
    ("asum", lambda n: dpia_blas.strategy_asum(n, block=n // 4),
     lambda rng, n: (jnp.asarray(rng.randn(n), "float32"),)),
    ("dot", lambda n: dpia_blas.strategy_dot(n, block=n // 4),
     lambda rng, n: (jnp.asarray(rng.randn(n), "float32"),
                     jnp.asarray(rng.randn(n), "float32"))),
]


@pytest.mark.parametrize("name,builder,mk", DPIA_CASES)
@pytest.mark.parametrize("n", [256, 1024])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_dpia_blas_sweep(rng, name, builder, mk, n, backend):
    expr, argv = builder(n)
    args = mk(rng, n)
    want = interp.interp(expr, {v.name: a for v, a in zip(argv, args)})
    from repro import compiler
    fn = compiler.Program(expr, argv).check().lower().compile(backend)
    got = fn(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("m,n,rb", [(64, 128, 16), (256, 64, 64)])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_dpia_gemv_sweep(rng, m, n, rb, backend):
    expr, argv = dpia_blas.strategy_gemv(m, n, row_block=rb)
    a = jnp.asarray(rng.randn(m, n), "float32")
    x = jnp.asarray(rng.randn(n), "float32")
    from repro import compiler
    fn = compiler.Program(expr, argv).check().lower().compile(backend)
    np.testing.assert_allclose(np.asarray(fn(a, x)), np.asarray(a @ x),
                               rtol=2e-3, atol=2e-3)


def test_ops_dispatcher(rng):
    """The public ops API routes impls and agrees with refs."""
    x = jnp.asarray(rng.randn(4096), "float32")
    y = jnp.asarray(rng.randn(4096), "float32")
    for impl in ("xla", "dpia-jnp"):
        np.testing.assert_allclose(np.asarray(ops.dot(x, y, impl=impl)),
                                   np.asarray(ref.dot(x, y)), rtol=1e-3)
        np.testing.assert_allclose(np.asarray(ops.asum(x, impl=impl)),
                                   np.asarray(ref.asum(x)), rtol=1e-3)
