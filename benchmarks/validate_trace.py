"""Schema validation for the observability artefacts CI uploads.

Checks (stdlib only, no jsonschema dependency):

  * a trace file is Chrome/Perfetto trace-event JSON — a ``traceEvents``
    list whose every event has a string ``name``, a known phase (``X``
    complete events carry numeric ``ts``/``dur``; ``i`` instants carry
    ``ts`` and scope ``s``), and integer ``pid``/``tid``;
  * a metrics file is a ``{name: snapshot}`` dict whose every snapshot has
    a known ``type`` with that type's required fields;
  * a BENCH_serve.json carries its embedded ``metrics`` snapshot with the
    benchmark's reported gauges present.

Usage:
  python benchmarks/validate_trace.py --trace trace.json \
      [--metrics metrics.json] [--bench BENCH_serve.json]

Exits non-zero with a message naming the first offending record, so a CI
failure points at the event, not just the file.
"""
from __future__ import annotations

import argparse
import json
import sys

_PHASES = {"X", "i", "B", "E", "M"}
_METRIC_FIELDS = {
    "counter": ("value",),
    "gauge": ("value",),
    "histogram": ("count", "total", "mean", "buckets"),
}


def fail(msg: str) -> None:
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def validate_trace(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: not a trace-event document (no 'traceEvents')")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(f"{path}: 'traceEvents' must be a non-empty list")
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: not an object")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            fail(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if ph not in _PHASES:
            fail(f"{where} ({ev['name']!r}): unknown phase {ph!r}")
        if ph in ("X", "i"):
            if not isinstance(ev.get("ts"), (int, float)):
                fail(f"{where} ({ev['name']!r}): non-numeric 'ts'")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                fail(f"{where} ({ev['name']!r}): bad 'dur'")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            fail(f"{where} ({ev['name']!r}): instant scope {ev.get('s')!r}")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                fail(f"{where} ({ev['name']!r}): non-integer {k!r}")
    return len(events)


def validate_metrics(snap: dict, where: str) -> int:
    if not isinstance(snap, dict) or not snap:
        fail(f"{where}: metrics snapshot must be a non-empty dict")
    for name, m in snap.items():
        if not isinstance(m, dict):
            fail(f"{where}: metric {name!r} is not an object")
        t = m.get("type")
        if t not in _METRIC_FIELDS:
            fail(f"{where}: metric {name!r} has unknown type {t!r}")
        for field in _METRIC_FIELDS[t]:
            if field not in m:
                fail(f"{where}: {t} {name!r} missing field {field!r}")
    return len(snap)


def validate_bench(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if "metrics" not in doc:
        fail(f"{path}: no embedded 'metrics' snapshot")
    n = validate_metrics(doc["metrics"], f"{path}[metrics]")
    for gauge in ("bench.fused.tok_s", "bench.continuous.tok_s",
                  "bench.prefill.latency_ms"):
        if gauge not in doc["metrics"]:
            fail(f"{path}: reported gauge {gauge!r} absent from metrics")
    return n


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--bench", default=None)
    args = ap.parse_args()
    if not (args.trace or args.metrics or args.bench):
        fail("nothing to validate: pass --trace/--metrics/--bench")
    if args.trace:
        n = validate_trace(args.trace)
        print(f"validate_trace: {args.trace}: {n} events OK")
    if args.metrics:
        with open(args.metrics) as f:
            n = validate_metrics(json.load(f), args.metrics)
        print(f"validate_trace: {args.metrics}: {n} metrics OK")
    if args.bench:
        n = validate_bench(args.bench)
        print(f"validate_trace: {args.bench}: embedded metrics "
              f"({n}) OK")


if __name__ == "__main__":
    main()
