"""stablelm-1.6b [dense] — 24L d=2048 32H (kv=32) ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352)

def smoke_config() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=256,
                               dtype="float32", max_seq=64)
