"""Serving: jitted prefill/decode steps with KV-cache sharding + a simple
continuous-batching engine (the 'serve a small model with batched requests'
driver used by examples/serve_lm.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

from repro.models.transformer import Model
from repro.sharding import rules


def make_serve_fns(model: Model, mesh: Optional[Mesh] = None):
    """Returns (prefill_fn, decode_fn), jitted; sharded when mesh given."""
    cfg = model.cfg

    def prefill(params, tokens, cache):
        return model.prefill(params, tokens, cache)

    def decode(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos)
        return logits, cache

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode)

    return jax.jit(prefill), jax.jit(decode)


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray          # (s,) or (s, K)
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class BatchedEngine:
    """Static-batch serving engine: prefill a batch of requests, then decode
    lock-step until every request finishes (max_new_tokens).

    ``tuning_cache`` (a path or repro.autotune.TuningCache) pre-tunes the
    strategy autotuner for this model's kernel shapes (prefill and decode,
    for ``batch_sizes``) at engine build time, and ``run`` scopes the
    ``repro.kernels.ops`` DPIA dispatch to that cache via
    ``repro.compiler.options(tuning_cache=...)`` — thread-local, per-engine,
    so concurrent engines with different caches no longer race on a process
    global.  A tuner disabled via ``REPRO_AUTOTUNE=0`` or the enclosing
    options scope stays disabled.  Shapes outside the warmed set cost one
    cheap analytic ranking pass on first sight; the warmed params are kept
    in ``self.tuned``."""

    def __init__(self, model: Model, params, max_seq: int = 512,
                 tuning_cache=None, batch_sizes=(1, 8)):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.tuning_cache = tuning_cache
        self.tuned: Dict[str, dict] = {}
        if tuning_cache is not None:
            from repro import autotune
            self.tuned = autotune.warm_for_model(
                model.cfg, max_seq=max_seq, cache=tuning_cache,
                batch_sizes=batch_sizes)
        self.prefill_fn, self.decode_fn = make_serve_fns(model)

    def _options_scope(self):
        """The compile-options scope this engine's kernels run under."""
        from repro import compiler
        if self.tuning_cache is None:
            return contextlib.nullcontext()
        return compiler.options(tuning_cache=self.tuning_cache)

    def run(self, requests: List[Request], key=None) -> List[List[int]]:
        with self._options_scope():
            return self._run(requests, key)

    def _run(self, requests: List[Request], key=None) -> List[List[int]]:
        cfg = self.model.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        b = len(requests)
        s = max(int(r.prompt.shape[0]) for r in requests)
        # left-pad prompts to a common length with token 0
        def pad(p):
            pad_n = s - p.shape[0]
            return jnp.pad(p, [(pad_n, 0)] + [(0, 0)] * (p.ndim - 1))
        tokens = jnp.stack([pad(r.prompt) for r in requests])
        cache = self.model.init_cache(b, self.max_seq)
        logits, cache = self.prefill_fn(self.params, tokens, cache)

        max_new = max(r.max_new_tokens for r in requests)
        outs = [[] for _ in requests]
        pos = s
        token = None
        for step in range(max_new):
            key, sub = jax.random.split(key)
            temp = requests[0].temperature
            nxt = sample(logits, sub, temperature=temp)        # (b,)
            for i, r in enumerate(requests):
                if step < r.max_new_tokens:
                    outs[i].append(int(nxt[i]))
            tok = nxt[:, None]
            if cfg.n_codebooks:
                tok = jnp.broadcast_to(tok[..., None],
                                       (b, 1, cfg.n_codebooks))
            logits, cache = self.decode_fn(self.params, tok, cache,
                                           jnp.int32(pos))
            pos += 1
        return outs
