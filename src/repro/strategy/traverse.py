"""HOAS-aware traversal combinators over the DPIA phrase tree.

The functional phrase nodes are frozen dataclasses whose children are
either plain sub-phrases (``BinOp.a``) or *binders* — Python callables
receiving ``Var`` nodes (``Map.f``, ``Reduce.f``).  A slot table maps each
node type to its children in declared field order, so traversal strategies
can

  * descend into plain children by field name, and
  * descend *under* a binder by probing it with a fresh typed ``Var``
    (deciding success and recording the trace on the probe body), then
    rebuilding the binder as a closure that re-applies the same pure
    strategy at every later instantiation.

Paths in traces are tuples of slot names from the root (``("e", "f")`` =
"inside the ``e`` child, under its ``f`` binder"), which is what makes a
trace replayable with :func:`at` / :func:`replay`.

``fingerprint`` is the structural identity the subsystem standardises on:
binders are instantiated with canonical depth-indexed names so two
independently built phrases compare equal iff they are the same term —
``repr``/``pretty.show`` cannot serve here because ``phrases.fresh()``
draws from a process-global counter.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

from repro.core.dpia import phrases as P
from repro.core.dpia.types import Arr, ExpT

from . import lang
from .lang import (Result, Strategy, StrategyTrace, failure, rule, success)

__all__ = ["Slot", "slots_of", "fingerprint", "one", "all_", "topdown",
           "bottomup", "at", "replay"]


# ---------------------------------------------------------------------------
# the slot table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Slot:
    """One child position of a phrase node.

    ``kind`` is "phrase" (a plain sub-phrase field) or "binder" (a HOAS
    callable field); for binders ``arg_types(node)`` yields the PhraseTypes
    of the fresh Vars to probe with."""
    name: str
    kind: str
    arg_types: Callable[[P.Phrase], Tuple] = None


def _elem_of(p: P.Phrase):
    d = P.exp_data(p)
    if not isinstance(d, Arr):
        raise TypeError(f"binder input is not an array: {d}")
    return d.elem


def _map_args(m: P.Map) -> Tuple:
    return (ExpT(_elem_of(m.e)),)


def _reduce_args(r: P.Reduce) -> Tuple:
    return (ExpT(_elem_of(r.e)), ExpT(P.exp_data(r.init)))


def _ph(name: str) -> Slot:
    return Slot(name, "phrase")


_SLOTS = {
    P.UnOp: [_ph("e")],
    P.BinOp: [_ph("a"), _ph("b")],
    P.Map: [Slot("f", "binder", _map_args), _ph("e")],
    P.Reduce: [Slot("f", "binder", _reduce_args), _ph("init"), _ph("e")],
    P.Zip: [_ph("a"), _ph("b")],
    P.Split: [_ph("e")],
    P.Join: [_ph("e")],
    P.PairE: [_ph("a"), _ph("b")],
    P.Fst: [_ph("e")],
    P.Snd: [_ph("e")],
    P.IdxE: [_ph("e"), _ph("i")],
    P.AsVector: [_ph("e")],
    P.AsScalar: [_ph("e")],
    P.Transpose: [_ph("e")],
    P.DotBlock: [_ph("a"), _ph("b")],
    P.FullReduce: [_ph("e")],
    P.ToMem: [_ph("e")],
}


def slots_of(p: P.Phrase) -> List[Slot]:
    """The traversable children of ``p`` (empty for leaves: Var, Lit, and
    every imperative node — strategies rewrite functional terms only)."""
    return _SLOTS.get(type(p), [])


# ---------------------------------------------------------------------------
# structural fingerprint
# ---------------------------------------------------------------------------

def _head(p: P.Phrase) -> str:
    """Node head: type name + every scalar (non-phrase, non-binder) field."""
    vals = []
    for f in dataclasses.fields(p):
        v = getattr(p, f.name)
        if isinstance(v, P.Phrase) or callable(v):
            continue
        vals.append(f"{f.name}={v!r}")
    return f"{type(p).__name__}({','.join(vals)})"


def fingerprint(p: P.Phrase) -> str:
    """Canonical structural string: equal iff the phrases are the same term.

    Binders are instantiated with depth-indexed ``_fp<i>`` names, so the
    fingerprint is stable across processes and across builder call sites
    (unlike reprs, which embed the global fresh-variable counter)."""
    parts: List[str] = []
    counter = [0]

    def go(q: P.Phrase) -> None:
        if isinstance(q, P.Var):
            parts.append(f"Var({q.name}:{q.t})")
            return
        parts.append(_head(q))
        for slot in slots_of(q):
            parts.append(f"<{slot.name}")
            if slot.kind == "phrase":
                go(getattr(q, slot.name))
            else:
                fvs = []
                for t in slot.arg_types(q):
                    fvs.append(P.Var(f"_fp{counter[0]}", t))
                    counter[0] += 1
                go(getattr(q, slot.name)(*fvs))
            parts.append(">")

    go(p)
    return "".join(parts)


# ---------------------------------------------------------------------------
# traversal strategies
# ---------------------------------------------------------------------------

def _descend(s: Strategy, p: P.Phrase, slot: Slot,
             path: Tuple[str, ...]) -> Result:
    """Apply ``s`` to one child slot of ``p``; rebuild ``p`` on success."""
    sub_path = tuple(path) + (slot.name,)
    if slot.kind == "phrase":
        res = s.apply(getattr(p, slot.name), sub_path)
        if not res.ok:
            return res
        return success(dataclasses.replace(p, **{slot.name: res.phrase}),
                       res.trace)
    # binder: probe with fresh typed Vars to decide success + trace, then
    # rebuild the closure to re-apply the (pure) strategy per instantiation
    try:
        arg_ts = slot.arg_types(p)
    except Exception as e:  # untyped/odd input: this slot just fails
        return failure(f"binder {slot.name}: {e}")
    f = getattr(p, slot.name)
    probes = [P.Var(P.fresh("_probe"), t) for t in arg_ts]
    try:
        body = f(*probes)
    except Exception as e:
        return failure(f"binder {slot.name}: {e}")
    res = s.apply(body, sub_path)
    if not res.ok:
        return res

    def new_f(*args, _f=f, _s=s):
        r2 = _s.apply(_f(*args))
        if not r2.ok:  # pure strategies succeed identically on every probe
            raise RuntimeError(
                f"strategy {_s.name} succeeded on the binder probe but "
                f"failed on re-instantiation: {r2.reason}")
        return r2.phrase

    return success(dataclasses.replace(p, **{slot.name: new_f}), res.trace)


class _One(Strategy):
    """Apply ``s`` to the first child (declared slot order) where it
    succeeds; fail if no child admits it."""

    def __init__(self, s: Strategy):
        self.s = s
        self.name = f"one({s.name})"

    def apply(self, phrase, path=()):
        reasons = []
        for slot in slots_of(phrase):
            res = _descend(self.s, phrase, slot, path)
            if res.ok:
                return res
            reasons.append(f"{slot.name}: {res.reason}")
        return failure(f"one: no child of {type(phrase).__name__} matched"
                       + (f" ({'; '.join(reasons)})" if reasons else ""))


class _All(Strategy):
    """Apply ``s`` to every child; all must succeed.  Vacuously succeeds on
    leaves (the standard ELEVATE semantics that makes ``topdown`` total)."""

    def __init__(self, s: Strategy):
        self.s = s
        self.name = f"all({s.name})"

    def apply(self, phrase, path=()):
        cur = phrase
        steps = StrategyTrace()
        for slot in slots_of(phrase):
            res = _descend(self.s, cur, slot, path)
            if not res.ok:
                return failure(f"all: child {slot.name}: {res.reason}")
            cur, steps = res.phrase, steps + res.trace
        return success(cur, steps)


def one(s: Strategy) -> Strategy:
    return _One(s)


def all_(s: Strategy) -> Strategy:
    return _All(s)


class _TopDown(Strategy):
    """``topdown(s) = alt(s, one(topdown(s)))`` — outermost-first."""

    def __init__(self, s: Strategy):
        self.s = s
        self.name = f"topdown({s.name})"

    def apply(self, phrase, path=()):
        res = self.s.apply(phrase, path)
        if res.ok:
            return res
        return one(self).apply(phrase, path)


class _BottomUp(Strategy):
    """``bottomup(s) = alt(one(bottomup(s)), s)`` — innermost-first."""

    def __init__(self, s: Strategy):
        self.s = s
        self.name = f"bottomup({s.name})"

    def apply(self, phrase, path=()):
        res = one(self).apply(phrase, path)
        if res.ok:
            return res
        return self.s.apply(phrase, path)


def topdown(s: Strategy) -> Strategy:
    """Apply ``s`` at the outermost position where it succeeds."""
    return _TopDown(s)


def bottomup(s: Strategy) -> Strategy:
    """Apply ``s`` at the innermost position where it succeeds."""
    return _BottomUp(s)


class _At(Strategy):
    """Apply ``s`` exactly at ``path`` (slot names from the root)."""

    def __init__(self, path: Sequence[str], s: Strategy):
        self.path = tuple(path)
        self.s = s
        self.name = f"at({'/'.join(self.path) or '.'},{s.name})"

    def apply(self, phrase, path=()):
        return self._go(phrase, self.path, tuple(path))

    def _go(self, p, rel, abs_path):
        if not rel:
            return self.s.apply(p, abs_path)
        head, rest = rel[0], rel[1:]
        for slot in slots_of(p):
            if slot.name == head:
                inner = _At(rest, self.s)
                # reuse the rebuild machinery with the inner navigation as
                # the strategy for this slot
                return _descend(inner, p, slot, abs_path)
        return failure(f"at: {type(p).__name__} has no slot {head!r}")


def at(path: Sequence[str], s: Strategy) -> Strategy:
    return _At(path, s)


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

def replay(trace, phrase: P.Phrase) -> Result:
    """Re-run a serialised :class:`StrategyTrace` on ``phrase``.

    Each step becomes ``at(step.path, rule(step.rule, **step.params))``
    applied in order — a mined or cached derivation replays with no search.
    Returns a normal :class:`Result`; unknown rules or bad params are a
    failure value like any other."""
    try:
        tr = StrategyTrace.from_doc(trace)
        prog = lang.seq(*[at(s.path, rule(s.rule, **s.params))
                          for s in tr.steps])
    except (KeyError, TypeError, ValueError) as e:
        return failure(f"replay: malformed trace: {e}")
    return prog.apply(phrase)
