"""Serving fast-path benchmark: prefill latency, decode tokens/s, host-sync
and recompile accounting — the numbers behind the decode-hot-path rebuild.

Compares three drivers over the same dense LM and request mix:

  legacy      — faithful replica of the pre-PR ``BatchedEngine`` loop: one
                jitted decode step per token, sampling on the host, one
                device->host sync per token (``int(tok)``), whole batch at
                ``requests[0].temperature``;
  fused       — ``BatchedEngine``: jitted ``lax.scan`` decode chunks with
                per-request sampling fused in, donated cache/buffers, one
                host sync per chunk;
  continuous  — ``ContinuousEngine``: the same fused chunks behind the
                continuous-batching scheduler (fixed slots, bucketed
                prefill).

Also measures recompiles: after one warm pass over the bucketed shape set,
further traffic must hit the jit caches exactly (asserted unless
``--no-assert``), the fused engines must beat legacy decode throughput by
>= 2x on CPU, and fused prefill (through the engine's per-bucket AOT
executables) must not regress vs the legacy jitted prefill — the two are
timed INTERLEAVED so host drift cancels out of the ratio.

``--long-prompt`` adds the paged-KV section: a long-prompt/many-slot mix
served by ``kv_layout="dense"`` vs ``kv_layout="paged"`` + chunked prefill,
reporting peak resident KV bytes, tokens/s, and recompile counts — the
paged pool must hold >= 2x fewer bytes at equal (+-10%) throughput.

Usage:
  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--long-prompt]
      [--out FILE] [--trace FILE] [--metrics-out FILE]

Writes BENCH_serve.json (``--out`` to override; includes a metrics-registry
snapshot under ``"metrics"``) and prints a summary.  ``--trace`` enables
``repro.obs`` span tracing and exports a Chrome/Perfetto trace-event JSON.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# the pre-PR engine, replicated for an honest baseline
# ---------------------------------------------------------------------------

class LegacyBatchedEngine:
    """The seed's static-batch loop: per-token dispatch + per-token host
    sync + single-temperature sampling (including its ``requests[0]``
    temperature bug, kept verbatim — this is the measured baseline, not an
    endorsement)."""

    def __init__(self, model, params, max_seq: int = 512):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.prefill_fn = jax.jit(
            lambda p, t, c: model.prefill(p, t, c))
        self.decode_fn = jax.jit(
            lambda p, tok, c, pos: model.decode_step(p, tok, c, pos))

    def run(self, requests, key=None) -> List[List[int]]:
        from repro.serve.engine import sample
        cfg = self.model.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        b = len(requests)
        s = max(int(r.prompt.shape[0]) for r in requests)

        def pad(p):
            pad_n = s - p.shape[0]
            return jnp.pad(p, [(pad_n, 0)] + [(0, 0)] * (p.ndim - 1))
        tokens = jnp.stack([pad(r.prompt) for r in requests])
        cache = self.model.init_cache(b, self.max_seq)
        logits, cache = self.prefill_fn(self.params, tokens, cache)

        max_new = max(r.max_new_tokens for r in requests)
        outs = [[] for _ in requests]
        pos = s
        for step in range(max_new):
            key, sub = jax.random.split(key)
            temp = requests[0].temperature
            nxt = sample(logits, sub, temperature=temp)
            for i, r in enumerate(requests):
                if step < r.max_new_tokens:
                    outs[i].append(int(nxt[i]))          # per-token sync
            tok = nxt[:, None]
            if cfg.n_codebooks:
                tok = jnp.broadcast_to(tok[..., None],
                                       (b, 1, cfg.n_codebooks))
            logits, cache = self.decode_fn(self.params, tok, cache,
                                           jnp.int32(pos))
            pos += 1
        return outs


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def _mk_model(full: bool):
    from repro.models.common import ModelConfig
    from repro.models.transformer import Model
    if full:
        # compute-heavier model with a serving-sized KV cache (~32 MB),
        # where the legacy loop's per-step undonated cache copy is the
        # dominating cost the donated fused chunk removes
        cfg = ModelConfig(name="serve-bench-full", family="dense", n_layers=4,
                          d_model=256, n_heads=8, n_kv_heads=2, d_ff=768,
                          vocab=1024, dtype="float32", remat=False,
                          max_seq=1024)
    else:
        # the default config is deliberately overhead-dominated: the decode
        # harness (dispatch, host syncs, cache copies) is what this
        # benchmark measures; kernel-level compute has its own benchmarks
        cfg = ModelConfig(name="serve-bench", family="dense",
                          n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab=512, dtype="float32", remat=False,
                          max_seq=128)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n: int, prompt_len: int, max_new: int):
    from repro.serve.engine import Request
    key = jax.random.PRNGKey(42)
    return [Request(
        prompt=jax.random.randint(jax.random.fold_in(key, i),
                                  (prompt_len + 2 * (i % 3),), 0, cfg.vocab),
        max_new_tokens=max_new, temperature=0.0) for i in range(n)]


def timed(thunks: dict, repeats: int = 4) -> dict:
    """Interleaved best-of-N wall timing: ``{label: thunk}`` ->
    ``{label: (best_seconds, last_result)}``.

    The ONE timing loop of this benchmark (prefill, decode, and the paged
    section all go through it).  Labels are measured interleaved
    (a, b, a, b, ... repeated) and best-of-N per label, so slow drift in
    background load on a shared host cancels out of cross-label ratios
    instead of biasing whichever label ran last.  Every invocation runs
    under an ``obs.span`` and each label's best time lands in the metrics
    registry (``bench.<label>.best_s``), so the numbers BENCH_serve.json
    reports and the numbers in the exported metrics/trace are the same
    measurements."""
    from repro import obs
    best = {k: float("inf") for k in thunks}
    result = {k: None for k in thunks}
    for rep in range(repeats):
        for k, fn in thunks.items():
            with obs.span(f"bench.{k}", rep=rep):
                t0 = time.perf_counter()
                result[k] = fn()
                dt = time.perf_counter() - t0
            best[k] = min(best[k], dt)
    for k, v in best.items():
        obs.gauge(f"bench.{k}.best_s").set(v)
    return {k: (best[k], result[k]) for k in thunks}


def _timed_runs(engines, reqs, key, repeats: int = 4, labels=None) -> list:
    """Per engine: (tokens, best wall time) via :func:`timed`."""
    labels = labels or [f"engine{i}" for i in range(len(engines))]
    thunks = {lb: (lambda e=e: e.run(reqs, key=key))
              for lb, e in zip(labels, engines)}
    res = timed(thunks, repeats=repeats)
    return [(sum(len(o) for o in res[lb][1]), res[lb][0]) for lb in labels]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short runs (CI): fewer tokens/repeats")
    ap.add_argument("--long-prompt", action="store_true",
                    help="add the paged-KV section: long-prompt/many-slot "
                         "mix, dense vs paged layouts")
    ap.add_argument("--full", action="store_true",
                    help="compute-heavier model (reports speedup without "
                         "asserting it — it is hardware-dependent there)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="enable span tracing and export a Chrome/Perfetto "
                         "trace-event JSON to FILE")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="also export the metrics registry snapshot as "
                         "JSON to FILE")
    ap.add_argument("--no-assert", action="store_true",
                    help="report only; do not enforce speedup/recompiles")
    args = ap.parse_args()

    from repro import compiler, obs
    from repro.serve.engine import BatchedEngine, ContinuousEngine, Request

    if args.trace:
        obs.enable()

    cfg, model, params = _mk_model(args.full)
    max_new = 32 if args.smoke else 64
    batch = 4
    chunk = 8
    max_seq = cfg.max_seq
    reqs = _mk_requests(cfg, batch, 16, max_new)
    key = jax.random.PRNGKey(7)

    print(f"# serve_bench: {cfg.name} (layers={cfg.n_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab}) batch={batch} "
          f"max_new={max_new} chunk={chunk}")

    # -- prefill latency: engine AOT executable vs legacy jit, interleaved ----
    lengths = [int(r.prompt.shape[0]) for r in reqs]
    s = max(lengths)
    fused = BatchedEngine(model, params, max_seq=max_seq, chunk=chunk)
    legacy = LegacyBatchedEngine(model, params, max_seq=max_seq)
    toks = jnp.stack([fused._pad_prompt(r.prompt, s) for r in reqs])
    larr = jnp.asarray(lengths, jnp.int32)
    cache0 = model.init_cache(batch, max_seq)  # never donated: reusable

    # the engine's admission path: one lowered+compiled executable per
    # padded-bucket shape, called directly (no per-call jit dispatch), the
    # zero cache built inside the program (no input-cache copy)
    prefill_fns = {
        "fused": lambda: fused._prefill_call(toks, larr),
        "legacy": lambda: legacy.prefill_fn(params, toks, cache0),
    }
    for fn in prefill_fns.values():
        jax.block_until_ready(fn()[0])        # warm/compile

    # noise-free comparison first: XLA's own cost analysis of the two
    # compiled programs — the regression fix must hold at the PROGRAM
    # level (equal flops, fewer bytes: no input-cache copy), independent
    # of wall-clock noise on a loaded host
    def _xla_cost(exe):
        ca = exe.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)))
    fused_exe = fused._prefill_exes[(toks.shape, str(toks.dtype))]
    legacy_exe = legacy.prefill_fn.lower(params, toks, cache0).compile()
    pf_flops, pf_bytes = _xla_cost(fused_exe)
    pl_flops, pl_bytes = _xla_cost(legacy_exe)

    reps = 11 if args.smoke else 21
    prefill_s = prefill_legacy_s = 1.0
    best_ratio = float("inf")
    prefill_thunks = {
        f"prefill_{k}": (lambda fn=fn: jax.block_until_ready(fn()[0]))
        for k, fn in prefill_fns.items()}
    for _attempt in range(3):                 # ride out host load spikes
        res = timed(prefill_thunks, repeats=reps)
        best = {k: res[f"prefill_{k}"][0] for k in prefill_fns}
        if best["fused"] / best["legacy"] < best_ratio:
            best_ratio = best["fused"] / best["legacy"]
            prefill_s, prefill_legacy_s = best["fused"], best["legacy"]
        if prefill_s <= prefill_legacy_s:
            break
    print(f"  prefill     {prefill_s * 1e3:9.2f} ms  (batch={batch}, "
          f"seq={s}; legacy {prefill_legacy_s * 1e3:.2f} ms, wall ratio "
          f"{prefill_s / prefill_legacy_s:.2f}, bytes ratio "
          f"{pf_bytes / max(pl_bytes, 1.0):.3f})")

    # -- decode throughput: run time minus the engine's own prefill ----------
    legacy.run(reqs, key=key)                      # warm/compile
    t0 = time.perf_counter()
    fused.run(reqs, key=key)                       # warm/compile
    t_warm = time.perf_counter() - t0
    (n_leg, t_leg_e2e), (n_fus, t_fus) = _timed_runs(
        [legacy, fused], reqs, key, labels=["legacy", "fused"])
    t_leg = max(t_leg_e2e - prefill_legacy_s, 1e-9)
    t_fus = max(t_fus - prefill_s, 1e-9)
    print(f"  legacy      {n_leg / t_leg:9.1f} tok/s   "
          f"({n_leg} tokens, {t_leg:.2f}s decode, 1 host sync/token)")
    print(f"  fused       {n_fus / t_fus:9.1f} tok/s   "
          f"({n_fus} tokens, {t_fus:.2f}s decode, 1 host sync/chunk "
          f"of {chunk})")

    # -- continuous batching + recompile accounting ---------------------------
    cont = ContinuousEngine(model, params, max_seq=max_seq, slots=batch,
                            chunk=chunk)
    # warm pass over the bucketed shape set: every prompt bucket once
    warm_reqs = []
    for b in cont.buckets:
        if b + max_new <= max_seq:
            warm_reqs += _mk_requests(cfg, 1, min(b, b - 2) or 1, max_new)
    cont.run(warm_reqs or reqs, key=key)
    compiles_warm = cont.decode_cache_misses()
    prefill_compiles_warm = cont.prefill_cache_size()

    [(n_cont, t_cont)] = _timed_runs([cont], reqs, key,
                                     labels=["continuous"])
    compiles_after = cont.decode_cache_misses()
    prefill_compiles_after = cont.prefill_cache_size()
    recompiles = (compiles_after - compiles_warm) + (
        prefill_compiles_after - prefill_compiles_warm)
    # continuous run time includes its per-admission prefills, so its rate
    # is END-TO-END — compared against legacy end-to-end, not decode-only
    print(f"  continuous  {n_cont / t_cont:9.1f} tok/s   "
          f"({n_cont} tokens, {t_cont:.2f}s end-to-end, slots={batch})")
    print(f"  recompiles after warm-up: {recompiles} "
          f"(decode {compiles_after - compiles_warm}, "
          f"prefill {prefill_compiles_after - prefill_compiles_warm})")

    speedup = (n_fus / t_fus) / (n_leg / t_leg)
    speedup_cont = (n_cont / t_cont) / (n_leg / t_leg_e2e)
    print(f"  fused/legacy decode speedup          {speedup:6.2f}x")
    print(f"  continuous/legacy end-to-end speedup {speedup_cont:6.2f}x")

    # -- paged KV + chunked prefill: the long-prompt/many-slot mix ------------
    long_doc = None
    if args.long_prompt:
        from repro.models.common import ModelConfig
        from repro.models.transformer import Model
        from repro.serve import paged as paged_mod

        # a serving-shaped GQA config (many q heads, ONE kv head — the
        # llama/mistral serving regime): KV traffic is the realistic small
        # share of step cost, so the paged gather prices in honestly while
        # the resident-bytes claim is exercised at real prompt lengths
        lp_cfg = ModelConfig(name="serve-bench-long", family="dense",
                             n_layers=2, d_model=256, n_heads=8,
                             n_kv_heads=1, d_ff=1024, vocab=512,
                             dtype="float32", remat=False, max_seq=256)
        lp_model = Model(lp_cfg)
        lp_params = lp_model.init_params(jax.random.PRNGKey(1))

        lp_seq = 256
        lp_slots = 8
        lp_new = 8 if args.smoke else 16
        lp_chunk = 8
        lp_block = 16
        lp_prefill_chunk = 64
        lens = [224, 24, 40, 176, 16, 120, 64, 32]
        waves = 1 if args.smoke else 2
        lp_key = jax.random.PRNGKey(11)
        lp_reqs = [Request(
            prompt=jax.random.randint(jax.random.fold_in(lp_key, i),
                                      (lens[i % len(lens)],), 0,
                                      lp_cfg.vocab),
            max_new_tokens=lp_new, temperature=0.0)
            for i in range(waves * len(lens))]

        # pool sized for the dominant FIFO admission window of the mix
        # (lp_slots consecutive requests' spans).  Long-lived requests can
        # transiently coexist with a LATER window and defer an admission
        # by a boundary or two — that residual cost is part of what the
        # +-10% throughput assertion below prices.  The saving is the
        # point: dense pays slots * max_seq regardless of traffic
        need = [paged_mod.blocks_for(n + lp_new, lp_block) for n in
                (lens * waves)]
        window = max(sum(need[i:i + lp_slots])
                     for i in range(max(1, len(need) - lp_slots + 1)))
        dense_eng = ContinuousEngine(lp_model, lp_params, max_seq=lp_seq,
                                     slots=lp_slots, chunk=lp_chunk,
                                     prefill_chunk=lp_prefill_chunk)
        paged_eng = ContinuousEngine(lp_model, lp_params, max_seq=lp_seq,
                                     slots=lp_slots, chunk=lp_chunk,
                                     kv_layout="paged", block_size=lp_block,
                                     kv_blocks=window,
                                     prefill_chunk=lp_prefill_chunk)
        dense_bytes = paged_mod.dense_kv_bytes(lp_cfg, lp_slots, lp_seq)
        paged_bytes = paged_mod.paged_kv_bytes(lp_cfg, window, lp_block)
        mem_ratio = dense_bytes / max(paged_bytes, 1)

        for eng in (dense_eng, paged_eng):     # warm the shape set
            eng.run(lp_reqs, key=lp_key)
        d_decode0, p_decode0 = (dense_eng.decode_cache_misses(),
                                paged_eng.decode_cache_misses())
        d_pf0, p_pf0 = (dense_eng.prefill_cache_size(),
                        paged_eng.prefill_cache_size())
        tok_ratio = 0.0
        for _attempt in range(3):             # ride out host load spikes
            (a_n_d, a_t_d), (a_n_p, a_t_p) = _timed_runs(
                [dense_eng, paged_eng], lp_reqs, lp_key,
                repeats=2 if args.smoke else 4,
                labels=["dense", "paged"])
            r = (a_n_p / a_t_p) / (a_n_d / a_t_d)
            if r > tok_ratio:                 # keep the whole attempt's
                tok_ratio = r                 # numbers, so the committed
                n_d, t_d, n_p, t_p = a_n_d, a_t_d, a_n_p, a_t_p
            if tok_ratio >= 1.0:              # tok/s and ratio agree
                break
        lp_recompiles = (
            (dense_eng.decode_cache_misses() - d_decode0)
            + (paged_eng.decode_cache_misses() - p_decode0)
            + (dense_eng.prefill_cache_size() - d_pf0)
            + (paged_eng.prefill_cache_size() - p_pf0))
        print(f"  long-prompt mix: {len(lp_reqs)} reqs, prompts "
              f"{min(lens)}..{max(lens)}, slots={lp_slots}, "
              f"max_seq={lp_seq}, prefill_chunk={lp_prefill_chunk} "
              f"(buckets {dense_eng.buckets})")
        print(f"    dense  {n_d / t_d:9.1f} tok/s   peak KV "
              f"{dense_bytes / 1e6:7.2f} MB ({lp_slots}x{lp_seq} dense)")
        print(f"    paged  {n_p / t_p:9.1f} tok/s   peak KV "
              f"{paged_bytes / 1e6:7.2f} MB ({window} blocks of "
              f"{lp_block}) -> {mem_ratio:.2f}x smaller")
        print(f"    paged/dense tok/s ratio {tok_ratio:.2f}, recompiles "
              f"after warm-up {lp_recompiles}")
        long_doc = {
            "model": {"name": lp_cfg.name, "n_layers": lp_cfg.n_layers,
                      "d_model": lp_cfg.d_model, "n_heads": lp_cfg.n_heads,
                      "n_kv_heads": lp_cfg.n_kv_heads,
                      "d_ff": lp_cfg.d_ff},
            "slots": lp_slots, "max_seq": lp_seq, "max_new": lp_new,
            "prompt_lens": lens, "waves": waves, "block_size": lp_block,
            "kv_blocks": window, "prefill_chunk": lp_prefill_chunk,
            "buckets": list(dense_eng.buckets),
            "dense_kv_bytes": dense_bytes, "paged_kv_bytes": paged_bytes,
            "kv_bytes_ratio": mem_ratio,
            "dense_tok_s": n_d / t_d, "paged_tok_s": n_p / t_p,
            "tok_s_ratio": tok_ratio,
            "recompiles_after_warmup": lp_recompiles,
        }

    doc = {
        "config": {"name": cfg.name, "n_layers": cfg.n_layers,
                   "d_model": cfg.d_model, "vocab": cfg.vocab,
                   "batch": batch, "max_new": max_new, "chunk": chunk,
                   "smoke": bool(args.smoke), "full": bool(args.full)},
        "prefill": {"latency_ms": prefill_s * 1e3,
                    "legacy_latency_ms": prefill_legacy_s * 1e3,
                    "fused_flops": pf_flops, "legacy_flops": pl_flops,
                    "fused_bytes": pf_bytes, "legacy_bytes": pl_bytes,
                    "batch": batch, "seq": s},
        "decode": {
            "legacy_tok_s": n_leg / t_leg,
            "fused_tok_s": n_fus / t_fus,
            "legacy_tok_s_end_to_end": n_leg / t_leg_e2e,
            "continuous_tok_s_end_to_end": n_cont / t_cont,
            "speedup_fused_vs_legacy": speedup,
            "speedup_continuous_vs_legacy_end_to_end": speedup_cont,
            "fused_warmup_s": t_warm,
        },
        "sync": {"legacy_host_syncs_per_token": 1,
                 "fused_host_syncs_per_step_in_chunk": 0,
                 "fused_host_syncs_per_chunk": 1, "chunk": chunk},
        "recompiles": {
            "decode_compiles_warm": compiles_warm,
            "decode_recompiles_after_warmup": compiles_after - compiles_warm,
            "prefill_recompiles_after_warmup":
                prefill_compiles_after - prefill_compiles_warm,
            "executor_cache": compiler.executor_cache().stats(),
        },
    }
    if long_doc is not None:
        doc["long_prompt"] = long_doc

    # the reported numbers go through the metrics registry too, so the
    # snapshot embedded below (and any --metrics-out export) carries them
    # alongside the serving spine's own counters/histograms
    for name, v in (("bench.prefill.latency_ms", prefill_s * 1e3),
                    ("bench.legacy.tok_s", n_leg / t_leg),
                    ("bench.fused.tok_s", n_fus / t_fus),
                    ("bench.continuous.tok_s", n_cont / t_cont),
                    ("bench.speedup_fused_vs_legacy", speedup),
                    ("bench.recompiles_after_warmup", recompiles)):
        obs.gauge(name).set(v)
    doc["metrics"] = obs.metrics_snapshot()

    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"  wrote {args.out}")
    if args.trace:
        obs.export_trace(args.trace)
        print(f"  wrote {args.trace} ({len(obs.trace_events())} events; "
              f"load in https://ui.perfetto.dev)")
    if args.metrics_out:
        obs.export_metrics(args.metrics_out)
        print(f"  wrote {args.metrics_out}")

    if not args.no_assert:
        assert recompiles == 0, \
            f"{recompiles} recompiles after warm-up (want 0)"
        # the PR 3 prefill regression stays fixed — asserted where it is
        # deterministic: the fused program must not do more work than the
        # legacy one (equal flops, no extra bytes: the input-cache copy is
        # gone), plus a generously-margined wall-clock guard for gross
        # regressions (sub-15% wall deltas are host noise here)
        assert pf_flops <= pl_flops * 1.01 and pf_bytes <= pl_bytes, \
            (f"fused prefill program regressed vs legacy: flops "
             f"{pf_flops:.0f} vs {pl_flops:.0f}, bytes {pf_bytes:.0f} vs "
             f"{pl_bytes:.0f}")
        assert prefill_s <= prefill_legacy_s * 1.15, \
            (f"fused prefill {prefill_s * 1e3:.2f} ms regressed vs legacy "
             f"{prefill_legacy_s * 1e3:.2f} ms")
        if not args.full:
            # the harness-overhead claim; on the --full model the ratio is
            # compute-bound and hardware-dependent, so it is reported only
            assert speedup >= 2.0, \
                f"fused decode {speedup:.2f}x legacy (want >= 2x)"
        if long_doc is not None:
            assert long_doc["kv_bytes_ratio"] >= 2.0, \
                (f"paged peak KV only {long_doc['kv_bytes_ratio']:.2f}x "
                 f"smaller (want >= 2x)")
            assert long_doc["tok_s_ratio"] >= 0.9, \
                (f"paged tok/s {long_doc['tok_s_ratio']:.2f}x dense "
                 f"(want >= 0.9)")
            assert long_doc["recompiles_after_warmup"] == 0, \
                (f"{long_doc['recompiles_after_warmup']} long-prompt "
                 f"recompiles after warm-up (want 0)")
        print("  asserts OK (decode speedup, prefill non-regression, "
              "0 recompiles after warm-up"
              + (", paged memory/throughput" if long_doc else "") + ")")


if __name__ == "__main__":
    main()
