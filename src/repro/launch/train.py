"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires together config -> model -> mesh -> sharded train step -> data pipeline
-> fault-tolerant loop (checkpoint/restart, NaN guard, watchdog).  On this
CPU container use --smoke (reduced config, 1 device); on a real cluster the
same file launches at any mesh size.
"""
from __future__ import annotations

import argparse
import logging
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="mesh data-axis size; 0 = all devices")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
    import numpy as np

    from repro.ckpt.manager import CheckpointManager
    from repro.configs import config, smoke_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.ft.resilience import TrainLoop
    from repro.models.transformer import Model
    from repro.train.step import (make_train_state, make_train_step,
                                  state_specs)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    log = logging.getLogger("repro.train")

    cfg = smoke_config(args.arch) if args.smoke else config(args.arch)
    model = Model(cfg)

    n_dev = len(jax.devices())
    nd = args.data_axis or n_dev
    mesh = Mesh(np.array(jax.devices()[:nd]).reshape(nd, 1),
                ("data", "model"))
    log.info("arch=%s params=%.2fM mesh=%s", cfg.name,
             cfg.param_count() / 1e6 if args.smoke else
             cfg.param_count() / 1e6, dict(mesh.shape))

    key = jax.random.PRNGKey(0)
    state = make_train_state(model, key, use_8bit=cfg.opt_8bit)
    st_spec = state_specs(state, mesh, cfg)
    step_fn, jit_with, batch_spec = make_train_step(
        model, mesh, microbatches=args.microbatches, base_lr=args.lr,
        total_steps=args.steps)
    train_step = jit_with(st_spec)

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        n_codebooks=cfg.n_codebooks))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    t_hist = []

    def on_metrics(step, m):
        t_hist.append(time.time())
        if step % args.log_every == 0:
            dt = (t_hist[-1] - t_hist[-min(len(t_hist), args.log_every)]) / \
                max(min(len(t_hist), args.log_every) - 1, 1)
            log.info("step=%d loss=%.4f gnorm=%.3f lr=%.2e %.0fms/step",
                     step, float(m["loss"]), float(m["grad_norm"]),
                     float(m["lr"]), dt * 1000)

    def wrapped_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return train_step(state, batch)

    loop = TrainLoop(wrapped_step, ckpt, data, ckpt_every=args.ckpt_every)
    state = loop.run(state, num_steps=args.steps, on_metrics=on_metrics)
    log.info("done: %d steps (skipped=%d)", args.steps, loop.skipped_steps)


if __name__ == "__main__":
    main()
