"""Stage III (Pallas backend): grid-level DPIA -> pl.pallas_call kernels.

The TPU re-basing of the paper's OpenCL code generator (section 6):

  * ``parfor[grid(k)]`` nests  ->  Pallas grid dimensions (the paper's
    parforWorkgroup/parforLocal -> get_group_id/get_local_id loops);
  * the SCIR acceptor discipline -> disjoint explicit stores into the output
    ref, with index paths computed exactly as in Fig. 6b;
  * ``new[vmem]``   -> kernel scratch (the paper's hoisted local allocations);
  * ``new[reg]``    -> loop-carried SSA values (TPU: VREG accumulators);
  * ``for``         -> in-kernel ``lax.fori_loop``;
  * non-grid top-level commands -> host-side execution (the paper's host code
    between kernel launches), with HBM temporaries as jnp buffers.

Kernels are emitted for the *target* TPU (pl.pallas_call + grid + scratch)
and validated on CPU with ``interpret=True``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import phrases as P
from . import stage1, stage2
from .interp import interp
from .stage3_jnp import (FST, SND, Store, _reshape_leading, exec_comm,
                         fold_acc, set_path, written_roots)
from .types import (AccT, Arr, DataType, ExpT, Idx, Num, Pair, VarT, Vec,
                    dtype_of, shape_of, zero_value)

try:  # pltpu provides VMEM scratch shapes; interpret mode accepts them on CPU
    from jax.experimental.pallas import tpu as pltpu

    def _scratch(shape, dtype):
        return pltpu.VMEM(shape if shape else (1,), jnp.dtype(dtype))
except Exception:  # pragma: no cover - fallback for older jax
    pltpu = None

    def _scratch(shape, dtype):
        return jax.ShapeDtypeStruct(shape if shape else (1,), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# helpers: data types <-> ref pytrees
# ---------------------------------------------------------------------------

def _leaf_shapes(d: DataType):
    """Pytree of (shape, dtype) mirroring the buffer layout of ``d``."""
    if isinstance(d, (Num, Idx)):
        return ((), dtype_of(d))
    if isinstance(d, Vec):
        return ((d.n,), d.dtype)
    if isinstance(d, Arr):
        inner = _leaf_shapes(d.elem)
        return jax.tree_util.tree_map(
            lambda sd: ((d.n,) + sd[0], sd[1]), inner,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple))
    if isinstance(d, Pair):
        return (_leaf_shapes(d.fst), _leaf_shapes(d.snd))
    raise TypeError(d)


def _flat_leaf_shapes(d: DataType) -> List[Tuple[Tuple[int, ...], str]]:
    out: List[Tuple[Tuple[int, ...], str]] = []

    def go(n):
        if isinstance(n, tuple) and len(n) == 2 and isinstance(n[0], tuple) \
                and all(isinstance(x, int) for x in n[0]):
            out.append(n)
        else:
            for c in n:
                go(c)

    go(_leaf_shapes(d))
    return out


def _rebuild_tree(d: DataType, leaves_iter):
    if isinstance(d, Pair):
        return (_rebuild_tree(d.fst, leaves_iter),
                _rebuild_tree(d.snd, leaves_iter))
    if isinstance(d, Arr):
        # arrays don't change the pair structure
        return _rebuild_tree(_strip_arr(d), leaves_iter) \
            if isinstance(_strip_arr(d), Pair) else next(leaves_iter)
    return next(leaves_iter)


def _strip_arr(d: DataType) -> DataType:
    while isinstance(d, Arr):
        d = d.elem
    return d


# ---------------------------------------------------------------------------
# the kernel-body executor
# ---------------------------------------------------------------------------

class _LazyRefStore:
    """dict-like store whose values are loaded from refs on read, so the
    functional interpreter (Fig. 6c evaluator) works unchanged in-kernel."""

    def __init__(self, refs: Dict[str, object]):
        self.refs = refs

    def __contains__(self, name):
        return name in self.refs

    def __getitem__(self, name):
        return jax.tree_util.tree_map(lambda r: r[...], self.refs[name])


def _ref_write(ref, path, value):
    """Write ``value`` into ``ref`` at ``path`` (ints / ('ds',s,w) / fst|snd)."""
    if isinstance(ref, tuple):
        for k, comp in enumerate(path):
            if comp in (FST, SND):
                b = 0 if comp == FST else 1
                _ref_write(ref[b], list(path[:k]) + list(path[k + 1:]), value)
                return
        for r, v in zip(ref, value):
            _ref_write(r, path, v)
        return
    idx = tuple(pl.ds(c[1], c[2]) if isinstance(c, tuple) and c[0] == "ds"
                else c for c in path)
    val = jnp.asarray(value, ref.dtype)
    if idx:
        ref[idx] = val
    else:
        ref[...] = val.reshape(ref.shape)


class _KernelCtx:
    """State while tracing one kernel body."""

    def __init__(self, kenv, refs, bindings, scratch_iter):
        self.kenv = kenv            # name -> value (inputs, indices, REG cells)
        self.refs = refs            # name -> ref pytree (outputs, scratch)
        self.bindings = bindings    # acceptor-parameter name -> acceptor phrase
        self.scratch_iter = scratch_iter
        self.reg_names = set()

    def eval(self, e):
        return interp(e, self.kenv, _LazyRefStore(self.refs))


def _exec_kernel(p: P.Phrase, ctx: _KernelCtx) -> None:  # noqa: C901
    if isinstance(p, P.Skip):
        return
    if isinstance(p, P.SeqC):
        _exec_kernel(p.c1, ctx)
        _exec_kernel(p.c2, ctx)
        return
    if isinstance(p, P.Assign):
        value = ctx.eval(p.e)
        _kwrite(p.a, [], value, ctx)
        return
    if isinstance(p, P.New):
        v = P.Var(P.fresh("kbuf"), VarT(p.d))
        if p.space == P.REG:
            ctx.kenv[v.name] = zero_value(p.d)
            ctx.reg_names.add(v.name)
            _exec_kernel(p.f(v), ctx)
            ctx.kenv.pop(v.name, None)
            ctx.reg_names.discard(v.name)
        else:  # vmem (and any hbm remnants) -> scratch refs
            refs = next(ctx.scratch_iter)
            ctx.refs[v.name] = refs
            _exec_kernel(p.f(v), ctx)
            del ctx.refs[v.name]
        return
    if isinstance(p, P.For):
        i = P.Var(P.fresh("i"), ExpT(Idx(p.n)))
        body = p.f(i)
        regs = sorted(r for r in written_roots(body) if r in ctx.reg_names)

        if p.unroll:
            for k in range(p.n):
                ctx.kenv[i.name] = jnp.asarray(k, "int32")
                _exec_kernel(body, ctx)
            ctx.kenv.pop(i.name, None)
            return

        carry0 = tuple(ctx.kenv[r] for r in regs)

        def loop_body(k, carry):
            ctx.kenv[i.name] = k
            for r, c in zip(regs, carry):
                ctx.kenv[r] = c
            _exec_kernel(body, ctx)
            return tuple(ctx.kenv[r] for r in regs)

        final = jax.lax.fori_loop(0, p.n, loop_body, carry0)
        for r, c in zip(regs, final):
            ctx.kenv[r] = c
        ctx.kenv.pop(i.name, None)
        return
    if isinstance(p, P.ParFor):
        # deeper parallel loops inside a kernel run sequentially on this core
        # (the strategy put them below the grid level on purpose)
        i = P.Var(P.fresh("i"), ExpT(Idx(p.n)))
        o = P.Var(P.fresh("o"), AccT(p.d))
        body = p.f(i, o)
        regs = sorted(r for r in written_roots(body) if r in ctx.reg_names)
        ctx.bindings[o.name] = None  # placeholder; set per-iteration below
        carry0 = tuple(ctx.kenv[r] for r in regs)

        def loop_body(k, carry):
            ctx.kenv[i.name] = k
            ctx.bindings[o.name] = P.IdxAcc(p.a, P.Var(i.name, ExpT(Idx(p.n))))
            for r, c in zip(regs, carry):
                ctx.kenv[r] = c
            _exec_kernel(body, ctx)
            return tuple(ctx.kenv[r] for r in regs)

        final = jax.lax.fori_loop(0, p.n, loop_body, carry0)
        for r, c in zip(regs, final):
            ctx.kenv[r] = c
        ctx.kenv.pop(i.name, None)
        ctx.bindings.pop(o.name, None)
        return
    if isinstance(p, (P.MapI, P.ReduceI)):
        _exec_kernel(stage2.expand(p), ctx)
        return
    raise TypeError(f"_exec_kernel: not a command {type(p).__name__}")


def _kwrite(a: P.Phrase, idxs: List, value, ctx: _KernelCtx) -> None:
    """In-kernel acceptor write: REG cells rebind, refs store."""
    # chase bound acceptor parameters (the o of each enclosing parfor)
    while isinstance(a, P.Var) and a.name in ctx.bindings:
        a = ctx.bindings[a.name]

    def leaf(root, path, val):
        if isinstance(root, P.Var):
            name = root.name
        else:  # AccPart
            name = root.v.name
        if name in ctx.bindings:
            _kwrite(ctx.bindings[name], path, val, ctx)
            return None
        if name in ctx.reg_names:
            ctx.kenv[name] = set_path(ctx.kenv[name], path, val)
            return None
        if name in ctx.refs:
            _ref_write(ctx.refs[name], path, val)
            return None
        raise KeyError(f"kernel write to unknown root {name!r}")

    fold_acc(a, idxs, value, ctx.eval, leaf)


# ---------------------------------------------------------------------------
# kernel stage construction
# ---------------------------------------------------------------------------

def _collect_grid(pf: P.ParFor):
    """Peel nested grid parfors; returns (grid_dims, i_vars, body, out_acc)."""
    dims: List[int] = []
    ivars: List[P.Var] = []
    bindings: Dict[str, P.Phrase] = {}
    node: P.Phrase = pf
    out_acc = pf.a
    while isinstance(node, P.ParFor) and node.level.kind in ("grid", "par"):
        i = P.Var(P.fresh("g"), ExpT(Idx(node.n)))
        o = P.Var(P.fresh("go"), AccT(node.d))
        dims.append(node.n)
        ivars.append(i)
        body = node.f(i, o)
        bindings[o.name] = P.IdxAcc(node.a, i)
        node = body
    return dims, ivars, node, bindings


def _free_exp_vars(p: P.Phrase) -> Dict[str, DataType]:
    """Free expression-typed identifiers of a phrase (kernel inputs)."""
    found: Dict[str, DataType] = {}

    def go(q, bound):
        if isinstance(q, P.Var) and isinstance(q.t, ExpT):
            if q.name not in bound:
                found[q.name] = q.t.d
            return
        if isinstance(q, P.ExpPart) and isinstance(q.v, P.Var):
            if q.v.name not in bound:
                found[q.v.name] = q.v.t.d
            return
        for attr in ("e", "a", "b", "i", "v", "c1", "c2", "init", "acc", "exp"):
            c = getattr(q, attr, None)
            if isinstance(c, P.Phrase):
                go(c, bound)
        # binders
        if isinstance(q, P.New):
            v = P.Var(P.fresh("v"), VarT(q.d))
            go(q.f(v), bound | {v.name})
        elif isinstance(q, P.For):
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            go(q.f(i), bound | {i.name})
        elif isinstance(q, P.ParFor):
            i = P.Var(P.fresh("i"), ExpT(Idx(q.n)))
            o = P.Var(P.fresh("o"), AccT(q.d))
            go(q.f(i, o), bound | {i.name, o.name})
        elif isinstance(q, P.Map):
            d = P.exp_data(q.e)
            x = P.Var(P.fresh("x"), ExpT(d.elem))
            go(q.f(x), bound | {x.name})
        elif isinstance(q, P.Reduce):
            d = P.exp_data(q.e)
            x = P.Var(P.fresh("x"), ExpT(d.elem))
            acc = P.Var(P.fresh("acc"), P.type_of(q.init))
            go(q.f(x, acc), bound | {x.name, acc.name})
        elif isinstance(q, (P.MapI, P.ReduceI)):
            go(stage2.expand(q), bound)

    go(p, set())
    return found


def _collect_scratch(body: P.Phrase) -> List[DataType]:
    """Data types of non-REG News in deterministic traversal order."""
    out: List[DataType] = []

    def go(q):
        if isinstance(q, P.SeqC):
            go(q.c1)
            go(q.c2)
        elif isinstance(q, P.New):
            if q.space != P.REG:
                out.append(q.d)
            go(q.f(P.Var(P.fresh("v"), VarT(q.d))))
        elif isinstance(q, P.For):
            go(q.f(P.Var(P.fresh("i"), ExpT(Idx(q.n)))))
        elif isinstance(q, P.ParFor):
            go(q.f(P.Var(P.fresh("i"), ExpT(Idx(q.n))),
                   P.Var(P.fresh("o"), AccT(q.d))))
        elif isinstance(q, (P.MapI, P.ReduceI)):
            go(stage2.expand(q))

    go(body)
    return out


def _run_kernel_stage(pf: P.ParFor, env: Dict, store: Store,
                      interpret: bool) -> Store:
    from .stage3_jnp import acc_root

    dims, ivars, body, bindings = _collect_grid(pf)
    root = acc_root(pf.a)
    out_buf = store[root]

    inputs = _free_exp_vars(body)
    # split inputs into those from env (kernel args) vs store (host temps)
    in_names, in_vals = [], []
    for name in sorted(inputs):
        if name in env:
            in_names.append(name)
            in_vals.append(env[name])
        elif name in store:
            in_names.append(name)
            in_vals.append(store[name])
        # loop indices of enclosing host loops arrive via env too

    # flatten input pytrees into individual refs
    flat_vals, in_treedefs = [], []
    for v in in_vals:
        leaves, treedef = jax.tree_util.tree_flatten(v)
        leaves = [jnp.reshape(l, (1,)) if l.ndim == 0 else l for l in leaves]
        flat_vals.append(leaves)
        in_treedefs.append(treedef)

    out_leaves, out_treedef = jax.tree_util.tree_flatten(out_buf)
    out_shape = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in out_leaves]

    scratch_types = _collect_scratch(body)
    scratch_shapes = []
    scratch_layout = []  # list of (num_leaves, treedef builder info)
    for d in scratch_types:
        leaf_specs = _flat_leaf_shapes(d)
        scratch_layout.append((d, len(leaf_specs)))
        for shape, dtype in leaf_specs:
            scratch_shapes.append(_scratch(shape, dtype))

    n_in = sum(len(f) for f in flat_vals)
    grid = tuple(dims) if dims else (1,)

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:n_in + len(out_leaves)]
        scratch_refs = refs[n_in + len(out_leaves):]

        # rebuild input values (loaded whole; VMEM staging is explicit via
        # the strategy's toVMEM -> scratch copies)
        kenv: Dict[str, object] = {}
        pos = 0
        for name, leaves, treedef, orig in zip(
                in_names, flat_vals, in_treedefs, in_vals):
            vals = []
            for l in leaves:
                r = in_refs[pos]
                v = r[...]
                orig_leaf = jax.tree_util.tree_leaves(orig)[len(vals)]
                if orig_leaf.ndim == 0:
                    v = v[0]
                vals.append(v)
                pos += 1
            kenv[name] = jax.tree_util.tree_unflatten(treedef, vals)

        for k, iv in enumerate(ivars):
            kenv[iv.name] = pl.program_id(k) if dims else jnp.int32(0)

        out_ref_tree = jax.tree_util.tree_unflatten(out_treedef, list(out_refs))

        # group scratch refs per New
        scratch_tree: List[object] = []
        si = 0
        for d, nleaf in scratch_layout:
            leaves = list(scratch_refs[si:si + nleaf])
            si += nleaf
            scratch_tree.append(_build_ref_tree(d, iter(leaves)))

        ctx = _KernelCtx(kenv, {root: out_ref_tree}, dict(bindings),
                         iter(scratch_tree))
        _exec_kernel(body, ctx)

    flat_all = [l for f in flat_vals for l in f]
    result = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*flat_all)
    if not isinstance(result, (list, tuple)):
        result = [result]
    new_out = jax.tree_util.tree_unflatten(out_treedef, list(result))
    out_store = dict(store)
    out_store[root] = new_out
    return out_store


def _build_ref_tree(d: DataType, leaves_iter):
    if isinstance(_strip_arr(d), Pair):
        core = _strip_arr(d)
        return (_build_ref_tree(core.fst, leaves_iter),
                _build_ref_tree(core.snd, leaves_iter))
    return next(leaves_iter)


# ---------------------------------------------------------------------------
# host-side executor: like stage3_jnp.exec_comm but grid parfors -> kernels
# ---------------------------------------------------------------------------

def exec_host(p: P.Phrase, env: Dict, store: Store, interpret: bool) -> Store:
    if isinstance(p, P.ParFor) and p.level.kind in ("grid", "par"):
        return _run_kernel_stage(p, env, store, interpret)
    if isinstance(p, P.SeqC):
        return exec_host(p.c2, env,
                         exec_host(p.c1, env, store, interpret), interpret)
    if isinstance(p, P.New):
        v = P.Var(P.fresh("hbuf"), VarT(p.d))
        store2 = dict(store)
        store2[v.name] = zero_value(p.d)
        store3 = exec_host(p.f(v), env, store2, interpret)
        store3 = dict(store3)
        del store3[v.name]
        return store3
    if isinstance(p, (P.MapI, P.ReduceI)):
        return exec_host(stage2.expand(p), env, store, interpret)
    # everything else (assignments, sequential loops) runs host-side
    return exec_comm(p, env, store)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def compile_expr_pallas(expr: P.Phrase, arg_vars, *, interpret: bool = True,
                        check: bool = True, lowered=None):
    """Functional expression -> callable running grid strategies as Pallas
    kernels (Stage I -> II -> kernel extraction).  ``lowered`` optionally
    supplies an already-translated ``(command, out_var)`` pair (the staged
    repro.compiler path) so Stage I/II is not redone here."""
    from . import check as chk
    from . import hoist as hoist_mod

    if lowered is not None:
        cmd, out = lowered
        d = out.t.d
    else:
        d = P.exp_data(expr)
        out = P.Var("out#", AccT(d))
        cmd = stage2.expand(stage1.translate(expr, out))
    # SCIR check happens BEFORE hoisting (as in the paper, where section 6.4 is
    # a code-generation step downstream of the type system; hoisting preserves
    # race freedom by construction — each iteration owns its indexed slice).
    if check:
        P.type_of(cmd)
        chk.check_race_free(cmd)
    # paper 6.4: HBM temporaries must be allocated outside kernels
    cmd = hoist_mod.hoist(cmd, spaces=(P.HBM,))
    names = [v.name for v in arg_vars]
    out_name = out.name

    def fn(*args):
        env = dict(zip(names, args))
        store: Store = {out_name: zero_value(d)}
        store = exec_host(cmd, env, store, interpret)
        return store[out_name]

    return fn


# self-register as a Stage III target (see repro.compiler.backends)
from repro.compiler.backends import Backend as _Backend  # noqa: E402
from repro.compiler.backends import register_backend as _register  # noqa: E402

_register(_Backend(
    name="pallas", compile=compile_expr_pallas,
    accepts=("check", "lowered", "interpret"),
    description="grid-level imperative DPIA -> pl.pallas_call kernels (TPU; "
                "interpret mode on CPU)"),
    aliases=("dpia-pallas",), overwrite=True)
