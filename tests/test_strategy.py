"""repro.strategy — combinator laws, traversal order, trace round-trips,
oracle equality of the strategy-program spaces against the legacy builders,
mining, seeding, and trace provenance through tune/Program/AOT.

Structural identity throughout is ``repro.strategy.fingerprint`` (binder-
stable), not ``repr`` (whose fresh-variable counter is process-global)."""
import json
import warnings

import numpy as np
import pytest

from repro import autotune, compiler, obs
from repro import strategy as st
from repro.autotune import space
from repro.core.dpia import interp, phrases as P, strategies
from repro.core.dpia.types import Arr, Num
from repro.kernels import dpia_blas
from repro.strategy import mine


def fp(e):
    return st.fingerprint(e)


def naive_dot(n=64):
    expr, argv = dpia_blas.naive_dot(n)
    return expr, argv


FUSE = st.rule("fuse_map_into_reduce")
BLOCK = st.rule("blocked_reduce", block=16, partial_level="grid(0)",
                combine="add")


# ---------------------------------------------------------------------------
# combinator laws (failure as a value, monoid structure)
# ---------------------------------------------------------------------------

def test_seq_identity_laws():
    e, _ = naive_dot()
    s = FUSE
    direct = s.apply(e)
    left = st.seq(st.id_(), s).apply(e)
    right = st.seq(s, st.id_()).apply(e)
    assert direct.ok and left.ok and right.ok
    assert fp(direct.phrase) == fp(left.phrase) == fp(right.phrase)
    # id contributes no trace steps: seq(id, s) traces exactly like s
    assert direct.trace.to_doc() == left.trace.to_doc() \
        == right.trace.to_doc()
    # and the empty seq IS the identity
    empty = st.seq().apply(e)
    assert empty.ok and fp(empty.phrase) == fp(e) and not empty.trace.steps


def test_seq_fails_when_any_half_fails():
    e, _ = naive_dot()
    assert not st.seq(st.fail_(), FUSE).apply(e)
    assert not st.seq(FUSE, st.fail_()).apply(e)
    r = st.seq(FUSE, st.fail_()).apply(e)
    assert not r.ok and r.phrase is None and r.reason


def test_alt_is_left_biased_and_try_fail_is_identity():
    e, _ = naive_dot()
    both = st.alt(FUSE, st.id_()).apply(e)
    assert both.ok and both.trace.steps  # FUSE won, not the identity
    fell = st.alt(st.fail_(), st.id_()).apply(e)
    assert fell.ok and fp(fell.phrase) == fp(e)
    tried = st.try_(st.fail_()).apply(e)
    assert tried.ok and fp(tried.phrase) == fp(e) and not tried.trace.steps


def test_rule_failure_is_a_value_not_an_exception():
    e, _ = naive_dot()
    # tile_matmul cannot possibly match a dot — must fail, never raise
    r = st.rule("tile_matmul", bm=8, bk=8).apply(e)
    assert not r.ok and r.reason


def test_repeat_terminates_without_progress_and_always_succeeds():
    e, _ = naive_dot()
    # id succeeds forever but never makes progress: repeat must stop
    r = st.repeat(st.id_()).apply(e)
    assert r.ok and fp(r.phrase) == fp(e)
    # a failing body leaves the term unchanged (zero iterations)
    r2 = st.repeat(st.fail_()).apply(e)
    assert r2.ok and fp(r2.phrase) == fp(e) and not r2.trace.steps
    # a once-applicable rule applies once, then the failure stops the loop
    r3 = st.repeat(FUSE).apply(e)
    assert r3.ok
    assert [s.rule for s in r3.trace.steps] == ["fuse_map_into_reduce"]


def test_repeat_n_fails_if_any_iteration_fails():
    e, _ = naive_dot()
    assert st.repeat_n(FUSE, 1).apply(e).ok
    assert not st.repeat_n(FUSE, 2).apply(e)  # fuse only applies once


# ---------------------------------------------------------------------------
# traversals: order, paths, HOAS binders
# ---------------------------------------------------------------------------

def test_topdown_vs_bottomup_first_match():
    """On the fused+blocked dot, vpu_reduce matches BOTH the outer
    partials-combine and the inner per-block reduce (under the grid map's
    binder).  topdown takes the outermost; bottomup the innermost — the
    traversal IS the choice, which is why the kernel spaces use bottomup."""
    e, _ = naive_dot(64)
    blocked = st.seq(FUSE, BLOCK).apply(e)
    assert blocked.ok
    top = st.topdown(st.rule("vpu_reduce")).apply(blocked.phrase)
    bot = st.bottomup(st.rule("vpu_reduce")).apply(blocked.phrase)
    assert top.ok and bot.ok
    assert top.trace.steps[-1].path == ()
    assert bot.trace.steps[-1].path == ("e", "f")
    assert fp(top.phrase) != fp(bot.phrase)


def test_bottomup_rewrites_under_binders():
    """The bottomup vpu_reduce fires inside the grid Map's HOAS closure —
    the rebuilt binder must produce the rewritten body on every call."""
    e, argv = naive_dot(64)
    res = st.seq(FUSE, BLOCK,
                 st.bottomup(st.rule("vpu_reduce"))).apply(e)
    assert res.ok
    rng = np.random.RandomState(0)
    xs = rng.randn(64).astype("float32")
    ys = rng.randn(64).astype("float32")
    env = {"xs": xs, "ys": ys}
    np.testing.assert_allclose(np.asarray(interp.interp(res.phrase, env)),
                               xs @ ys, rtol=1e-5)


def test_at_navigates_to_recorded_path():
    e, _ = naive_dot(64)
    blocked = st.seq(FUSE, BLOCK).apply(e)
    r = st.at(("e", "f"), st.rule("vpu_reduce")).apply(blocked.phrase)
    bot = st.bottomup(st.rule("vpu_reduce")).apply(blocked.phrase)
    assert r.ok and fp(r.phrase) == fp(bot.phrase)
    assert not st.at(("e",), st.rule("vpu_reduce")).apply(blocked.phrase)


def test_one_vacuous_on_leaves_all_succeeds():
    x = P.var_exp("x", Arr(8, Num()))
    assert not st.one(st.id_()).apply(x)       # a leaf has no children
    assert st.all_(st.fail_()).apply(x).ok     # vacuously true on leaves


# ---------------------------------------------------------------------------
# traces: JSON round-trip + deterministic replay
# ---------------------------------------------------------------------------

def test_trace_json_round_trip_and_replay():
    e, _ = naive_dot(64)
    prog = st.seq(FUSE, BLOCK, st.bottomup(st.rule("vpu_reduce")))
    res = prog.apply(e)
    assert res.ok
    doc = json.loads(json.dumps(res.trace.to_doc()))
    assert st.is_trace_doc(doc) and doc["version"] == 1
    assert st.StrategyTrace.from_doc(doc).to_doc() == res.trace.to_doc()
    replayed = st.replay(doc, e)
    assert replayed.ok
    assert fp(replayed.phrase) == fp(res.phrase)
    assert replayed.trace.to_doc() == res.trace.to_doc()


def test_replay_of_malformed_trace_is_failure_value():
    e, _ = naive_dot(64)
    bad = {"version": 1, "steps": [{"rule": "no_such_rule", "path": [],
                                    "params": {}}]}
    assert not st.replay(bad, e)


# ---------------------------------------------------------------------------
# oracle equality: the six kernel spaces as strategy programs
# ---------------------------------------------------------------------------

SHAPES = {
    "dot": {"n": 512}, "asum": {"n": 512}, "scal": {"n": 512},
    "matmul": {"m": 64, "k": 64, "n": 64},
    "rmsnorm": {"rows": 16, "d": 64},
    "softmax": {"rows": 16, "d": 64},
}


@pytest.mark.parametrize("kernel", sorted(SHAPES))
def test_space_candidates_equal_legacy_builders(kernel):
    """Every enumerated candidate (now derived by its strategy program) is
    phrase-identical to the pre-strategy-language hand-built term."""
    shape = SHAPES[kernel]
    cands = space.enumerate_space(kernel, **shape)
    assert cands
    for cand in cands:
        legacy = space.legacy_candidate(kernel, cand.params_dict, **shape)
        e_new, argv_new = cand.build()
        e_old, argv_old = legacy.build()
        assert fp(e_new) == fp(e_old), \
            f"{kernel} {cand.params_key()} diverged from the legacy builder"
        assert [v.name for v in argv_new] == [v.name for v in argv_old]
        # non-identity candidates must be able to say how they were derived
        doc = cand.trace_doc()
        if cand.params_dict.get("block") is not None or \
                kernel in ("matmul", "rmsnorm", "softmax"):
            assert doc and doc["steps"]


def test_generic_space_covers_fused_term():
    expr, _ = st.fused_rmsnorm_matmul(32, 64, 32)
    got = st.generic_space(expr, blocks=(8, 16, 32), tiles=(16, 32, 64))
    rewrites = {p["rewrite"] for p, _, _ in got}
    assert "id" in rewrites and "tile_matmul" in rewrites
    assert len(got) > 2
    # every surviving candidate type-checks (well-typed by construction)
    for _, _, res in got:
        P.type_of(res.phrase)


# ---------------------------------------------------------------------------
# mining + seeding
# ---------------------------------------------------------------------------

def _trace(steps):
    return {"version": 1, "steps": steps}


def _step(rule, block):
    return [{"rule": "fuse_map_into_reduce", "path": [], "params": {}},
            {"rule": rule, "path": [],
             "params": {"block": block, "combine": "add"}}]


def test_anti_unify_holes_differing_params():
    t1, t2 = _trace(_step("blocked_reduce", 128)), \
        _trace(_step("blocked_reduce", 256))
    g = mine.anti_unify(t1, t2)
    assert [s.rule for s in g] == ["fuse_map_into_reduce", "blocked_reduce"]
    params = dict(g[1].params)
    assert params["block"] == mine.HOLE and params["combine"] == "add"
    a = mine.Abstraction("a", g)
    assert mine.matches(a, t1) and mine.matches(a, t2)
    assert mine.matches(a, _trace(_step("blocked_reduce", 999)))
    assert not mine.matches(a, _trace(_step("split_join", 128)))


def test_mine_respects_min_support_and_persists(tmp_path):
    traces = [_trace(_step("blocked_reduce", b)) for b in (128, 256, 512)]
    traces.append(_trace([{"rule": "tile_matmul", "path": [],
                           "params": {"bm": 32, "bk": 32}}]))
    abstractions = mine.mine(traces, min_support=3)
    assert abstractions and abstractions[0].support == 3
    assert all(a.support >= 3 for a in abstractions)
    path = str(tmp_path / "cache.abstractions.json")
    mine.save_abstractions(path, abstractions)
    loaded = mine.load_abstractions(path)
    assert [a.to_doc() for a in loaded] == [a.to_doc() for a in abstractions]
    assert mine.load_abstractions(str(tmp_path / "absent.json")) == []
    assert mine.abstractions_path("/x/tuning_cache.json") \
        == "/x/tuning_cache.abstractions.json"


def test_seeded_order_is_a_stable_partition():
    cands = space.enumerate_space("dot", n=512)
    abstraction = mine.Abstraction("a", mine.anti_unify(
        _trace(_step("blocked_reduce", 128)),
        _trace(_step("blocked_reduce", 256))))
    ordered = mine.seeded_order(cands, [abstraction])
    assert sorted(c.params_key() for c in ordered) \
        == sorted(c.params_key() for c in cands)
    hit = [c for c in ordered
           if c.trace_doc() and mine.matches(abstraction, c.trace_doc())]
    assert hit and ordered[:len(hit)] == hit  # all hits first, order kept
    assert ordered[0].params_dict != cands[0].params_dict  # naive deferred


def test_mined_corpus_seeds_tune(tmp_path):
    cache = str(tmp_path / "tuning_cache.json")
    for n in (512, 1024, 2048):
        autotune.tune("dot", n=n, cache=cache, measure=False)
        autotune.tune("asum", n=n, cache=cache, measure=False)
    from repro.autotune.cache import TuningCache
    abstractions = mine.mine(TuningCache(cache))
    assert abstractions
    mine.save_abstractions(mine.abstractions_path(cache), abstractions)
    was_enabled = obs.enabled()
    obs.enable()
    try:
        res = autotune.tune("dot", n=4096, cache=cache, measure=True,
                            iters=1, top_k=1)
        names = [e["name"] for e in obs.trace_events()]
    finally:
        if not was_enabled:
            obs.disable()
    assert res.source == "measured" and res.strategy_trace
    assert "autotune.seeded" in names


# ---------------------------------------------------------------------------
# satellite: hardened strategies.search
# ---------------------------------------------------------------------------

def test_search_skips_raising_cost_fn_with_warning():
    a, b, c = P.lit(1.0), P.lit(2.0), P.lit(3.0)
    costs = {id(a): 5.0, id(c): 1.0}

    def cost_fn(x):
        if x is b:
            raise RuntimeError("unpriceable term")
        return costs[id(x)]

    strategies._warned_cost_failure = False
    with pytest.warns(RuntimeWarning, match="cost_fn raised"):
        assert strategies.search([a, b, c], cost_fn) is c
    # once per process: the second failure is silent (event-only)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert strategies.search([a, b, c], cost_fn) is c
    strategies._warned_cost_failure = False
    # every candidate raising degrades to the deterministic first pick
    with pytest.warns(RuntimeWarning):
        def always(_):
            raise ValueError("nope")
        assert strategies.search([a, b], always) is a


# ---------------------------------------------------------------------------
# provenance: tune -> cache/obs, Program, AOT
# ---------------------------------------------------------------------------

def test_tune_records_strategy_trace_and_explains_it(tmp_path):
    cache = str(tmp_path / "tuning_cache.json")
    res = autotune.tune("dot", n=1024, cache=cache, measure=False)
    assert res.strategy_trace and res.strategy_trace["steps"]
    rules = [s["rule"] for s in res.strategy_trace["steps"]]
    assert "blocked_reduce" in rules
    # the cache record carries it, and the hit serves it back
    hit = autotune.tune("dot", n=1024, cache=cache, measure=False)
    assert hit.source == "cache"
    assert hit.strategy_trace == res.strategy_trace
    d = obs.provenance.get(res.key)
    assert d is not None and d.strategy_trace == res.strategy_trace
    assert "derived by" in d.describe()
    assert "blocked_reduce" in obs.explain(res.key)


def test_tune_with_explicit_strategy_programs(tmp_path):
    cache = str(tmp_path / "tuning_cache.json")
    progs = [st.named("fuse+block", st.seq(
        FUSE, st.rule("blocked_reduce", block=256,
                      partial_level="grid(0)", combine="add")))]
    res = autotune.tune("dot", n=1024, cache=cache, measure=False,
                        strategies=progs)
    assert res.params in ({"strategy": "fuse+block"}, {"strategy": "id"})
    assert res.strategy_trace is not None


def test_program_lower_accepts_strategy_and_trace():
    prog = compiler.Program.from_kernel(
        "dot", params={"block": None, "leaf": "seq"}, n=256)
    s = st.seq(FUSE, st.rule("blocked_reduce", block=64,
                             partial_level="grid(0)", combine="add"))
    p2 = prog.lower(s)
    assert p2.strategy_trace and p2.strategy_trace["steps"]
    p3 = prog.lower(p2.strategy_trace)  # replay the serialised derivation
    assert fp(p2.expr) == fp(p3.expr)
    assert p3.strategy_trace == p2.strategy_trace
    with pytest.raises(ValueError, match="failed"):
        prog.lower(st.fail_())


def test_program_export_round_trips_strategy_trace(tmp_path):
    prog = compiler.Program.from_kernel("matmul", m=64, k=64, n=64)
    assert prog.strategy_trace, "from_kernel must attach the derivation"
    assert prog.strategy_trace["steps"][0]["rule"] == "tile_matmul"
    path = str(tmp_path / "mm.json")
    prog.check().export(path)
    loaded = compiler.Program.load(path)
    assert loaded.strategy_trace == prog.strategy_trace


def test_aot_loaded_executor_reports_derivation(tmp_path):
    from repro.kernels import ops
    cache = str(tmp_path / "tuning_cache.json")
    aot = str(tmp_path / "aot")
    ops.clear_caches()
    try:
        with compiler.options(backend="dpia-jnp", tuning_cache=cache):
            x = np.ones((8, 64), np.float32)
            w = np.ones(64, np.float32)
            np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)), x,
                                       rtol=1e-5)
            assert compiler.executor_cache().save_aot(aot) >= 1
            compiler.executor_cache().clear()
            obs.provenance.clear()
            assert compiler.executor_cache().load_aot(aot) >= 1
        loaded = [d for d in obs.provenance.decisions()
                  if d.origin == "aot-loaded"]
        assert loaded and any(d.strategy_trace and d.strategy_trace["steps"]
                              for d in loaded)
    finally:
        ops.clear_caches()
