"""Roofline table from the dry-run results (experiments/dryrun.json).

Prints per (arch x shape x mesh): the three terms, the bottleneck, and the
MODEL_FLOPS / HLO_FLOPs usefulness ratio.  Used by benchmarks.run and to
generate EXPERIMENTS.md section Roofline.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS = "experiments/dryrun.json"


def load(path: str = RESULTS) -> Dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.2f}ms"


def rows(results: Dict, mesh: Optional[str] = "single") -> List[Dict]:
    out = []
    for key, rec in sorted(results.items()):
        arch, shape, m = key.split("|")
        if mesh and m != mesh:
            continue
        if rec.get("status") != "ok":
            out.append(dict(arch=arch, shape=shape, mesh=m,
                            status=rec.get("status"),
                            reason=rec.get("reason", "")[:60]))
            continue
        r = rec["roofline"]
        out.append(dict(
            arch=arch, shape=shape, mesh=m, status="ok",
            compute_s=r["compute_s"], memory_s=r["memory_s"],
            collective_s=r["collective_s"], bottleneck=r["bottleneck"],
            flops=r["flops"], coll_bytes=r["coll_bytes"],
            useful=r["useful_frac"], model_flops=r["model_flops"],
            tokens=rec.get("tokens_per_step"),
        ))
    return out


def print_table(results: Dict, mesh: str = "single",
                csv_rows: Optional[List[str]] = None) -> None:
    print(f"# Roofline ({mesh}-pod): compute/memory/collective terms per step")
    hdr = (f"{'arch':15s} {'shape':12s} {'compute':9s} {'memory':9s} "
           f"{'collect.':9s} {'bound':10s} {'useful':7s}")
    print(hdr)
    for r in rows(results, mesh):
        if r["status"] != "ok":
            print(f"{r['arch']:15s} {r['shape']:12s} -- {r['status']}: "
                  f"{r.get('reason','')}")
            continue
        useful = f"{r['useful']:.2f}" if r["useful"] else "-"
        print(f"{r['arch']:15s} {r['shape']:12s} {fmt_s(r['compute_s'])} "
              f"{fmt_s(r['memory_s'])} {fmt_s(r['collective_s'])} "
              f"{r['bottleneck']:10s} {useful:7s}")
        if csv_rows is not None:
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            csv_rows.append(
                f"roofline/{r['arch']}/{r['shape']}/{mesh},"
                f"{dom*1e6:.1f},bottleneck={r['bottleneck']}"
                f";useful={useful}")
