"""Theorem 5.1 as executable property: A(E)(out) == out := [[E]] through the
full Stage I -> II -> III pipeline, on fixed paper examples and on
hypothesis-generated random functional terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dpia import interp, phrases as P, stage1, stage2, stage3_jnp
from repro.core.dpia.types import Arr, Num, Pair


def run_pipeline(expr, argv, args):
    fn = stage3_jnp.compile_expr(expr, argv)
    return jax.jit(fn)(*args)


def oracle(expr, argv, args):
    return interp.interp(expr, {v.name: a for v, a in zip(argv, args)})


def check_equiv(expr, argv, args, rtol=1e-4):
    got = run_pipeline(expr, argv, args)
    want = oracle(expr, argv, args)
    jax.tree_util.tree_map(
        lambda g, w: np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=rtol, atol=1e-5), got, want)


class TestPaperExamples:
    def test_dot_product_eq1(self, rng):
        """Paper (1): reduce (+) 0 (map (fst*snd) (zip xs ys))."""
        n = 32
        xs = P.var_exp("xs", Arr(n, Num()))
        ys = P.var_exp("ys", Arr(n, Num()))
        e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                     P.Map(lambda z: P.mul(P.Fst(z), P.Snd(z)),
                           P.Zip(xs, ys)))
        ax = jnp.asarray(rng.randn(n), "float32")
        ay = jnp.asarray(rng.randn(n), "float32")
        check_equiv(e, [xs, ys], (ax, ay))
        np.testing.assert_allclose(run_pipeline(e, [xs, ys], (ax, ay)),
                                   np.dot(ax, ay), rtol=1e-4)

    def test_dot_product_eq2_strategy(self, rng):
        """Paper (2): split/nested-map/sequential-reduce strategy — same
        semantics, different schedule."""
        n = 32
        xs = P.var_exp("xs", Arr(n, Num()))
        ys = P.var_exp("ys", Arr(n, Num()))
        e = P.Reduce(
            lambda x, a: P.add(a, x), P.lit(0.0),
            P.Join(P.Map(
                lambda zs1: P.Map(
                    lambda zs2: P.Reduce(
                        lambda z, a: P.add(P.mul(P.Fst(z), P.Snd(z)), a),
                        P.lit(0.0), zs2),
                    P.Split(4, zs1), level=P.PAR),
                P.Split(8, P.Zip(xs, ys)), level=P.PAR)))
        ax = jnp.asarray(rng.randn(n), "float32")
        ay = jnp.asarray(rng.randn(n), "float32")
        check_equiv(e, [xs, ys], (ax, ay))

    def test_no_implicit_fusion(self):
        """Paper section 2.2: reduce-of-map materialises the intermediate —
        the translation must contain a `new` allocating n.num (no fusion)."""
        n = 16
        xs = P.var_exp("xs", Arr(n, Num()))
        e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                     P.Map(lambda x: P.mul(x, x), xs))
        cmd = stage1.translate(e, P.var_acc("out", Num()))
        # outermost phrase must be the temporary allocation of the map result
        assert isinstance(cmd, P.New)
        assert cmd.d == Arr(n, Num())

    def test_fused_strategy_has_no_temp(self):
        """After the *explicit* fusion rewrite, no temp array remains."""
        from repro.core.dpia import strategies
        n = 16
        xs = P.var_exp("xs", Arr(n, Num()))
        e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                     P.Map(lambda x: P.mul(x, x), xs))
        fused = strategies.fuse_map_into_reduce(e)
        cmd = stage1.translate(fused, P.var_acc("out", Num()))
        # reduceI's expansion allocates only the scalar accumulator
        cmd2 = stage2.expand(cmd)
        news = []

        def walk(p):
            if isinstance(p, P.New):
                news.append(p.d)
                walk(p.f(P.Var(P.fresh("v"), P.VarT(p.d))))
            elif isinstance(p, P.SeqC):
                walk(p.c1), walk(p.c2)
            elif isinstance(p, P.For):
                walk(p.f(P.var_exp(P.fresh("i"), Num())))
        from repro.core.dpia.types import VarT  # noqa
        try:
            walk(cmd2)
        except Exception:
            pass
        assert all(not isinstance(d, Arr) for d in news), news

    def test_gemv(self, rng):
        m, n = 6, 8
        A = P.var_exp("A", Arr(m, Arr(n, Num())))
        x = P.var_exp("x", Arr(n, Num()))
        e = P.Map(lambda row: P.Reduce(
            lambda z, acc: P.add(acc, z), P.lit(0.0),
            P.Map(lambda p_: P.mul(P.Fst(p_), P.Snd(p_)), P.Zip(row, x))), A)
        aM = jnp.asarray(rng.randn(m, n), "float32")
        ax = jnp.asarray(rng.randn(n), "float32")
        check_equiv(e, [A, x], (aM, ax))
        np.testing.assert_allclose(run_pipeline(e, [A, x], (aM, ax)),
                                   aM @ ax, rtol=1e-4)

    def test_pair_output(self, rng):
        n = 8
        xs = P.var_exp("xs", Arr(n, Num()))
        e = P.PairE(P.FullReduce("add", xs), P.FullReduce("max", xs))
        ax = jnp.asarray(rng.randn(n), "float32")
        check_equiv(e, [xs], (ax,))

    def test_transpose_roundtrip(self, rng):
        A = P.var_exp("A", Arr(4, Arr(6, Num())))
        aM = jnp.asarray(rng.randn(4, 6), "float32")
        check_equiv(P.Transpose(A), [A], (aM,))
        check_equiv(P.Transpose(P.Transpose(A)), [A], (aM,))

    def test_asvector_roundtrip(self, rng):
        xs = P.var_exp("xs", Arr(16, Num()))
        ax = jnp.asarray(rng.randn(16), "float32")
        check_equiv(P.AsScalar(P.AsVector(4, xs)), [xs], (ax,))


# ---------------------------------------------------------------------------
# property-based: random functional terms
# ---------------------------------------------------------------------------

def scalar_fn(which):
    return {
        0: lambda x: P.add(x, P.lit(1.0)),
        1: lambda x: P.mul(x, P.lit(2.0)),
        2: lambda x: P.UnOp("neg", x),
        3: lambda x: P.mul(x, x),
        4: lambda x: P.UnOp("abs", x),
    }[which]


@st.composite
def dpia_exprs(draw):
    """Random (expr, argv, concrete args) triples of array type."""
    n = draw(st.sampled_from([4, 6, 8, 12]))
    depth = draw(st.integers(0, 3))
    rng = np.random.RandomState(draw(st.integers(0, 2 ** 16)))
    xs = P.var_exp("xs", Arr(n, Num()))
    args = [jnp.asarray(rng.randn(n), "float32")]
    e = xs
    size = n
    for _ in range(depth):
        kind = draw(st.integers(0, 4))
        if kind == 0:
            e = P.Map(scalar_fn(draw(st.integers(0, 4))), e, level=P.PAR)
        elif kind == 1:
            divisors = [d for d in (2, 3, 4) if size % d == 0]
            if not divisors:
                continue
            d_ = draw(st.sampled_from(divisors))
            which = draw(st.integers(0, 4))  # drawn EAGERLY: binders are pure
            e = P.Join(P.Map(
                lambda blk, w=which: P.Map(scalar_fn(w), blk, level=P.SEQ),
                P.Split(d_, e), level=P.PAR))
        elif kind == 2:
            e = P.Map(lambda z: P.add(P.Fst(z), P.Snd(z)), P.Zip(e, e2(e)))
        elif kind == 3:
            divisors = [d for d in (2, 4) if size % d == 0]
            if divisors:
                d_ = draw(st.sampled_from(divisors))
                e = P.AsScalar(P.AsVector(d_, e))
        else:
            e = P.Map(scalar_fn(draw(st.integers(0, 4))), e, level=P.SEQ)
    if draw(st.booleans()):
        e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0), e)
    return e, [xs], tuple(args)


def e2(e):
    return P.Map(lambda x: P.mul(x, P.lit(0.5)), e, level=P.SEQ)


@settings(max_examples=25, deadline=None)
@given(dpia_exprs())
def test_random_terms_stage3_matches_oracle(triple):
    e, argv, args = triple
    check_equiv(e, argv, args, rtol=1e-3)
