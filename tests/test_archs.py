"""Per-architecture smoke tests: reduced config of the same family, one
forward + train-grad + prefill/decode step on CPU; output shapes + no NaNs.
(The FULL configs are exercised only by the dry-run, as assigned.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, config, smoke_config
from repro.models.transformer import Model

PUBLISHED_B = {   # sanity band for analytic param counts (total, +-30%)
    "stablelm-1.6b": 1.6, "qwen1.5-32b": 32, "yi-9b": 9, "qwen3-4b": 4,
    "zamba2-2.7b": 2.7, "dbrx-132b": 132, "grok-1-314b": 314,
    "chameleon-34b": 34, "rwkv6-1.6b": 1.6, "musicgen-large": 3.3,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = config(arch)
    published = PUBLISHED_B[cfg.name]
    got = cfg.param_count() / 1e9
    assert 0.7 * published <= got <= 1.35 * published, \
        f"{cfg.name}: {got:.1f}B vs published {published}B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train(arch, rng):
    cfg = smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    b, s = 2, 16
    shape = (b, s) + ((cfg.n_codebooks,) if cfg.n_codebooks else ())
    tokens = jax.random.randint(key, shape, 0, cfg.vocab)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab)

    logits = jax.jit(model.forward)(params, tokens)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(
        params, {"tokens": tokens, "labels": labels})
    assert bool(jnp.isfinite(loss))
    gn = jax.tree_util.tree_reduce(
        lambda a, l: a + jnp.sum(jnp.abs(l.astype(jnp.float32))), grads, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_consistency(arch, rng):
    """Prefill+decode must reproduce the full forward's next-token logits —
    the KV-cache / recurrent-state bookkeeping correctness test."""
    cfg = smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    b, s = 2, 12
    shape = (b, s) + ((cfg.n_codebooks,) if cfg.n_codebooks else ())
    tokens = jax.random.randint(key, shape, 0, cfg.vocab)

    full_logits = model.forward(params, tokens)          # (b, s, v)
    cache = model.init_cache(b, cfg.max_seq)
    last, cache = model.prefill(params, tokens, cache)

    np.testing.assert_allclose(
        np.asarray(last, "float32"),
        np.asarray(full_logits[:, -1], "float32"), rtol=2e-2, atol=2e-2)

    # decode one step and compare with a longer full forward
    nxt = jnp.argmax(last, axis=-1)[:, None]
    if cfg.n_codebooks:
        nxt = jnp.broadcast_to(nxt[..., None], (b, 1, cfg.n_codebooks))
    step_logits, cache = model.decode_step(params, nxt, cache, jnp.int32(s))
    tokens2 = jnp.concatenate([tokens, nxt], axis=1)
    full2 = model.forward(params, tokens2)
    np.testing.assert_allclose(
        np.asarray(step_logits, "float32"),
        np.asarray(full2[:, -1], "float32"), rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_dense(rng):
    """The flash-equivalent chunked path == materialised-softmax path."""
    from repro.models.attention import chunked_attention
    from repro.kernels import ref
    b, s, nh, nkv, hd = 2, 2048, 8, 2, 32
    q = jnp.asarray(rng.randn(b, s, nh, hd), "float32") * 0.3
    k = jnp.asarray(rng.randn(b, s, nkv, hd), "float32") * 0.3
    v = jnp.asarray(rng.randn(b, s, nkv, hd), "float32")
    got = chunked_attention(q, k, v, causal=True, kv_chunk=512)
    qf = q.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nkv, s, hd)
    want = ref.flash_attention(qf, kf, vf, causal=True).reshape(
        b, nh, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_mass_conservation(rng):
    """Every kept token's gates sum to 1; dropped tokens produce zeros."""
    from repro.models import ffn
    cfg = smoke_config("dbrx_132b")
    key = jax.random.PRNGKey(0)
    p = ffn.init_moe(key, cfg)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model), "float32")
    out, aux = ffn.moe(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) >= 1.0 - 1e-3  # >= 1 at uniform
