"""Schema validation for the observability artefacts CI uploads.

Checks (stdlib only, no jsonschema dependency):

  * a trace file is Chrome/Perfetto trace-event JSON — a ``traceEvents``
    list whose every event has a string ``name``, a known phase (``X``
    complete events carry numeric ``ts``/``dur``; ``i`` instants carry
    ``ts`` and scope ``s``), and integer ``pid``/``tid``;
  * a metrics file is a ``{name: snapshot}`` dict whose every snapshot has
    a known ``type`` with that type's required fields;
  * a BENCH_serve.json carries its embedded ``metrics`` snapshot with the
    benchmark's reported gauges present;
  * a strategy-trace artefact (``--strategy``) carries well-formed
    serialised ``repro.strategy.StrategyTrace`` docs — version 1, every
    step with a non-empty string ``rule``, a ``path`` of slot-name strings
    and JSON-scalar ``params``.  Accepts a bare trace doc, a tuning-cache
    file (every record's ``strategy_trace``), or any JSON object whose
    (nested) ``strategy_trace`` fields are then checked;
  * a flight-recorder dump (``--flight``, a ``flight-*.json`` file or a
    directory of them) is version 1, names a ``reason``, and carries a
    well-formed ring (``events``: entries with a known ``kind`` + name),
    an embedded metrics snapshot, and well-formed drift stats;
  * a ``BENCH_history.json`` trajectory (``--history``) is a list of runs
    each carrying a timestamp and the headline serve numbers;
  * a scheduler journal (``--journal``, JSONL from
    ``repro.serve.domains.SchedulerJournal``) has every line's sha256
    checksum recomputed and verified, every record kind known
    (submit/progress/terminal/evacuate/shrink), and the required fields
    per kind present — independently of the repro tree, so a journal CI
    uploads is provably replayable.

Usage:
  python benchmarks/validate_trace.py --trace trace.json \
      [--metrics metrics.json] [--bench BENCH_serve.json] \
      [--strategy tuning_cache.json] [--flight flight-dumps/] \
      [--history BENCH_history.json] [--journal journal.jsonl]

Exits non-zero with a message naming the first offending record, so a CI
failure points at the event, not just the file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_PHASES = {"X", "i", "B", "E", "M"}
_METRIC_FIELDS = {
    "counter": ("value",),
    "gauge": ("value",),
    "histogram": ("count", "total", "mean", "buckets"),
}


def fail(msg: str) -> None:
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def validate_trace(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: not a trace-event document (no 'traceEvents')")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(f"{path}: 'traceEvents' must be a non-empty list")
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: not an object")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            fail(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if ph not in _PHASES:
            fail(f"{where} ({ev['name']!r}): unknown phase {ph!r}")
        if ph in ("X", "i"):
            if not isinstance(ev.get("ts"), (int, float)):
                fail(f"{where} ({ev['name']!r}): non-numeric 'ts'")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                fail(f"{where} ({ev['name']!r}): bad 'dur'")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            fail(f"{where} ({ev['name']!r}): instant scope {ev.get('s')!r}")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                fail(f"{where} ({ev['name']!r}): non-integer {k!r}")
    return len(events)


def validate_metrics(snap: dict, where: str) -> int:
    if not isinstance(snap, dict) or not snap:
        fail(f"{where}: metrics snapshot must be a non-empty dict")
    for name, m in snap.items():
        if not isinstance(m, dict):
            fail(f"{where}: metric {name!r} is not an object")
        t = m.get("type")
        if t not in _METRIC_FIELDS:
            fail(f"{where}: metric {name!r} has unknown type {t!r}")
        for field in _METRIC_FIELDS[t]:
            if field not in m:
                fail(f"{where}: {t} {name!r} missing field {field!r}")
    return len(snap)


def validate_bench(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if "metrics" not in doc:
        fail(f"{path}: no embedded 'metrics' snapshot")
    n = validate_metrics(doc["metrics"], f"{path}[metrics]")
    for gauge in ("bench.fused.tok_s", "bench.continuous.tok_s",
                  "bench.prefill.latency_ms"):
        if gauge not in doc["metrics"]:
            fail(f"{path}: reported gauge {gauge!r} absent from metrics")
    return n


_TRACE_VERSION = 1  # repro.strategy.lang.TRACE_VERSION (stdlib-only here)


def validate_strategy_trace_doc(doc, where: str) -> int:
    if not isinstance(doc, dict):
        fail(f"{where}: strategy trace is not an object")
    if doc.get("version") != _TRACE_VERSION:
        fail(f"{where}: unsupported strategy-trace version "
             f"{doc.get('version')!r}")
    steps = doc.get("steps")
    if not isinstance(steps, list):
        fail(f"{where}: 'steps' must be a list")
    for i, s in enumerate(steps):
        w = f"{where}.steps[{i}]"
        if not isinstance(s, dict):
            fail(f"{w}: not an object")
        if not isinstance(s.get("rule"), str) or not s["rule"]:
            fail(f"{w}: missing/empty 'rule'")
        path = s.get("path", [])
        if not isinstance(path, list) or \
                not all(isinstance(p, str) and p for p in path):
            fail(f"{w} ({s['rule']!r}): 'path' must be a list of slot names")
        params = s.get("params", {})
        if not isinstance(params, dict):
            fail(f"{w} ({s['rule']!r}): 'params' must be an object")
        for k, v in params.items():
            if not isinstance(v, (str, int, float, bool)) and v is not None:
                fail(f"{w} ({s['rule']!r}): param {k!r} is not a JSON "
                     f"scalar: {type(v).__name__}")
    return len(steps)


def _find_strategy_traces(doc, where: str):
    """Yield (trace_doc, where) for every strategy trace in an artefact."""
    if isinstance(doc, dict):
        if "steps" in doc and "version" in doc:
            yield doc, where
            return
        for k, v in doc.items():
            if k == "strategy_trace" and v is not None:
                yield v, f"{where}.strategy_trace"
            elif isinstance(v, (dict, list)):
                yield from _find_strategy_traces(v, f"{where}.{k}")
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            if isinstance(v, (dict, list)):
                yield from _find_strategy_traces(v, f"{where}[{i}]")


def validate_strategy(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    found = list(_find_strategy_traces(doc, path))
    n = 0
    for trace, where in found:
        validate_strategy_trace_doc(trace, where)
        n += 1
    if n == 0:
        fail(f"{path}: no strategy traces found (neither a trace doc nor "
             f"any 'strategy_trace' field)")
    return n


_RING_KINDS = {"event", "span", "metric"}


def validate_flight_doc(doc: dict, where: str) -> int:
    """One flight-recorder dump document; returns its ring length."""
    if not isinstance(doc, dict):
        fail(f"{where}: not an object")
    if doc.get("version") != 1:
        fail(f"{where}: unsupported flight-dump version "
             f"{doc.get('version')!r}")
    if not isinstance(doc.get("reason"), str) or not doc["reason"]:
        fail(f"{where}: missing/empty 'reason'")
    if not isinstance(doc.get("ctx"), dict):
        fail(f"{where}: 'ctx' must be an object")
    events = doc.get("events")
    if not isinstance(events, list):
        fail(f"{where}: 'events' must be a list")
    for i, e in enumerate(events):
        w = f"{where}.events[{i}]"
        if not isinstance(e, dict):
            fail(f"{w}: not an object")
        if e.get("kind") not in _RING_KINDS:
            fail(f"{w}: unknown ring-entry kind {e.get('kind')!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            fail(f"{w}: missing/empty 'name'")
        if not isinstance(e.get("t"), (int, float)):
            fail(f"{w} ({e['name']!r}): non-numeric 't'")
        if e["kind"] == "span" and not isinstance(e.get("dur_us"),
                                                  (int, float)):
            fail(f"{w} ({e['name']!r}): span without numeric 'dur_us'")
        if e["kind"] == "metric" and not isinstance(e.get("delta"),
                                                    (int, float)):
            fail(f"{w} ({e['name']!r}): metric without numeric 'delta'")
    validate_metrics(doc.get("metrics", {}), f"{where}[metrics]")
    drift = doc.get("drift")
    if drift not in (None, {}):
        validate_drift_doc(drift, f"{where}[drift]")
    return len(events)


def validate_drift_doc(doc: dict, where: str) -> int:
    """A drift-auditor snapshot (embedded in dumps, or standalone)."""
    if not isinstance(doc, dict):
        fail(f"{where}: not an object")
    keys = doc.get("keys", {})
    if not isinstance(keys, dict):
        fail(f"{where}: 'keys' must be an object")
    for k, st in keys.items():
        w = f"{where}.keys[{k}]"
        if not isinstance(st, dict):
            fail(f"{w}: not an object")
        if not isinstance(st.get("n"), int) or st["n"] < 1:
            fail(f"{w}: bad sample count {st.get('n')!r}")
        if not isinstance(st.get("fired"), bool):
            fail(f"{w}: 'fired' must be a bool")
    ranking = doc.get("ranking", {})
    if not isinstance(ranking, dict):
        fail(f"{where}: 'ranking' must be an object")
    for k, f_ in ranking.items():
        w = f"{where}.ranking[{k}]"
        if not isinstance(f_, dict):
            fail(f"{w}: not an object")
        for field in ("measured_best", "predicted_best"):
            if not isinstance(f_.get(field), str):
                fail(f"{w}: missing '{field}'")
    return len(keys) + len(ranking)


def validate_flight(path: str) -> int:
    """A dump file, or a directory of flight-*.json dumps; returns the
    number of dump documents validated."""
    paths = [path]
    if os.path.isdir(path):
        paths = sorted(
            os.path.join(path, n) for n in os.listdir(path)
            if n.startswith("flight-") and n.endswith(".json"))
        if not paths:
            fail(f"{path}: directory holds no flight-*.json dumps")
    for p in paths:
        with open(p) as f:
            validate_flight_doc(json.load(f), p)
    return len(paths)


def validate_history(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, list):
        fail(f"{path}: history must be a list of run entries")
    for i, e in enumerate(doc):
        w = f"{path}[{i}]"
        if not isinstance(e, dict):
            fail(f"{w}: not an object")
        if not isinstance(e.get("t"), str) or not e["t"]:
            fail(f"{w}: missing timestamp 't'")
        if not isinstance(e.get("serve"), dict):
            fail(f"{w}: missing 'serve' headline dict")
        for field in ("recompiles", "drift"):
            if not isinstance(e.get(field), (int, float)):
                fail(f"{w}: missing numeric '{field}'")
    return len(doc)


# repro.serve.domains.JOURNAL_KINDS + the fields a replay needs per kind
# (stdlib-only mirror: this validator must not import the repro tree)
_JOURNAL_FIELDS = {
    "submit": ("rid", "prompt", "max_new", "temperature", "top_k", "stream"),
    "progress": ("rid", "tokens", "n"),
    "terminal": ("rid", "state"),
    "evacuate": ("rid", "host"),
    "shrink": ("frm", "to", "host"),
}


def validate_journal(path: str) -> int:
    """A scheduler journal: per-line checksum recompute + schema check.
    An empty journal (no traffic recorded) is valid; a torn or tampered
    line is a failure — CI uploads must verify, the lenient torn-tail
    recovery is the engine restart path's job, not the validator's."""
    import hashlib
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    for i, line in enumerate(lines):
        where = f"{path}:{i + 1}"
        try:
            rec = json.loads(line)
        except ValueError as e:
            fail(f"{where}: unparseable record ({e})")
        if not isinstance(rec, dict):
            fail(f"{where}: record is not an object")
        want = rec.pop("checksum", None)
        if not isinstance(want, str) or not want.startswith("sha256:"):
            fail(f"{where}: missing/malformed 'checksum'")
        blob = json.dumps(rec, sort_keys=True, separators=(",", ":"),
                          default=str)
        got = "sha256:" + hashlib.sha256(blob.encode()).hexdigest()
        if got != want:
            fail(f"{where}: checksum mismatch (journal tampered or torn)")
        kind = rec.get("kind")
        if kind not in _JOURNAL_FIELDS:
            fail(f"{where}: unknown record kind {kind!r}")
        for field in _JOURNAL_FIELDS[kind]:
            if field not in rec:
                fail(f"{where} ({kind}): missing field {field!r}")
    return len(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--bench", default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--flight", default=None,
                    help="flight-recorder dump file or directory of dumps")
    ap.add_argument("--history", default=None,
                    help="BENCH_history.json trajectory file")
    ap.add_argument("--journal", default=None,
                    help="scheduler journal (JSONL) to checksum-verify")
    args = ap.parse_args()
    if not (args.trace or args.metrics or args.bench or args.strategy
            or args.flight or args.history or args.journal):
        fail("nothing to validate: pass --trace/--metrics/--bench/"
             "--strategy/--flight/--history/--journal")
    if args.trace:
        n = validate_trace(args.trace)
        print(f"validate_trace: {args.trace}: {n} events OK")
    if args.metrics:
        with open(args.metrics) as f:
            n = validate_metrics(json.load(f), args.metrics)
        print(f"validate_trace: {args.metrics}: {n} metrics OK")
    if args.bench:
        n = validate_bench(args.bench)
        print(f"validate_trace: {args.bench}: embedded metrics "
              f"({n}) OK")
    if args.strategy:
        n = validate_strategy(args.strategy)
        print(f"validate_trace: {args.strategy}: {n} strategy trace"
              f"{'s' if n != 1 else ''} OK")
    if args.flight:
        n = validate_flight(args.flight)
        print(f"validate_trace: {args.flight}: {n} flight dump"
              f"{'s' if n != 1 else ''} OK")
    if args.history:
        n = validate_history(args.history)
        print(f"validate_trace: {args.history}: {n} history entr"
              f"{'ies' if n != 1 else 'y'} OK")
    if args.journal:
        n = validate_journal(args.journal)
        print(f"validate_trace: {args.journal}: {n} journal record"
              f"{'s' if n != 1 else ''} checksum-verified OK")


if __name__ == "__main__":
    main()
