"""The paper's benchmark ops (scal/asum/dot/gemv, section 7) + rmsnorm/matmul,
expressed as DPIA functional terms with TPU strategies and compiled through
the formal pipeline (Stage I -> II -> III).

Each op comes in two forms:
  * ``naive_*``    — the high-level specification (paper eq. (1) style);
  * ``strategy_*`` — a TPU-shaped strategy (paper eq. (2)/section 6.3 style):
    grid-blocked (`map[grid]` over `split`), whole-block VPU leaf ops (the
    lanes level), sequential combine.

Build functions return ``(expr, arg_vars)``; compile them through the staged
API — ``repro.compiler.Program(expr, arg_vars).check().lower()
.compile(backend)`` — or the deprecated ``compile_op`` shim.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.dpia import phrases as P
from repro.core.dpia.types import Arr, Num

Expr = P.Phrase


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def naive_scal(n: int) -> Tuple[Expr, List[P.Var]]:
    alpha = P.var_exp("alpha", Num())
    xs = P.var_exp("xs", Arr(n, Num()))
    e = P.Map(lambda x: P.mul(alpha, x), xs)
    return e, [alpha, xs]


def strategy_scal(n: int, block: int = 2048) -> Tuple[Expr, List[P.Var]]:
    alpha = P.var_exp("alpha", Num())
    xs = P.var_exp("xs", Arr(n, Num()))
    e = P.Join(P.Map(lambda blk: P.mul(alpha, blk),
                     P.Split(block, xs), level=P.GRID(0)))
    return e, [alpha, xs]


def wholeblock_scal(n: int) -> Tuple[Expr, List[P.Var]]:
    """Single whole-array VPU block op (one grid step) — the optimal strategy
    when the array fits one kernel invocation's streaming pass."""
    alpha = P.var_exp("alpha", Num())
    xs = P.var_exp("xs", Arr(n, Num()))
    e = P.Join(P.Map(lambda blk: P.mul(alpha, blk),
                     P.Split(n, xs), level=P.GRID(0)))
    return e, [alpha, xs]


def naive_asum(n: int) -> Tuple[Expr, List[P.Var]]:
    xs = P.var_exp("xs", Arr(n, Num()))
    e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                 P.Map(lambda x: P.UnOp("abs", x), xs))
    return e, [xs]


def strategy_asum(n: int, block: int = 2048) -> Tuple[Expr, List[P.Var]]:
    xs = P.var_exp("xs", Arr(n, Num()))
    partials = P.Map(lambda blk: P.FullReduce("add", P.UnOp("abs", blk)),
                     P.Split(block, xs), level=P.GRID(0))
    e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0), partials, level=P.SEQ)
    return e, [xs]


def naive_dot(n: int) -> Tuple[Expr, List[P.Var]]:
    xs = P.var_exp("xs", Arr(n, Num()))
    ys = P.var_exp("ys", Arr(n, Num()))
    e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0),
                 P.Map(lambda z: P.mul(P.Fst(z), P.Snd(z)), P.Zip(xs, ys)))
    return e, [xs, ys]


def strategy_dot(n: int, block: int = 2048) -> Tuple[Expr, List[P.Var]]:
    xs = P.var_exp("xs", Arr(n, Num()))
    ys = P.var_exp("ys", Arr(n, Num()))
    partials = P.Map(
        lambda blk: P.FullReduce("add", P.mul(P.Fst(blk), P.Snd(blk))),
        P.Split(block, P.Zip(xs, ys)), level=P.GRID(0))
    e = P.Reduce(lambda x, a: P.add(a, x), P.lit(0.0), partials, level=P.SEQ)
    return e, [xs, ys]


def mesh_dot(n: int, axis: str, nshards: int, block: int = 2048
             ) -> Tuple[Expr, List[P.Var]]:
    """Distributed dot: mesh-map partial dots + mesh-reduce (one all-reduce)."""
    xs = P.var_exp("xs", Arr(n, Num()))
    ys = P.var_exp("ys", Arr(n, Num()))
    chunk = n // nshards
    e = P.Reduce(
        lambda x, a: P.add(a, x), P.lit(0.0),
        P.Map(lambda blk: P.FullReduce(
            "add", P.mul(P.Fst(blk), P.Snd(blk))),
            P.Split(chunk, P.Zip(xs, ys)), level=P.MESH(axis)),
        level=P.MESH(axis))
    return e, [xs, ys]


def naive_gemv(m: int, n: int) -> Tuple[Expr, List[P.Var]]:
    a = P.var_exp("A", Arr(m, Arr(n, Num())))
    x = P.var_exp("x", Arr(n, Num()))
    e = P.Map(lambda row: P.Reduce(
        lambda z, acc: P.add(acc, z), P.lit(0.0),
        P.Map(lambda p: P.mul(P.Fst(p), P.Snd(p)), P.Zip(row, x))), a)
    return e, [a, x]


def strategy_gemv(m: int, n: int, row_block: int = 128
                  ) -> Tuple[Expr, List[P.Var]]:
    a = P.var_exp("A", Arr(m, Arr(n, Num())))
    x = P.var_exp("x", Arr(n, Num()))
    e = P.Join(P.Map(lambda rows: P.DotBlock(rows, x),
                     P.Split(row_block, a), level=P.GRID(0)))
    return e, [a, x]


def rmsnorm_row(d: int, eps: float, w: P.Var):
    """The per-row rmsnorm body both builders share: mean(x^2) -> rsqrt ->
    scale (whole-row VPU sum leaf)."""
    def per_row(row):
        ss = P.FullReduce("add", P.mul(row, row))
        inv = P.UnOp("rsqrt", P.add(P.div(ss, P.lit(float(d))), P.lit(eps)))
        return P.mul(P.mul(row, inv), w)
    return per_row


def naive_rmsnorm(rows: int, d: int, eps: float = 1e-6
                  ) -> Tuple[Expr, List[P.Var]]:
    """Row-wise rmsnorm spec: one map over rows, no blocking decided yet."""
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    w = P.var_exp("w", Arr(d, Num()))
    return P.Map(rmsnorm_row(d, eps, w), xs), [xs, w]


def strategy_rmsnorm(rows: int, d: int, eps: float = 1e-6,
                     row_block: int = 8) -> Tuple[Expr, List[P.Var]]:
    """Fused rmsnorm through DPIA: per row-block, mean(x^2) -> rsqrt -> scale."""
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    w = P.var_exp("w", Arr(d, Num()))
    e = P.Join(P.Map(
        lambda blk: P.Map(rmsnorm_row(d, eps, w), blk, level=P.SEQ),
        P.Split(row_block, xs), level=P.GRID(0)))
    return e, [xs, w]


def _softmax_row(row: Expr) -> Expr:
    """The one softmax spec both builders share: exp(x - max x) / sum."""
    mx = P.FullReduce("max", row)
    ex = P.UnOp("exp", P.sub(row, mx))
    return P.div(ex, P.FullReduce("add", ex))


def naive_softmax(rows: int, d: int) -> Tuple[Expr, List[P.Var]]:
    """Row softmax spec: per row, exp(x - max x) / sum exp(x - max x)."""
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    return P.Map(_softmax_row, xs), [xs]


def strategy_softmax(rows: int, d: int, row_block: int = 8
                     ) -> Tuple[Expr, List[P.Var]]:
    """Softmax with rmsnorm's strategy shape: grid over row blocks,
    sequential rows within a block, whole-row VPU max/sum leaves."""
    xs = P.var_exp("xs", Arr(rows, Arr(d, Num())))
    e = P.Join(P.Map(
        lambda blk: P.Map(_softmax_row, blk, level=P.SEQ),
        P.Split(row_block, xs), level=P.GRID(0)))
    return e, [xs]


def naive_matmul(m: int, k: int, n: int) -> Tuple[Expr, List[P.Var]]:
    """Matmul spec: per A row, per B^T column, a dot product — the blocking
    and MXU mapping are strategy decisions (``tile_matmul``), not spec."""
    a = P.var_exp("A", Arr(m, Arr(k, Num())))
    b = P.var_exp("B", Arr(k, Arr(n, Num())))
    e = P.Map(lambda row: P.Map(
        lambda col: P.Reduce(
            lambda q, acc: P.add(acc, q), P.lit(0.0),
            P.Map(lambda z: P.mul(P.Fst(z), P.Snd(z)), P.Zip(row, col))),
        P.Transpose(b)), a)
    return e, [a, b]


def strategy_matmul(m: int, k: int, n: int, bm: int = 128, bk: int = 128
                    ) -> Tuple[Expr, List[P.Var]]:
    """Blocked matmul: grid over row blocks, sequential MXU accumulation over
    k chunks (the canonical TPU matmul shape, in DPIA vocabulary) — the
    same term ``strategies.tile_matmul`` derives from ``naive_matmul``."""
    from repro.core.dpia.strategies import tiled_matmul_expr
    a = P.var_exp("A", Arr(m, Arr(k, Num())))
    b = P.var_exp("B", Arr(k, Arr(n, Num())))
    return tiled_matmul_expr(a, b, n, bm, bk), [a, b]


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def compile_op(expr: Expr, arg_vars, backend: str = "jnp", **kw):
    """Deprecated: compile via the staged API instead ::

        repro.compiler.Program(expr, arg_vars).check().lower() \\
            .compile(backend, jit=False)

    This shim delegates to the ``repro.compiler`` backend registry (raising
    ``ValueError`` with the registered names on an unknown backend) and
    returns the un-jitted callable, exactly as the seed did."""
    import warnings
    warnings.warn(
        "dpia_blas.compile_op is deprecated; use repro.compiler.Program("
        "expr, arg_vars).check().lower().compile(backend)",
        DeprecationWarning, stacklevel=2)
    from repro.compiler import get_backend
    return get_backend(backend).compile(expr, arg_vars, **kw)
