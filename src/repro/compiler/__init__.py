"""repro.compiler — the paper's staged pipeline as a first-class API.

The paper's claim is a *staged, strategy-preserving* compilation chain:

    functional term --rewrites--> strategy --Stage I/II--> race-free
    imperative DPIA --Stage III--> backend code

This package makes that chain the public product instead of hiding it
behind stringly-typed dispatch and process globals:

  backends — registry of Stage III targets (``jnp`` / ``pallas`` /
             ``shardmap`` self-register; user backends plug in the same way)
  options  — :class:`CompileOptions` threaded explicitly + thread-local
             ``with compiler.options(...):`` scoping (replaces
             ``ops.set_default_impl`` / ``ops.set_autotune`` globals)
  program  — :class:`Program` with the staged fluent API
             ``check()`` -> ``lower(strategy)`` -> ``compile(backend)``

Quick use::

    from repro import compiler

    prog = compiler.Program.from_kernel("dot", n=8192)
    fn = prog.check().lower("autotune").compile("pallas")
    y = fn(xs, ys)

    with compiler.options(backend="dpia-pallas", autotune=False):
        y = repro.kernels.ops.matmul(a, b)     # scoped, thread-local

See docs/compiler.md for the walkthrough (including writing a custom
backend).
"""
# NOTE: import order matters — ``backends`` and ``options`` must be bound
# before ``program`` pulls in repro.core.dpia, whose stage3 modules import
# repro.compiler.backends back to self-register.
from . import backends, options as _options_mod  # noqa: F401
from .backends import (  # noqa: F401
    Backend, backend_names, get_backend, ops_impls, register_backend,
    unregister_backend,
)
from .options import (  # noqa: F401
    CompileOptions, current_options, default_interpret, default_options,
    options, set_default_options,
)
from .program import CompiledKernel, Program, program  # noqa: F401
from . import executors, serialize  # noqa: F401
from .executors import ExecutorCache  # noqa: F401
from .executors import default_cache as executor_cache  # noqa: F401

__all__ = [
    "Backend", "backend_names", "get_backend", "ops_impls",
    "register_backend", "unregister_backend",
    "CompileOptions", "options", "current_options", "default_options",
    "set_default_options", "default_interpret",
    "Program", "CompiledKernel", "program",
    "ExecutorCache", "executor_cache", "executors", "serialize",
]
