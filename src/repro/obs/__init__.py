"""repro.obs — tracing, metrics, and strategy provenance for the spine.

The compiler's claim ("the chosen strategy is preserved end to end") and
the serving engines' invariants ("token-identical, zero recompiles after
warm-up") are asserted by tests; this package makes them *observable* in
any run:

  trace       span tracer (thread-local stacks, monotonic clocks,
              near-zero overhead disabled) with Chrome/Perfetto JSON
              export — ``obs.enable()``, ``with obs.span("name"): ...``,
              ``obs.export_trace("trace.json")``, load in
              https://ui.perfetto.dev
  metrics     always-on process registry of counters / gauges /
              histograms — ``obs.counter("x").inc()``,
              ``obs.metrics_snapshot()``
  provenance  a record per tuned decision (kernel strategy, mesh
              placement, KV layout): inputs, predicted roofline terms,
              measured time, cache origin — ``print(obs.explain())``

The instrumented spine: ``Program.check/lower/compile`` spans, executor
cache build/hit/AOT events, autotune enumeration + measurement spans,
serving per-chunk spans, per-request lifecycle metrics (queue wait, TTFT,
decode tok/s), KV pool occupancy gauges, and a recompile detector that
flags jit-cache growth after engine warm-up.  ``Engine.stats()`` is the
one-call summary.  See docs/observability.md.

Tracing defaults off; enable programmatically or with ``REPRO_TRACE=1``
(a path value also exports at exit).  Metrics and provenance are always
on — they only run at boundaries (tuning, staging, chunk edges), never in
a hot loop.
"""
from __future__ import annotations

from . import metrics, provenance, trace  # noqa: F401
from .metrics import (  # noqa: F401
    MetricsRegistry, counter, gauge, histogram, registry,
)
from .metrics import export as export_metrics  # noqa: F401
from .metrics import reset as metrics_reset  # noqa: F401
from .metrics import snapshot as metrics_snapshot  # noqa: F401
from .provenance import (  # noqa: F401
    Decision, ProvenanceLog, decisions, explain, record,
)
from .provenance import clear as clear_decisions  # noqa: F401
from .provenance import log as provenance_log  # noqa: F401
from .trace import (  # noqa: F401
    Tracer, disable, enable, enabled, instant, span, to_chrome, traced,
    tracer,
)
from .trace import clear as clear_trace  # noqa: F401
from .trace import events as trace_events  # noqa: F401
from .trace import export as export_trace  # noqa: F401

# ``instant`` under its semantic alias: a structured point event
event = instant

__all__ = [
    # tracing
    "Tracer", "tracer", "enable", "disable", "enabled", "span", "traced",
    "instant", "event", "trace_events", "clear_trace", "to_chrome",
    "export_trace",
    # metrics
    "MetricsRegistry", "registry", "counter", "gauge", "histogram",
    "metrics_snapshot", "metrics_reset", "export_metrics",
    # provenance
    "Decision", "ProvenanceLog", "record", "decisions", "explain",
    "clear_decisions", "provenance_log",
    "metrics", "provenance", "trace",
]
