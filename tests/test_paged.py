"""Paged KV-cache + chunked prefill tests: block-pool accounting, paged ==
dense token identity across model families, chunked == single-call prefill
identity, recompile discipline, and the KV-layout planner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import BatchedEngine, ContinuousEngine, Request
from repro.serve.paged import (BlockPool, blocks_for, dense_kv_bytes,
                               paged_kv_bytes, table_row)


def tiny_cfg(**kw):
    base = dict(name="paged-t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, dtype="float32",
                remat=False, max_seq=64)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_model():
    cfg = tiny_cfg()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def mixed_requests(cfg, n=6, key=None):
    key = key if key is not None else jax.random.PRNGKey(5)
    temps = [0.0, 0.9, 0.0, 1.3, 0.7, 0.0]
    top_ks = [0, 5, 0, 0, 3, 0]
    return [Request(
        prompt=jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (5 + 3 * i,), 0, cfg.vocab),
        max_new_tokens=4 + 3 * i, temperature=temps[i % 6],
        top_k=top_ks[i % 6]) for i in range(n)]


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_blocks_for(self):
        assert blocks_for(1, 16) == 1
        assert blocks_for(16, 16) == 1
        assert blocks_for(17, 16) == 2
        assert blocks_for(0, 16) == 1          # a slot always holds a page

    def test_alloc_free_roundtrip(self):
        pool = BlockPool(8, 16)
        a = pool.alloc("a", 3)
        b = pool.alloc("b", 2)
        assert a == [0, 1, 2] and b == [3, 4]  # deterministic ascending
        assert pool.free_blocks == 3 and pool.used_blocks == 5
        assert pool.free("a") == 3
        assert pool.free_blocks == 6
        # freed pages are reused first (LIFO), still deterministic
        assert pool.alloc("c", 1) == [0]
        assert pool.free("b") == 2 and pool.free("c") == 1
        assert pool.free_blocks == 8

    def test_exhaustion_raises_and_free_is_idempotent(self):
        pool = BlockPool(2, 16)
        pool.alloc("a", 2)
        assert not pool.can_alloc(1)
        with pytest.raises(ValueError):
            pool.alloc("b", 1)
        assert pool.free("a") == 2
        assert pool.free("a") == 0             # double-free: no-op

    def test_table_row_sentinel_padding(self):
        assert table_row([4, 7], 4, sentinel=9) == [4, 7, 9, 9]
        with pytest.raises(ValueError):
            table_row([1, 2, 3], 2, sentinel=9)

    def test_byte_accounting_family_aware(self):
        cfg = tiny_cfg()
        dense = dense_kv_bytes(cfg, slots=4, max_seq=64)
        assert dense == 2 * 2 * 4 * 64 * 2 * 8 * 4  # 2kv*L*slots*seq*nkv*hd*4B
        assert paged_kv_bytes(cfg, n_blocks=8, block_size=16) < dense
        ssm = tiny_cfg(family="ssm", name="paged-ssm")
        assert dense_kv_bytes(ssm, 4, 64) == 0   # no KV cache at all


# ---------------------------------------------------------------------------
# paged engine == dense oracle, all families
# ---------------------------------------------------------------------------

class TestPagedVsDense:
    def test_token_identical_dense_family(self, dense_model):
        """Mixed lengths/budgets/temperatures, fewer slots than requests:
        the paged engine must be token-identical to the dense oracle, with
        one decode compile and zero recompiles across reuse."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(7)
        reqs = mixed_requests(cfg)
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        paged = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                 min_bucket=8, kv_layout="paged",
                                 block_size=16)
        assert paged.run(reqs, key=key) == oracle
        assert paged.run(reqs, key=key) == oracle      # engine reuse
        assert paged.decode_cache_misses() == 1

    def test_token_identical_reordered_traffic(self, dense_model):
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(3)
        reqs = [r for r in mixed_requests(cfg) if r.temperature == 0.0]
        paged = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                 min_bucket=8, kv_layout="paged")
        a = paged.run(reqs, key=key)
        b = paged.run(list(reversed(reqs)), key=key)
        assert a == list(reversed(b))

    @pytest.mark.parametrize("name", ["rwkv6-1.6b", "zamba2-2.7b"])
    def test_token_identical_recurrent_families(self, name):
        """ssm (no KV at all) and hybrid (paged shared-attention KV +
        slot-indexed mamba state) both stay token-identical."""
        from repro.configs import smoke_config
        cfg = smoke_config(name)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(9)
        reqs = [Request(
            prompt=jax.random.randint(jax.random.fold_in(key, 40 + i),
                                      (3 + 4 * i,), 0, cfg.vocab),
            max_new_tokens=4 + 2 * i,
            temperature=(0.8 if i == 1 else 0.0)) for i in range(3)]
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        paged = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                 min_bucket=8, kv_layout="paged",
                                 block_size=16)
        assert paged.run(reqs, key=key) == oracle
        assert paged.decode_cache_misses() == 1

    def test_no_block_leak_across_cycles(self, dense_model):
        """Free-block count returns to initial after N admit/retire cycles,
        and the staging/bookkeeping dicts drain."""
        cfg, model, params = dense_model
        paged = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                 min_bucket=8, kv_layout="paged")
        init_free = paged.pool.free_blocks
        for k in range(3):
            paged.run(mixed_requests(cfg, n=4), key=jax.random.PRNGKey(k))
        assert paged.pool.free_blocks == init_free
        assert paged.pool.used_blocks == 0
        assert paged._staging == {} and paged._admit_logits == {}
        assert paged._requests == {} and paged.sched.outputs == {}

    def test_block_starved_pool_defers_but_stays_identical(self, dense_model):
        """A pool that can only hold one request span at a time serialises
        admissions (FIFO, no head-of-line skipping) — throughput policy,
        never tokens."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(7)
        reqs = mixed_requests(cfg, n=4)
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        starved = ContinuousEngine(model, params, max_seq=64, slots=2,
                                   chunk=4, min_bucket=8, kv_layout="paged",
                                   block_size=16, kv_blocks=4)
        assert starved.run(reqs, key=key) == oracle
        assert starved.pool.free_blocks == 4

    def test_oversized_request_rejected_up_front(self, dense_model):
        cfg, model, params = dense_model
        paged = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                 min_bucket=8, kv_layout="paged",
                                 block_size=16, kv_blocks=2)
        with pytest.raises(ValueError, match="KV blocks"):
            paged.submit(Request(prompt=jnp.arange(40) % cfg.vocab,
                                 max_new_tokens=8))

    def test_block_size_must_divide_max_seq(self, dense_model):
        cfg, model, params = dense_model
        with pytest.raises(ValueError, match="divide"):
            ContinuousEngine(model, params, max_seq=60, slots=2,
                             kv_layout="paged", block_size=16)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

class TestChunkedPrefill:
    def _long_reqs(self, cfg, key):
        return [Request(prompt=jax.random.randint(
                    jax.random.fold_in(key, 70 + i), (29 + 12 * i,), 0,
                    cfg.vocab),
                        max_new_tokens=5,
                        temperature=(1.1 if i == 1 else 0.0),
                        top_k=(4 if i == 1 else 0)) for i in range(2)]

    @pytest.mark.parametrize("kv_layout", ["dense", "paged"])
    def test_chunked_equals_single_call(self, dense_model, kv_layout):
        """Prompts longer than ``prefill_chunk`` are split across chunk
        boundaries; tokens must match the single-call oracle exactly."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(13)
        reqs = self._long_reqs(cfg, key)
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout=kv_layout,
                               prefill_chunk=16)
        assert eng.buckets[-1] == 16           # big buckets are GONE
        assert eng.run(reqs, key=key) == oracle

    @pytest.mark.parametrize("name", ["rwkv6-1.6b", "zamba2-2.7b"])
    def test_chunked_recurrent_families(self, name):
        """Chunked prefill carries the recurrent state (conv tail, wkv/ssm
        state) across chunk boundaries bitwise."""
        from repro.configs import smoke_config
        cfg = smoke_config(name)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(21)
        reqs = [Request(prompt=jax.random.randint(
                    jax.random.fold_in(key, 3), (27,), 0, cfg.vocab),
                        max_new_tokens=6)]
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout="paged",
                               prefill_chunk=8)
        assert eng.run(reqs, key=key) == oracle

    def test_tail_bucket_overrun_does_not_corrupt(self, dense_model):
        """A tail chunk whose padded bucket overruns max_seq (non-power-of-
        two max_seq: 97-token prompt, chunks 64+64-padded into a 100-wide
        cache) must DROP the out-of-range rows — regression test for the
        dynamic_update_slice clamp that silently clobbered positions
        36..63."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(17)
        reqs = [Request(prompt=jax.random.randint(jax.random.PRNGKey(4),
                                                  (97,), 0, cfg.vocab),
                        max_new_tokens=3)]
        oracle = BatchedEngine(model, params, max_seq=100,
                               chunk=4).run(reqs, key=key)
        eng = ContinuousEngine(model, params, max_seq=100, slots=2, chunk=4,
                               min_bucket=16, prefill_chunk=64)
        assert eng.run(reqs, key=key) == oracle

    def test_zero_recompiles_after_chunked_warmup(self, dense_model):
        """One warm pass over short + long prompts closes the executable
        set: further long-prompt traffic hits the caches exactly."""
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout="paged",
                               prefill_chunk=16)
        key = jax.random.PRNGKey(0)
        # the executable set is (bucket x first/continuation): warm every
        # bucket in both roles (21 = 16-chunk + 5-tail covers 8-cont)
        warm = [Request(prompt=jnp.arange(n) % cfg.vocab, max_new_tokens=3)
                for n in (5, 12, 21, 29, 47)]
        eng.run(warm, key=key)
        decode0 = eng.decode_cache_misses()
        prefill0 = eng.prefill_cache_size()
        traffic = [Request(prompt=jnp.arange(7 * i + 3) % cfg.vocab,
                           max_new_tokens=2 + i, temperature=0.3 * i)
                   for i in range(1, 8)]
        eng.run(traffic, key=jax.random.PRNGKey(1))
        assert eng.decode_cache_misses() == decode0 == 1
        assert eng.prefill_cache_size() == prefill0


# ---------------------------------------------------------------------------
# model level: paged decode is bitwise the dense computation
# ---------------------------------------------------------------------------

class TestPagedModelLevel:
    def test_paged_decode_bitwise_equals_dense(self, dense_model):
        cfg, model, params = dense_model
        max_seq, bs = 64, 16
        p = jax.random.randint(jax.random.PRNGKey(2), (10,), 0, cfg.vocab)
        lg_d, dense = model.prefill(params, p[None],
                                    model.init_cache(1, max_seq))
        paged_cache = model.init_paged_cache(1, max_seq, n_blocks=6,
                                             block_size=bs)
        kv, st = model.split_paged_cache(paged_cache)
        bt_row = jnp.arange(4, dtype=jnp.int32)
        lg_p, kv, st = model.prefill_paged(params, p[None], kv, bt_row,
                                           model.init_prefill_state(1),
                                           0, jnp.asarray([10]), first=True)
        np.testing.assert_array_equal(np.asarray(lg_d), np.asarray(lg_p))
        cache_p = model.merge_paged_cache(kv, st)
        tok = jnp.argmax(lg_d, -1)[:, None]
        pos = jnp.asarray([10], jnp.int32)
        for _ in range(3):
            ld, dense = model.decode_step(params, tok, dense, pos)
            lp, cache_p = model.decode_step(params, tok, cache_p, pos,
                                            block_tables=bt_row[None])
            np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
            tok = jnp.argmax(ld, -1)[:, None]
            pos = pos + 1

    def test_out_of_table_write_drops(self, dense_model):
        """A lane parked past max_seq maps to the sentinel page: the pool
        is untouched (the paged twin of the dense mode='drop' scatter)."""
        cfg, model, params = dense_model
        paged_cache = model.init_paged_cache(1, 64, n_blocks=4,
                                             block_size=16)
        kv, _ = model.split_paged_cache(paged_cache)
        before = np.asarray(kv.k).copy()
        tok = jnp.zeros((1, 1), jnp.int32)
        bt = jnp.full((1, 4), 4, jnp.int32)    # all-sentinel table
        _, cache2 = model.decode_step(params, tok, paged_cache,
                                      jnp.asarray([64], jnp.int32),
                                      block_tables=bt)
        kv2, _ = model.split_paged_cache(cache2)
        np.testing.assert_array_equal(before, np.asarray(kv2.k))


# ---------------------------------------------------------------------------
# the KV-layout planner + per-platform HW presets
# ---------------------------------------------------------------------------

class TestKvLayoutPlanner:
    def test_presets_exist_per_platform(self):
        from repro.autotune import HW_PRESETS, hw_model
        assert set(HW_PRESETS) == {"cpu", "gpu", "tpu"}
        assert hw_model("cpu").hbm_bw < hw_model("gpu").hbm_bw
        assert hw_model("no-such-platform") is hw_model("tpu")
        assert hw_model() in HW_PRESETS.values()

    def test_paged_shrinks_resident_never_traffic(self):
        from repro.autotune import kv_layout_cost
        kw = dict(slots=8, max_seq=4096, kv_heads=8, head_dim=128, layers=32,
                  dtype_bytes=2, block_size=16, expected_seq=512)
        dense = kv_layout_cost("dense", **kw)
        paged = kv_layout_cost("paged", **kw)
        assert paged.resident_bytes < dense.resident_bytes / 2
        assert paged.step_hbm_bytes >= dense.step_hbm_bytes

    def test_picks_dense_small_paged_huge(self, dense_model, tmp_path):
        from repro import autotune
        cfg, _, _ = dense_model
        cpath = str(tmp_path / "plan.json")
        small = autotune.pick_kv_layout(cfg, slots=2, max_seq=64,
                                        platform="tpu", cache=cpath)
        assert small["layout"] == "dense"
        big_cfg = tiny_cfg(name="paged-big", n_layers=32, d_model=4096,
                           n_heads=32, n_kv_heads=8, max_seq=131072)
        big = autotune.pick_kv_layout(big_cfg, slots=256, max_seq=131072,
                                      expected_seq=4096, platform="tpu",
                                      cache=cpath)
        assert big["layout"] == "paged"
        assert big["dense_bytes"] > big["paged_bytes"]

    def test_decision_is_cached(self, dense_model, tmp_path):
        from repro import autotune
        cfg, _, _ = dense_model
        cpath = str(tmp_path / "tune.json")
        a = autotune.pick_kv_layout(cfg, slots=2, max_seq=64,
                                    platform="tpu", cache=cpath)
        b = autotune.pick_kv_layout(cfg, slots=2, max_seq=64,
                                    platform="tpu", cache=cpath)
        assert a == b
        cache = autotune.TuningCache(cpath)
        assert any(k.startswith("kv_layout|") for k in cache.keys())

    def test_auto_engine_resolves_layout(self, dense_model, tmp_path):
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8, kv_layout="auto",
                               tuning_cache=str(tmp_path / "t.json"),
                               aot=False)
        assert eng.kv_layout in ("dense", "paged")


# ---------------------------------------------------------------------------
# layout as a cache-key dimension
# ---------------------------------------------------------------------------

class TestLayoutKeys:
    def test_executor_key_carries_layout(self):
        from repro.compiler import executors
        k_dense = executors.make_key("matmul", {"m": 8, "k": 8, "n": 8},
                                     "jnp")
        k_paged = executors.make_key("matmul", {"m": 8, "k": 8, "n": 8},
                                     "jnp", layout="paged")
        assert k_dense != k_paged and "|paged|" in k_paged

    def test_tuning_key_layout_only_when_non_default(self):
        from repro.autotune import cache as cache_mod
        base = cache_mod.make_key("dot", {"n": 64})
        assert "layout" not in base            # pre-paged keys unchanged
        paged = cache_mod.make_key("dot", {"n": 64}, layout="paged")
        assert paged == base + "|layout=paged"

    def test_options_validate_kv_layout(self):
        from repro import compiler
        with compiler.options(kv_layout="paged") as o:
            assert o.kv_layout == "paged"
        with pytest.raises(ValueError):
            compiler.CompileOptions(kv_layout="ragged")
