"""Functional reference semantics of DPIA expressions (paper section 5.2).

``interp(E, env)`` is the denotation [[E]] used as the oracle for translation
correctness (Theorem 5.1 as an executable property).  Values are pytrees:

  * ``Arr(n, d)``   -> leading axis of size n on every leaf
  * ``Pair(a, b)``  -> python 2-tuple (struct-of-arrays)
  * ``Vec(w, dt)``  -> trailing lane axis of size w
  * ``Num/Idx``     -> scalar jnp arrays

The interpreter is trace-compatible: it can run under jit/vmap, which is how
``map`` is given its parallel semantics here (vmap = the mathematical reading).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import phrases as P
from .types import Arr, ExpT, Num, Pair, dtype_of, shape_of

Env = Dict[str, object]

_UNOPS: Dict[str, Callable] = {
    "neg": lambda x: -x,
    "exp": jnp.exp,
    "log": jnp.log,
    "abs": jnp.abs,
    "rsqrt": jax.lax.rsqrt,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}

_BINOPS: Dict[str, Callable] = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
    "div": jnp.divide,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def interp(p: P.Phrase, env: Env, store: Optional[Env] = None):  # noqa: C901
    """Denotation of a functional expression phrase.

    ``store`` optionally resolves ``ExpPart`` reads of imperative variables —
    used when the same evaluator serves as the expression (r-value) evaluator
    of the imperative backend (paper Fig. 6c).
    """
    rec = lambda q: interp(q, env, store)  # noqa: E731

    if isinstance(p, P.Var):
        try:
            return env[p.name]
        except KeyError:
            raise NameError(f"unbound DPIA variable {p.name!r}") from None
    if isinstance(p, P.ExpPart):
        v = p.v
        if isinstance(v, P.VView):
            return rec(v.exp)
        assert isinstance(v, P.Var), "ExpPart of non-variable"
        src = store if store is not None and v.name in store else env
        return src[v.name]
    if isinstance(p, P.Lit):
        shp = shape_of(p.d)
        if shp:
            return jnp.full(shp, p.value, dtype=dtype_of(p.d))
        return jnp.asarray(p.value, dtype=dtype_of(p.d))
    if isinstance(p, P.UnOp):
        return _UNOPS[p.op](rec(p.e))
    if isinstance(p, P.BinOp):
        return _BINOPS[p.op](rec(p.a), rec(p.b))
    if isinstance(p, P.Map):
        xs = rec(p.e)
        d = P.exp_data(p.e)
        assert isinstance(d, Arr)
        x = P.Var(P.fresh("x"), ExpT(d.elem))
        body = p.f(x)

        def apply_elem(xv):
            return interp(body, {**env, x.name: xv}, store)

        return jax.vmap(apply_elem)(xs)
    if isinstance(p, P.Reduce):
        xs = rec(p.e)
        init = rec(p.init)
        d = P.exp_data(p.e)
        assert isinstance(d, Arr)
        x = P.Var(P.fresh("x"), ExpT(d.elem))
        acc = P.Var(P.fresh("acc"), P.type_of(p.init))
        body = p.f(x, acc)

        def step(carry, xv):
            out = interp(body, {**env, x.name: xv, acc.name: carry}, store)
            return out, None

        final, _ = jax.lax.scan(step, init, xs)
        return final
    if isinstance(p, P.Zip):
        return (rec(p.a), rec(p.b))
    if isinstance(p, P.Split):
        v = rec(p.e)
        return jax.tree_util.tree_map(
            lambda l: l.reshape((l.shape[0] // p.n, p.n) + l.shape[1:]), v)
    if isinstance(p, P.Join):
        v = rec(p.e)
        return jax.tree_util.tree_map(
            lambda l: l.reshape((l.shape[0] * l.shape[1],) + l.shape[2:]), v)
    if isinstance(p, P.PairE):
        return (rec(p.a), rec(p.b))
    if isinstance(p, P.Fst):
        return rec(p.e)[0]
    if isinstance(p, P.Snd):
        return rec(p.e)[1]
    if isinstance(p, P.IdxE):
        v = rec(p.e)
        i = rec(p.i)
        return jax.tree_util.tree_map(lambda l: l[i], v)
    if isinstance(p, P.AsVector):
        v = rec(p.e)
        return v.reshape((v.shape[0] // p.w, p.w))
    if isinstance(p, P.AsScalar):
        v = rec(p.e)
        return v.reshape((v.shape[0] * v.shape[1],))
    if isinstance(p, P.Transpose):
        v = rec(p.e)
        return jax.tree_util.tree_map(lambda l: jnp.swapaxes(l, 0, 1), v)
    if isinstance(p, P.DotBlock):
        a, b = rec(p.a), rec(p.b)
        return jnp.matmul(a, b, preferred_element_type=p.acc_dtype)
    if isinstance(p, P.FullReduce):
        v = rec(p.e)
        return jnp.sum(v) if p.op == "add" else jnp.max(v)
    if isinstance(p, P.ToMem):
        return rec(p.e)
    raise TypeError(f"interp: not a functional expression: {type(p).__name__}")


def interp_fn(expr: P.Phrase, arg_vars):
    """Close an expression over named argument Vars -> python callable."""
    names = [v.name for v in arg_vars]

    def fn(*vals):
        return interp(expr, dict(zip(names, vals)))

    return fn
