"""Public kernel API used by the model zoo.

Every op has interchangeable implementations, selected per call (``impl=``),
per scope (``with repro.compiler.options(backend=...):``, thread-local), or
per explicit ``options=repro.compiler.CompileOptions(...)``:

  'xla'           — plain jnp (XLA fuses/lowers; default for dry-run & CPU)
  'pallas'        — hand-written Pallas kernel (TPU target; interpret on CPU)
  'dpia-jnp'      — DPIA strategy compiled through the formal pipeline, jnp
  'dpia-pallas'   — DPIA strategy compiled to Pallas kernels
  'dpia-shardmap' — mesh-level DPIA strategy (repro.mesh) compiled to
                    shard_map + collectives; the mesh comes from
                    ``options(mesh=...)`` or the process mesh context, and
                    ops fall back to the single-device dpia-jnp path (with
                    a one-shot warning) when no mesh axis fits

Dispatch is table-driven: each op registers one handler per impl name, so
the impl matrix is *data* (``_OP_IMPLS``) derived from the
``repro.compiler`` backend registry, not if/elif chains.  The DPIA paths are
thin wrappers over cached ``repro.compiler.Program``s — every compiled
kernel goes through ``Program.check().lower().compile(backend)`` and is
memoised keyed by (kernel, shape, strategy params, CompileOptions bits).

Strategy parameters (block/tile sizes, reduce leaves) for the DPIA paths are
chosen by the ``repro.autotune`` cost model per shape/backend and remembered
in its persistent cache; ``options(autotune=False)`` (or the deprecated
``set_autotune(False)``) restores the hard-coded defaults.

``set_default_impl`` / ``set_autotune`` remain as deprecation shims that
delegate to ``repro.compiler.set_default_options``.
"""
from __future__ import annotations

import logging
import threading
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro import compiler, obs
from repro.compiler import CompileOptions, current_options
from repro.compiler import executors as _executors

log = logging.getLogger("repro.kernels.ops")

from . import dpia_blas, ref
from .flash_attention import flash_attention as _fa_pallas
from .matmul import matmul as _mm_pallas
from .rmsnorm import rmsnorm as _rms_pallas

# ---------------------------------------------------------------------------
# table-driven dispatch
# ---------------------------------------------------------------------------

_OP_IMPLS: Dict[str, Dict[str, Callable]] = {}


def _impl_handler(op: str, *impls: str):
    """Register a handler for ``op`` under the given impl names."""
    def deco(fn):
        table = _OP_IMPLS.setdefault(op, {})
        for name in impls:
            table[name] = fn
        return fn
    return deco


def _dispatch(op: str, impl: Optional[str], options: Optional[CompileOptions],
              *args, **kw):
    opts = options if options is not None else current_options()
    name = impl or opts.backend
    table = _OP_IMPLS[op]
    fn = table.get(name)
    if fn is None and name.startswith("dpia-") and name in compiler.ops_impls():
        # a user-registered Stage III backend: the DPIA handlers are
        # backend-generic (they derive the backend from the impl name), so
        # any op's 'dpia-jnp' handler serves every 'dpia-<registered>' impl
        fn = table.get("dpia-jnp")
    if fn is None:
        raise ValueError(f"{op}: unknown impl {name!r}; valid backends: "
                         f"{list(compiler.ops_impls())}")
    return fn(name, opts, *args, **kw)


def _dpia_backend(impl: str) -> str:
    return impl[len("dpia-"):]


# ---------------------------------------------------------------------------
# compiled-executor cache + tuned-params lookup
# ---------------------------------------------------------------------------

_tuned_memo: Dict[Tuple, Optional[dict]] = {}
_warned: set = set()
_LOCK = threading.Lock()


def clear_caches() -> None:
    """Drop compiled-executor/tuned-params memos (and one-shot warn state)."""
    compiler.executor_cache().clear()
    _tuned_memo.clear()
    _warned.clear()


def _warn_once(key: Tuple, msg: str) -> None:
    """One-shot degradation signal, emitted three ways: a structured obs
    event + always-on counter (machine-readable: dashboards, the bench's
    metrics snapshot), the module logger (operator logs), and the original
    ``RuntimeWarning`` (back-compat: tests and callers that filter
    warnings keep working).  The counter/logger fire even when the warning
    has already been shown — the *event stream* should see every
    occurrence; only the warning is once-per-key."""
    obs.counter("kernels.fallbacks").inc()
    obs.event("kernels.fallback", kind=str(key[0]),
              key="/".join(str(k) for k in key), msg=msg)
    with _LOCK:
        if key in _warned:
            return
        _warned.add(key)
    log.warning("%s", msg)
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def _cache_token(cache) -> str:
    if cache is None:
        return "<default>"
    path = getattr(cache, "path", None)
    return str(path) if path is not None else str(cache)


def _tuned(kernel: str, backend: str, opts: CompileOptions,
           **shape) -> Optional[dict]:
    """Tuned params for the kernel at this shape, or None (use defaults).

    Steady state is one dict lookup (per-process memo); a cold shape costs
    one analytic ranking pass via the tuner's persistent cache.  The lookup
    passes the *actual* mesh descriptor (``opts.mesh_descriptor()``), so
    params tuned on one mesh are never silently shared with another.  A
    failing lookup falls back to the defaults *and warns once per
    kernel/backend* — a broken tuning cache should be diagnosable, not an
    invisible perf regression."""
    if not opts.autotune:
        return None
    mesh_desc = opts.mesh_descriptor()
    memo_key = (kernel, backend, mesh_desc, opts.kv_layout,
                _cache_token(opts.tuning_cache),
                tuple(sorted(shape.items())))
    if memo_key in _tuned_memo:
        return _tuned_memo[memo_key]
    from repro import autotune
    try:
        params = autotune.get_tuned(kernel, backend=backend, mesh=mesh_desc,
                                    cache=opts.tuning_cache,
                                    layout=opts.kv_layout, **shape)
    except Exception as e:  # never let tuning break the op itself
        params = None
        _warn_once(("tune", kernel, backend),
                   f"autotune lookup failed for {kernel!r} (backend "
                   f"{backend!r}): {type(e).__name__}: {e}; using the "
                   f"default strategy params")
    _tuned_memo[memo_key] = params
    return params


def _compiled(kernel: str, shape: Dict[str, int],
              params: Optional[Dict[str, object]], builder, backend: str,
              opts: CompileOptions) -> compiler.CompiledKernel:
    """Build-and-memoise ``Program.check().lower().compile(backend)`` in the
    process-wide executor cache (``repro.compiler.executor_cache``).

    Steady state is one dict lookup on the canonical
    ``(kernel, shape, dtype, backend, params, options)`` key — the staged
    pipeline runs at most once per key per process, and a key pre-populated
    from the AOT store never stages at all."""
    key = _executors.make_key(kernel, shape, backend, params=params,
                              layout=opts.kv_layout,
                              interpret=bool(opts.interpret),
                              jit=bool(opts.jit))

    def build():
        # builders may return a ready Program (candidate path — carries its
        # strategy_trace) or the bare (expr, arg_vars) tuple
        built = builder()
        if isinstance(built, compiler.Program):
            prog = built
            prog.kernel = prog.kernel or kernel
            prog.shape = dict(prog.shape or shape)
        else:
            expr, arg_vars = built
            prog = compiler.Program(expr, arg_vars, name=kernel,
                                    kernel=kernel, shape=shape)
        return prog.check().lower().compile(backend, options=opts)

    return compiler.executor_cache().get_or_compile(
        key, build, meta={"interpret": bool(opts.interpret),
                          "jit": bool(opts.jit)})


def _default_params(kernel: str, **shape) -> Dict[str, object]:
    """The kernel's canonical un-tuned strategy params — one source of
    truth (autotune.space.default_params), shared with Program.from_kernel
    and the benchmarks' 'default' rows so they cannot drift."""
    from repro.autotune import space as _sp
    return _sp.default_params(kernel, **shape)


def _cand_program(kernel: str, params: Dict[str, object], **shape):
    """Builder for :func:`_compiled`: the candidate's Program, with the
    strategy derivation (``strategy_trace``) riding along into the executor
    and the AOT store."""
    from repro.autotune import space as _sp
    return _sp.candidate_from_params(kernel, dict(params), **shape).program()


def _record_default(kernel: str, backend: str, opts: CompileOptions,
                    shape: Dict[str, int], origin: str, note: str) -> None:
    """Provenance for the paths that DON'T go through the tuner: the
    kernel ran its canonical default strategy, and `obs.explain()` should
    say so (and why) rather than show a hole."""
    from repro.autotune import cache as _tc
    try:
        params = _default_params(kernel, **shape)
    except Exception:
        params = {}
    key = _tc.make_key(kernel, shape, "float32", backend,
                       opts.mesh_descriptor(), layout=opts.kv_layout)
    obs.record("kernel", kernel, key, params, origin, shape=dict(shape),
               backend=backend, mesh=opts.mesh_descriptor(),
               layout=opts.kv_layout, note=note)


def _compiled_or_reference(kernel: str, shape: Dict[str, int],
                           params: Optional[Dict[str, object]], builder,
                           backend: str, opts: CompileOptions
                           ) -> compiler.CompiledKernel:
    """The backend rung of the degradation ladder (docs/resilience.md).

    Builds the executor for ``backend``; when staging/compilation fails —
    a broken Pallas lowering, a failed AOT rebuild, an injected
    ``executor.build`` fault — the op DEGRADES to the ``dpia-jnp``
    reference backend (same strategy, reference lowering) instead of
    raising into the model's forward pass.  The degradation is recorded as
    provenance origin ``degraded(<backend>->jnp)`` + the
    ``kernels.degradations`` counter, so ``obs.explain()`` shows why the
    strategy changed.  The jnp rung itself has nothing below it: its
    failures propagate."""
    try:
        return _compiled(kernel, shape, params, builder, backend, opts)
    except Exception as e:
        if backend == "jnp":
            raise
        _warn_once(("degraded", kernel, backend),
                   f"{kernel!r} failed to build/compile for backend "
                   f"{backend!r} ({type(e).__name__}: {e}); degrading to "
                   f"the dpia-jnp reference path")
        obs.counter("kernels.degradations").inc()
        _record_default(kernel, "jnp", opts, shape,
                        f"degraded({backend}->jnp)",
                        f"backend {backend!r} build failed: "
                        f"{type(e).__name__}: {e}")
        return _compiled(kernel, shape, params, builder, "jnp", opts)


def _tuned_or_default(kernel: str, backend: str, opts: CompileOptions,
                      shape: Dict[str, int]) -> compiler.CompiledKernel:
    """The op-layer DPIA path: tuned candidate if available+buildable, else
    the kernel's default strategy.  All roads lead through Program."""
    params = _tuned(kernel, backend, opts, **shape)
    if params is not None:
        def build(params=params, shape=shape):
            return _cand_program(kernel, params, **shape)
        try:
            return _compiled(kernel, shape, params, build, backend, opts)
        except Exception as e:  # malformed cache params: use the default
            _warn_once(("params", kernel, backend),
                       f"tuned params {params!r} for {kernel!r} (backend "
                       f"{backend!r}) failed to build/compile: "
                       f"{type(e).__name__}: {e}; using the default "
                       f"strategy params")
            obs.counter("kernels.degradations").inc()
            _record_default(kernel, backend, opts, shape,
                            "degraded(tuned->default)",
                            f"tuned params {params!r} failed to build")
    else:
        _record_default(
            kernel, backend, opts, shape, "default",
            "autotune disabled in options" if not opts.autotune
            else "no tuned entry (lookup failed or returned nothing)")

    def build_default(shape=shape):
        return _cand_program(kernel, _default_params(kernel, **shape),
                             **shape)
    # default params are a pure function of the shape, so params=None ("the
    # default point") keys them; a failing default build degrades one rung
    # further, to the dpia-jnp reference backend
    return _compiled_or_reference(kernel, shape, None, build_default,
                                  backend, opts)


# ---------------------------------------------------------------------------
# mesh-level dispatch (the 'dpia-shardmap' impl; see repro.mesh)
# ---------------------------------------------------------------------------

_MESH_OPS = ("dot", "asum", "scal", "matmul", "rmsnorm", "softmax")


def _mesh_compiled(kernel: str, shape: Dict[str, int], opts: CompileOptions,
                   mesh_obj, extra_params: Optional[Dict[str, object]] = None
                   ) -> compiler.CompiledKernel:
    """Executor for the mesh placement of ``kernel`` on ``mesh_obj``.

    Placement params come from the tuner's mesh space (keyed by the real
    mesh descriptor), else the default placement; the executor cache key
    carries the descriptor so meshes never share artefacts.  Mesh programs
    skip Stage I->II (shard_map consumes the functional term; the per-shard
    bodies are checked by the inner backend)."""
    from repro import mesh as mesh_mod
    desc = mesh_mod.descriptor(mesh_obj)
    axes = mesh_mod.parse_descriptor(desc)
    params = _tuned(kernel, "shardmap", opts, **shape)
    if params is None or params.get("mesh_axis") is None:
        params = mesh_mod.default_mesh_params(kernel, axes, **shape)
    build_shape = dict(shape, **(extra_params or {}))
    key_params = dict(params, **(extra_params or {}))

    def build(params=params):
        cand = mesh_mod.mesh_candidate_from_params(
            kernel, params, axes, **build_shape)
        prog = compiler.Program.from_builder(
            cand.build, name=kernel, kernel=kernel, shape=shape)
        return prog.compile("shardmap", options=opts, mesh=mesh_obj)

    key = _executors.make_key(kernel, shape, "shardmap", params=key_params,
                              mesh=desc, layout=opts.kv_layout,
                              interpret=bool(opts.interpret),
                              jit=bool(opts.jit))
    return compiler.executor_cache().get_or_compile(
        key, build, meta={"interpret": bool(opts.interpret),
                          "jit": bool(opts.jit)})


def _mesh_or_none(kernel: str, opts: CompileOptions, shape: Dict[str, int],
                  extra_params: Optional[Dict[str, object]] = None
                  ) -> Optional[compiler.CompiledKernel]:
    """The dpia-shardmap op path, or None when the op must fall back to the
    single-device pipeline (no mesh in scope / no axis divides the extent /
    a malformed cache entry).  Falling back warns once per kernel so a
    sharding misconfiguration is diagnosable, not silent."""
    mesh_obj = opts.resolved_mesh()
    if mesh_obj is None:
        _warn_once(("mesh", kernel, "nomesh"),
                   f"{kernel}: impl 'dpia-shardmap' selected but no mesh is "
                   f"in scope (options(mesh=...) / sharding.ctx.set_mesh); "
                   f"using the single-device dpia-jnp path")
        return None
    try:
        return _mesh_compiled(kernel, shape, opts, mesh_obj, extra_params)
    except Exception as e:
        _warn_once(("mesh", kernel, "fallback"),
                   f"{kernel}: mesh placement on "
                   f"{getattr(mesh_obj, 'shape', mesh_obj)} failed "
                   f"({type(e).__name__}: {e}); using the single-device "
                   f"dpia-jnp path")
        return None


# ---------------------------------------------------------------------------
# warm-up: stage the executors a serving engine will hit, without running them
# ---------------------------------------------------------------------------

def warm_kernel(kernel: str, *, backend: str | None = None,
                options: CompileOptions | None = None,
                **shape) -> compiler.CompiledKernel:
    """Stage+compile (lazily jitted, never executed) the executor the DPIA
    dispatch path would build for ``kernel`` at ``shape`` — exactly the same
    cache key the runtime handlers use, so a warmed executor is a guaranteed
    dispatch hit.  Serving engines call this at start-up and then persist
    the result with ``repro.compiler.executor_cache().save_aot(dir)``."""
    opts = options if options is not None else current_options()
    b = backend or opts.dpia_backend
    if b == "shardmap":
        mesh_obj = opts.resolved_mesh()
        if mesh_obj is not None and kernel in _MESH_OPS:
            shape_d = {k: v for k, v in shape.items() if k != "eps"}
            extra = ({"eps": shape.get("eps", 1e-6)}
                     if kernel == "rmsnorm" else None)
            try:
                return _mesh_compiled(kernel, shape_d, opts, mesh_obj, extra)
            except Exception:
                pass  # unshardable shape: warm the single-device path
        b = "jnp"
    if kernel in ("dot", "asum", "scal"):
        return _tuned_or_default(kernel, b, opts, dict(shape))
    if kernel == "gemv":
        return _gemv_compiled(b, opts, shape["m"], shape["n"])
    if kernel == "matmul":
        return _matmul_compiled(b, opts, shape["m"], shape["k"], shape["n"])
    if kernel == "rmsnorm":
        return _rmsnorm_compiled(b, opts, shape["rows"], shape["d"],
                                 shape.get("eps", 1e-6))
    if kernel == "softmax":
        return _softmax_compiled(b, opts, shape["rows"], shape["d"])
    raise ValueError(f"warm_kernel: unknown kernel {kernel!r}")


# ---------------------------------------------------------------------------
# deprecation shims (the seed's process-global knobs)
# ---------------------------------------------------------------------------

def set_default_impl(impl: str) -> None:
    """Deprecated: mutate the process-wide default impl.

    Use ``with repro.compiler.options(backend=...):`` (thread-local scope)
    or per-call ``impl=``/``options=`` instead."""
    warnings.warn(
        "set_default_impl is deprecated; use "
        "repro.compiler.options(backend=...) or pass impl=/options= per "
        "call", DeprecationWarning, stacklevel=2)
    valid = compiler.ops_impls()
    if impl not in valid:
        raise ValueError(f"unknown impl {impl!r}; valid backends: "
                         f"{list(valid)}")
    compiler.set_default_options(backend=impl)


def set_autotune(enabled: bool, cache=None) -> None:
    """Deprecated: toggle autotuned strategy selection process-wide.

    Use ``with repro.compiler.options(autotune=..., tuning_cache=...):``
    instead.  Compiled-program and params memos are dropped so the change
    takes effect."""
    warnings.warn(
        "set_autotune is deprecated; use "
        "repro.compiler.options(autotune=..., tuning_cache=...)",
        DeprecationWarning, stacklevel=2)
    compiler.set_default_options(autotune=bool(enabled), tuning_cache=cache)
    clear_caches()


def autotune_enabled() -> bool:
    """Whether the active options enable autotuned strategy selection."""
    return current_options().autotune


# ---- BLAS ops (paper section 7) ---------------------------------------------

def scal(alpha, x, impl: str | None = None,
         options: CompileOptions | None = None):
    return _dispatch("scal", impl, options, alpha, x)


@_impl_handler("scal", "xla", "pallas")
def _scal_ref(impl, opts, alpha, x):
    return ref.scal(alpha, x)


@_impl_handler("scal", "dpia-jnp", "dpia-pallas")
def _scal_dpia(impl, opts, alpha, x):
    fn = _tuned_or_default("scal", _dpia_backend(impl), opts,
                           dict(n=x.shape[0]))
    return fn(jnp.asarray(alpha, x.dtype), x)


@_impl_handler("scal", "dpia-shardmap")
def _scal_mesh(impl, opts, alpha, x):
    fn = _mesh_or_none("scal", opts, dict(n=x.shape[0]))
    if fn is None:
        return _scal_dpia("dpia-jnp", opts, alpha, x)
    return fn(jnp.asarray(alpha, x.dtype), x)


def asum(x, impl: str | None = None, options: CompileOptions | None = None):
    return _dispatch("asum", impl, options, x)


@_impl_handler("asum", "xla", "pallas")
def _asum_ref(impl, opts, x):
    return ref.asum(x)


@_impl_handler("asum", "dpia-jnp", "dpia-pallas")
def _asum_dpia(impl, opts, x):
    fn = _tuned_or_default("asum", _dpia_backend(impl), opts,
                           dict(n=x.shape[0]))
    return fn(x)


@_impl_handler("asum", "dpia-shardmap")
def _asum_mesh(impl, opts, x):
    fn = _mesh_or_none("asum", opts, dict(n=x.shape[0]))
    return fn(x) if fn is not None else _asum_dpia("dpia-jnp", opts, x)


def dot(x, y, impl: str | None = None, options: CompileOptions | None = None):
    return _dispatch("dot", impl, options, x, y)


@_impl_handler("dot", "xla", "pallas")
def _dot_ref(impl, opts, x, y):
    return ref.dot(x, y)


@_impl_handler("dot", "dpia-jnp", "dpia-pallas")
def _dot_dpia(impl, opts, x, y):
    fn = _tuned_or_default("dot", _dpia_backend(impl), opts,
                           dict(n=x.shape[0]))
    return fn(x, y)


@_impl_handler("dot", "dpia-shardmap")
def _dot_mesh(impl, opts, x, y):
    fn = _mesh_or_none("dot", opts, dict(n=x.shape[0]))
    return fn(x, y) if fn is not None else _dot_dpia("dpia-jnp", opts, x, y)


def gemv(a, x, impl: str | None = None, options: CompileOptions | None = None):
    return _dispatch("gemv", impl, options, a, x)


@_impl_handler("gemv", "xla", "pallas")
def _gemv_ref(impl, opts, a, x):
    return ref.gemv(a, x)


def _gemv_compiled(backend: str, opts: CompileOptions, m: int, n: int):
    # gemv has no autotune space yet; always the default row-blocked strategy
    return _compiled_or_reference("gemv", dict(m=m, n=n), None,
                                  lambda: dpia_blas.strategy_gemv(m, n),
                                  backend, opts)


@_impl_handler("gemv", "dpia-jnp", "dpia-pallas")
def _gemv_dpia(impl, opts, a, x):
    fn = _gemv_compiled(_dpia_backend(impl), opts, *a.shape)
    return fn(a, x)


@_impl_handler("gemv", "dpia-shardmap")
def _gemv_mesh(impl, opts, a, x):
    # gemv has no mesh strategy yet: the row-blocked single-device path
    return _gemv_dpia("dpia-jnp", opts, a, x)


# ---- transformer ops ---------------------------------------------------------

def matmul(a, b, impl: str | None = None, out_dtype=None,
           options: CompileOptions | None = None):
    return _dispatch("matmul", impl, options, a, b, out_dtype=out_dtype)


@_impl_handler("matmul", "xla")
def _matmul_ref(impl, opts, a, b, out_dtype=None):
    return ref.matmul(a, b, out_dtype=out_dtype)


@_impl_handler("matmul", "pallas")
def _matmul_pallas(impl, opts, a, b, out_dtype=None):
    return _mm_pallas(a, b, out_dtype=out_dtype)


def _matmul_compiled(backend: str, opts: CompileOptions, m: int, k: int,
                     n: int):
    params = _tuned("matmul", backend, opts, m=m, k=k, n=n)
    if params is None:
        _record_default("matmul", backend, opts, dict(m=m, k=k, n=n),
                        "default", "no tuned entry")
    params = params or {}
    defaults = _default_params("matmul", m=m, k=k, n=n)
    bm, bk = params.get("bm"), params.get("bk")
    if not (isinstance(bm, int) and bm > 0 and m % bm == 0):
        bm = defaults["bm"]  # malformed/hand-edited cache entry
    if not (isinstance(bk, int) and bk > 0 and k % bk == 0):
        bk = defaults["bk"]
    return _compiled_or_reference(
        "matmul", dict(m=m, k=k, n=n), dict(bm=bm, bk=bk),
        lambda: _cand_program("matmul", {"bm": bm, "bk": bk}, m=m, k=k, n=n),
        backend, opts)


@_impl_handler("matmul", "dpia-jnp", "dpia-pallas")
def _matmul_dpia(impl, opts, a, b, out_dtype=None):
    m, k = a.shape
    fn = _matmul_compiled(_dpia_backend(impl), opts, m, k, b.shape[1])
    return fn(a, b).astype(out_dtype or a.dtype)


@_impl_handler("matmul", "dpia-shardmap")
def _matmul_mesh(impl, opts, a, b, out_dtype=None):
    m, k = a.shape
    fn = _mesh_or_none("matmul", opts, dict(m=m, k=k, n=b.shape[1]))
    if fn is None:
        return _matmul_dpia("dpia-jnp", opts, a, b, out_dtype=out_dtype)
    return fn(a, b).astype(out_dtype or a.dtype)


def rmsnorm(x, w, eps: float = 1e-6, impl: str | None = None,
            options: CompileOptions | None = None):
    return _dispatch("rmsnorm", impl, options, x, w, eps=eps)


@_impl_handler("rmsnorm", "xla")
def _rmsnorm_ref(impl, opts, x, w, eps=1e-6):
    return ref.rmsnorm(x, w, eps=eps)


@_impl_handler("rmsnorm", "pallas")
def _rmsnorm_pallas(impl, opts, x, w, eps=1e-6):
    return _rms_pallas(x, w, eps=eps)


def _rmsnorm_compiled(backend: str, opts: CompileOptions, rows: int, d: int,
                      eps: float = 1e-6):
    params = _tuned("rmsnorm", backend, opts, rows=rows, d=d)
    if params is None:
        _record_default("rmsnorm", backend, opts, dict(rows=rows, d=d),
                        "default", "no tuned entry")
    params = params or {}
    rb = params.get("row_block")
    if not (isinstance(rb, int) and rb > 0 and rows % rb == 0):
        # malformed/missing cache entry; eps is threaded separately, so the
        # builder below stays direct and only the params value is shared
        rb = _default_params("rmsnorm", rows=rows, d=d)["row_block"]
    return _compiled_or_reference(
        "rmsnorm", dict(rows=rows, d=d), dict(row_block=rb, eps=eps),
        lambda: _cand_program("rmsnorm", {"row_block": rb},
                              rows=rows, d=d, eps=eps),
        backend, opts)


@_impl_handler("rmsnorm", "dpia-jnp", "dpia-pallas")
def _rmsnorm_dpia(impl, opts, x, w, eps=1e-6):
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    fn = _rmsnorm_compiled(_dpia_backend(impl), opts, x2.shape[0], d, eps)
    return fn(x2.astype(jnp.float32),
              w.astype(jnp.float32)).reshape(x.shape).astype(x.dtype)


@_impl_handler("rmsnorm", "dpia-shardmap")
def _rmsnorm_mesh(impl, opts, x, w, eps=1e-6):
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    fn = _mesh_or_none("rmsnorm", opts, dict(rows=x2.shape[0], d=d),
                       extra_params={"eps": eps})
    if fn is None:
        return _rmsnorm_dpia("dpia-jnp", opts, x, w, eps=eps)
    return fn(x2.astype(jnp.float32),
              w.astype(jnp.float32)).reshape(x.shape).astype(x.dtype)


def softmax(x, axis: int = -1, impl: str | None = None,
            options: CompileOptions | None = None):
    return _dispatch("softmax", impl, options, x, axis=axis)


@_impl_handler("softmax", "xla", "pallas")
def _softmax_ref(impl, opts, x, axis=-1):
    return ref.softmax(x, axis=axis)


def _softmax_compiled(backend: str, opts: CompileOptions, rows: int, d: int):
    params = _tuned("softmax", backend, opts, rows=rows, d=d)
    if params is None:
        _record_default("softmax", backend, opts, dict(rows=rows, d=d),
                        "default", "no tuned entry")
    params = params or {}
    rb = params.get("row_block")
    if not (isinstance(rb, int) and rb > 0 and rows % rb == 0):
        rb = _default_params("softmax", rows=rows, d=d)["row_block"]
    return _compiled_or_reference(
        "softmax", dict(rows=rows, d=d), dict(row_block=rb),
        lambda: _cand_program("softmax", {"row_block": rb}, rows=rows, d=d),
        backend, opts)


@_impl_handler("softmax", "dpia-jnp", "dpia-pallas")
def _softmax_dpia(impl, opts, x, axis=-1):
    if x.ndim < 2 or axis not in (-1, x.ndim - 1):
        return ref.softmax(x, axis=axis)  # DPIA path covers row softmax only
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    fn = _softmax_compiled(_dpia_backend(impl), opts, x2.shape[0], d)
    return fn(x2.astype(jnp.float32)).reshape(x.shape).astype(x.dtype)


@_impl_handler("softmax", "dpia-shardmap")
def _softmax_mesh(impl, opts, x, axis=-1):
    if x.ndim < 2 or axis not in (-1, x.ndim - 1):
        return ref.softmax(x, axis=axis)  # DPIA path covers row softmax only
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    fn = _mesh_or_none("softmax", opts, dict(rows=x2.shape[0], d=d))
    if fn is None:
        return _softmax_dpia("dpia-jnp", opts, x, axis=axis)
    return fn(x2.astype(jnp.float32)).reshape(x.shape).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    q_offset: int = 0, impl: str | None = None,
                    options: CompileOptions | None = None):
    return _dispatch("flash_attention", impl, options, q, k, v,
                     causal=causal, scale=scale, q_offset=q_offset)


@_impl_handler("flash_attention", "xla", "dpia-jnp", "dpia-pallas",
               "dpia-shardmap")
def _fa_ref(impl, opts, q, k, v, *, causal=True, scale=None, q_offset=0):
    # no DPIA flash-attention strategy yet: dpia-* impls use the reference
    return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                               q_offset=q_offset)


@_impl_handler("flash_attention", "pallas")
def _fa_kernel(impl, opts, q, k, v, *, causal=True, scale=None, q_offset=0):
    return _fa_pallas(q, k, v, causal=causal, scale=scale, q_offset=q_offset)
