"""Serving fast-path tests: fused decode chunks, per-request sampling,
continuous batching vs the static-batch oracle, recompile accounting, and
the executor-cache/AOT start-up flow."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.serve.engine import (BatchedEngine, ContinuousEngine, Request,
                                sample, sample_tokens)
from repro.serve.scheduler import Scheduler, pick_bucket, seq_buckets


def tiny_cfg(**kw):
    base = dict(name="serve-t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, dtype="float32",
                remat=False, max_seq=64)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def dense_model():
    cfg = tiny_cfg()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def mixed_requests(cfg, n=6, key=None):
    key = key if key is not None else jax.random.PRNGKey(5)
    temps = [0.0, 0.9, 0.0, 1.3, 0.7, 0.0]
    top_ks = [0, 5, 0, 0, 3, 0]
    return [Request(
        prompt=jax.random.randint(jax.random.fold_in(key, 100 + i),
                                  (5 + 3 * i,), 0, cfg.vocab),
        max_new_tokens=4 + 3 * i, temperature=temps[i % 6],
        top_k=top_ks[i % 6]) for i in range(n)]


# ---------------------------------------------------------------------------
# per-request sampling (the requests[0].temperature regression)
# ---------------------------------------------------------------------------

class TestPerRequestSampling:
    def test_greedy_request_unaffected_by_hot_neighbour(self, dense_model):
        """Seed bug: the whole batch sampled at requests[0].temperature.
        A greedy request must produce its solo-greedy tokens even when
        request 0 runs hot."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(11)
        prompt = jnp.arange(7) % cfg.vocab
        hot = Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=8,
                      temperature=5.0)
        cold = Request(prompt=prompt, max_new_tokens=8, temperature=0.0)

        engine = BatchedEngine(model, params, max_seq=64, chunk=4)
        together = engine.run([hot, cold], key=key)
        alone = engine.run([Request(prompt=prompt, max_new_tokens=8,
                                    temperature=0.0)], key=key)
        assert together[1] == alone[0]

    def test_hot_request_actually_samples(self, dense_model):
        """And conversely: a hot request next to a greedy request[0] must
        not silently decode greedily (two different keys almost surely
        diverge at temperature 5)."""
        cfg, model, params = dense_model
        prompt = jnp.arange(6) % cfg.vocab
        mk = lambda t: [Request(prompt=jnp.arange(4) % cfg.vocab,  # noqa:E731
                                max_new_tokens=12, temperature=0.0),
                        Request(prompt=prompt, max_new_tokens=12,
                                temperature=t)]
        engine = BatchedEngine(model, params, max_seq=64, chunk=4)
        hot = engine.run(mk(5.0), key=jax.random.PRNGKey(1))
        hot2 = engine.run(mk(5.0), key=jax.random.PRNGKey(2))
        greedy = engine.run(mk(0.0), key=jax.random.PRNGKey(1))
        assert hot[0] == greedy[0]            # request 0 greedy either way
        assert hot[1] != greedy[1] or hot2[1] != greedy[1]


# ---------------------------------------------------------------------------
# continuous batching == static oracle
# ---------------------------------------------------------------------------

class TestContinuousVsStatic:
    def test_token_identical_mixed_lengths_and_budgets(self, dense_model):
        """Mixed prompt lengths, mixed max_new_tokens, mixed temperatures
        and top-k, fewer slots than requests: the continuous engine must be
        token-identical to the static-batch oracle."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(7)
        reqs = mixed_requests(cfg)
        static = BatchedEngine(model, params, max_seq=64, chunk=4)
        oracle = static.run(reqs, key=key)
        for slots in (2, 3):
            cont = ContinuousEngine(model, params, max_seq=64, slots=slots,
                                    chunk=4, min_bucket=8)
            got = cont.run(reqs, key=key)
            assert got == oracle, f"slots={slots}"

    def test_token_identical_greedy_reordered_traffic(self, dense_model):
        """Greedy tokens are a function of the request alone: serving the
        same requests in a different submission order must return the same
        per-request outputs (outputs follow submission order)."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(3)
        reqs = [r for r in mixed_requests(cfg) if r.temperature == 0.0]
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        a = cont.run(reqs, key=key)
        b = cont.run(list(reversed(reqs)), key=key)
        assert a == list(reversed(b))

    def test_reused_engine_stays_token_identical(self, dense_model):
        """PRNG streams are per-RUN batch indices, not lifetime request
        ids: the second (sampled!) run of a reused engine must still match
        the oracle and the first run."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(7)
        reqs = mixed_requests(cfg)
        assert any(r.temperature > 0 for r in reqs)
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run(reqs, key=key)
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        first = cont.run(reqs, key=key)
        second = cont.run(reqs, key=key)
        assert first == oracle
        assert second == oracle

    def test_completed_requests_are_released(self, dense_model):
        """run() collects outputs and drops every per-request record — a
        long-running engine's memory is bounded by in-flight work."""
        cfg, model, params = dense_model
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        for k in range(3):
            cont.run(mixed_requests(cfg, n=4), key=jax.random.PRNGKey(k))
        assert cont._requests == {} and cont._stream_keys == {}
        assert cont.sched.outputs == {} and cont.sched.meta == {}

    def test_output_lengths_respect_budgets(self, dense_model):
        cfg, model, params = dense_model
        reqs = mixed_requests(cfg)
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        outs = cont.run(reqs, key=jax.random.PRNGKey(0))
        assert [len(o) for o in outs] == [r.max_new_tokens for r in reqs]
        assert all(0 <= t < cfg.vocab for o in outs for t in o)


# ---------------------------------------------------------------------------
# sampling edge cases
# ---------------------------------------------------------------------------

class TestSample:
    def test_top_k_one_is_argmax(self):
        logits = jnp.asarray([[0.1, 2.0, -1.0, 0.5]])
        for _ in range(3):
            tok = sample(logits, jax.random.PRNGKey(0), temperature=1.0,
                         top_k=1)
            assert int(tok[0]) == 1

    def test_top_k_keeps_ties_at_cutoff(self):
        """The k-th largest value is a >=-threshold: ties with the cutoff
        all stay in the candidate set."""
        logits = jnp.asarray([[2.0, 2.0, 2.0, -10.0]])
        seen = set()
        for i in range(40):
            tok = sample(logits, jax.random.PRNGKey(i), temperature=1.0,
                         top_k=2)
            seen.add(int(tok[0]))
        assert seen == {0, 1, 2}      # all three tied values reachable
        assert 3 not in seen

    def test_top_k_zero_and_oversized_are_noops(self):
        logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])
        k = jax.random.PRNGKey(4)
        full = sample(logits, k, temperature=1.0, top_k=0)
        over = sample(logits, k, temperature=1.0, top_k=99)
        assert int(full[0]) == int(over[0])

    def test_zero_temperature_is_greedy(self):
        logits = jnp.asarray([[0.1, 5.0, -1.0]])
        assert int(sample(logits, jax.random.PRNGKey(0))[0]) == 1

    def test_batched_matches_scalar_per_row(self):
        """sample_tokens must agree with sample() row by row for every
        (temperature, top_k) mix in the batch."""
        rng = np.random.RandomState(0)
        logits = jnp.asarray(rng.randn(4, 16), "float32")
        keys = jnp.stack([jax.random.PRNGKey(i) for i in range(4)])
        temps = jnp.asarray([0.0, 1.0, 0.7, 2.0], "float32")
        top_ks = jnp.asarray([0, 3, 0, 1], "int32")
        got = sample_tokens(logits, keys, temps, top_ks)
        for i in range(4):
            want = sample(logits[i:i + 1], keys[i],
                          temperature=float(temps[i]), top_k=int(top_ks[i]))
            assert int(got[i]) == int(want[0]), i


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_buckets(self):
        assert seq_buckets(64, 16) == (16, 32, 64)
        assert seq_buckets(48, 16) == (16, 32, 48)
        assert pick_bucket(5, (16, 32)) == 16
        assert pick_bucket(17, (16, 32)) == 32
        with pytest.raises(ValueError):
            pick_bucket(33, (16, 32))

    def test_fifo_admission_and_retirement(self):
        s = Scheduler(2)
        for rid in range(3):
            s.submit(rid, prompt_len=4, max_new=3)
        assert s.admissions() == [(0, 0), (1, 1)]     # FIFO into free slots
        assert s.admissions() == []                   # no free slot left
        s.record_first(0, 7)
        s.record_first(1, 8)
        toks = np.arange(8).reshape(2, 4)             # chunk of 4 > remaining
        done = s.record_chunk(toks)
        assert sorted(done) == [0, 1]
        assert s.outputs[0] == [7, 0, 1]              # extra tokens discarded
        assert s.outputs[1] == [8, 4, 5]
        assert s.admissions() == [(0, 2)]             # freed slot reused
        assert not s.idle

    def test_max_new_one_retires_at_prefill(self):
        s = Scheduler(1)
        s.submit(0, prompt_len=4, max_new=1)
        assert s.admissions() == [(0, 0)]
        assert s.record_first(0, 9) is True
        assert s.outputs[0] == [9]
        assert s.idle

    def test_bucket_boundary_values(self):
        """Exact-boundary lookups: a prompt of exactly a bucket's length
        lands in THAT bucket, not the next one up."""
        buckets = seq_buckets(64, 16)
        assert pick_bucket(16, buckets) == 16
        assert pick_bucket(64, buckets) == 64          # == max_seq
        assert pick_bucket(17, buckets) == 32
        assert seq_buckets(64, 16) is buckets          # cached, not rebuilt

    def test_bucket_boundary_admission(self, dense_model):
        """Engine-level boundary admission: prompt length exactly == a
        bucket and exactly == max_seq must admit cleanly and stay
        token-identical to the oracle (the == max_seq prompt has no decode
        budget left: it gets its one prefill-sampled... zero tokens)."""
        cfg, model, params = dense_model
        key = jax.random.PRNGKey(2)
        at_bucket = Request(prompt=jnp.arange(16) % cfg.vocab,
                            max_new_tokens=6)
        at_max = Request(prompt=jnp.arange(64) % cfg.vocab,
                         max_new_tokens=0)
        oracle = BatchedEngine(model, params, max_seq=64,
                               chunk=4).run([at_bucket], key=key)
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        assert cont.run([at_bucket], key=key) == oracle
        assert cont.run([at_max], key=key) == [[]]
        with pytest.raises(ValueError):        # one past max_seq: rejected
            cont.submit(Request(prompt=jnp.arange(64) % cfg.vocab,
                                max_new_tokens=1))


# ---------------------------------------------------------------------------
# recompile accounting: bounded shapes, zero recompiles after warm-up
# ---------------------------------------------------------------------------

class TestRecompiles:
    def test_zero_recompiles_after_bucket_warmup(self, dense_model):
        """After one pass over the prompt buckets, arbitrary further
        traffic (new lengths, budgets, temperatures) must hit the jit
        caches exactly — zero decode or prefill cache misses."""
        cfg, model, params = dense_model
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=8)
        key = jax.random.PRNGKey(0)
        # warm-up: one prompt per bucket (8, 16, 32, 64 -> those that fit)
        warm = [Request(prompt=jnp.arange(min(b, 40)) % cfg.vocab,
                        max_new_tokens=3)
                for b in cont.buckets if min(b, 40) + 3 <= 64]
        cont.run(warm, key=key)
        decode0 = cont.decode_cache_misses()
        prefill0 = int(cont._prefill._cache_size())
        assert decode0 >= 1

        traffic = [Request(
            prompt=jnp.arange(3 + 5 * i) % cfg.vocab,
            max_new_tokens=2 + i, temperature=0.3 * i, top_k=i)
            for i in range(5)]
        cont.run(traffic, key=jax.random.PRNGKey(1))
        assert cont.decode_cache_misses() == decode0
        assert int(cont._prefill._cache_size()) == prefill0

    def test_static_engine_one_decode_compile(self, dense_model):
        """The fused chunk compiles once per batch shape; chunks within and
        across runs of the same shape reuse it."""
        cfg, model, params = dense_model
        engine = BatchedEngine(model, params, max_seq=64, chunk=4)
        reqs = mixed_requests(cfg, n=4)
        engine.run(reqs, key=jax.random.PRNGKey(0))
        assert engine.decode_cache_misses() == 1
        engine.run(reqs, key=jax.random.PRNGKey(1))
        assert engine.decode_cache_misses() == 1


# ---------------------------------------------------------------------------
# executor cache + AOT start-up
# ---------------------------------------------------------------------------

class TestEngineAot:
    def test_restart_skips_staging(self, dense_model, tmp_path):
        """Engine #1 tunes, stages, and exports its executors; engine #2 in
        fresh caches loads them AOT — zero staged builds on restart."""
        from repro import compiler
        from repro.kernels import ops
        cfg, model, params = dense_model
        cpath = str(tmp_path / "tune.json")

        ops.clear_caches()
        BatchedEngine(model, params, max_seq=32, tuning_cache=cpath,
                      batch_sizes=(1, 2), chunk=4)
        aot_dir = cpath + ".aot"
        assert os.path.isdir(aot_dir) and len(os.listdir(aot_dir)) > 0
        built = compiler.executor_cache().stats()["builds"]
        assert built > 0

        ops.clear_caches()
        e2 = BatchedEngine(model, params, max_seq=32, tuning_cache=cpath,
                           batch_sizes=(1, 2), chunk=4)
        st = compiler.executor_cache().stats()
        assert st["builds"] == 0, st          # staging skipped entirely
        assert st["aot_loads"] == built
        outs = e2.run([Request(prompt=jnp.arange(5) % cfg.vocab,
                               max_new_tokens=4)])
        assert len(outs[0]) == 4
        ops.clear_caches()

    def test_program_export_load_roundtrip(self, tmp_path):
        from repro import compiler
        prog = compiler.Program.from_kernel("matmul", m=8, k=8, n=8)
        prog.check().lower()
        path = prog.export(str(tmp_path / "mm.json"))
        loaded = compiler.Program.load(path)
        assert loaded.kernel == "matmul" and loaded.shape == dict(m=8, k=8,
                                                                  n=8)
        rng = np.random.RandomState(0)
        a = jnp.asarray(rng.randn(8, 8), "float32")
        b = jnp.asarray(rng.randn(8, 8), "float32")
        np.testing.assert_allclose(
            np.asarray(loaded.compile("jnp")(a, b)),
            np.asarray(prog.compile("jnp")(a, b)), rtol=1e-6)


# ---------------------------------------------------------------------------
# model-level: vector positions + length-aware prefill
# ---------------------------------------------------------------------------

class TestDecodePositions:
    def test_vector_pos_matches_scalar(self, dense_model):
        cfg, model, params = dense_model
        b, s = 3, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                  cfg.vocab)
        cache = model.init_cache(b, 32)
        last, cache = model.prefill(params, toks, cache)
        nxt = jnp.argmax(last, -1)[:, None]
        lg_s, c_s = model.decode_step(params, nxt, cache, jnp.int32(s))
        lg_v, c_v = model.decode_step(params, nxt, cache,
                                      jnp.full((b,), s, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                                   rtol=1e-6)
        for a, bb in zip(jax.tree_util.tree_leaves(c_s),
                         jax.tree_util.tree_leaves(c_v)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       rtol=1e-6)

    def test_right_padded_prefill_is_padding_invariant(self, dense_model):
        """For attention families a right-padded prefill with lengths= is
        the unpadded computation: causal masking keeps real tokens from
        ever attending to the padding."""
        cfg, model, params = dense_model
        p = jax.random.randint(jax.random.PRNGKey(2), (5,), 0, cfg.vocab)
        un, _ = model.prefill(params, p[None], model.init_cache(1, 32))
        padded = jnp.pad(p, (0, 11))[None]
        pad_l, _ = model.prefill(params, padded, model.init_cache(1, 32),
                                 lengths=jnp.asarray([5]))
        np.testing.assert_allclose(np.asarray(un), np.asarray(pad_l),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# recurrent families: masked state updates make padded prefill invariant
# ---------------------------------------------------------------------------

class TestRecurrentPaddingInvariance:
    """ssm (rwkv6) and hybrid (zamba2) prefill with lengths= masks the
    recurrent-state updates at padded positions, so the state after a
    RIGHT-padded prefill is the unpadded state — which is what makes
    continuous batching token-identical for these families too."""

    @pytest.fixture(scope="class", params=["rwkv6-1.6b", "zamba2-2.7b"])
    def recurrent_model(self, request):
        from repro.configs import smoke_config
        cfg = smoke_config(request.param)
        model = Model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        return cfg, model, params

    def test_padded_prefill_state_matches_unpadded(self, recurrent_model):
        cfg, model, params = recurrent_model
        p = jax.random.randint(jax.random.PRNGKey(2), (5,), 0, cfg.vocab)
        un_l, un_c = model.prefill(params, p[None], model.init_cache(1, 32))
        padded = jnp.pad(p, (0, 3))[None]
        pad_l, pad_c = model.prefill(params, padded, model.init_cache(1, 32),
                                     lengths=jnp.asarray([5]))
        np.testing.assert_allclose(np.asarray(un_l), np.asarray(pad_l),
                                   rtol=1e-5, atol=1e-5)
        if cfg.family == "hybrid":
            # recurrent (mamba) state must match exactly; the kv part
            # follows the attention-family discipline (positions >= length
            # are never read: decode masks by pos)
            for a, b in zip(jax.tree_util.tree_leaves(un_c["mamba"]),
                            jax.tree_util.tree_leaves(pad_c["mamba"])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-5)
            for a, b in zip(jax.tree_util.tree_leaves(un_c["kv"]),
                            jax.tree_util.tree_leaves(pad_c["kv"])):
                np.testing.assert_allclose(np.asarray(a)[:, :, :5],
                                           np.asarray(b)[:, :, :5],
                                           rtol=1e-5, atol=1e-5)
        else:
            for a, b in zip(jax.tree_util.tree_leaves(un_c),
                            jax.tree_util.tree_leaves(pad_c)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-5)

    def test_continuous_token_identical_to_static(self, recurrent_model):
        """The ROADMAP follow-up: bucket padding in the continuous engine
        no longer perturbs recurrent families' tokens."""
        cfg, model, params = recurrent_model
        reqs = lambda: [Request(  # noqa: E731
            prompt=jax.random.randint(jax.random.fold_in(
                jax.random.PRNGKey(4), i), (3 + 3 * i,), 0, cfg.vocab),
            max_new_tokens=4 + 2 * i) for i in range(3)]
        key = jax.random.PRNGKey(9)
        static = BatchedEngine(model, params, max_seq=64, chunk=4)
        cont = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                                min_bucket=4)
        assert static.run(reqs(), key=key) == cont.run(reqs(), key=key)


# ---------------------------------------------------------------------------
# request-lifecycle edges (PR 8: tests/test_resilience.py has the fault
# drills; these are the plain state-machine corners)
# ---------------------------------------------------------------------------

class TestLifecycleEdges:
    def test_submit_max_new_zero_retires_ok_empty(self, dense_model):
        """A zero-budget request is legal: it admits, prefills, and retires
        ``ok`` with no tokens — never wedging its slot."""
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=2, chunk=4,
                               min_bucket=8)
        reqs = [Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=0),
                Request(prompt=jnp.arange(7) % cfg.vocab, max_new_tokens=6)]
        out = eng.run(reqs, key=jax.random.PRNGKey(1))
        assert out[0] == []
        assert len(out[1]) == 6
        assert eng.sched.stats()["retires"] == 2

    def test_duplicate_req_id_rejected(self):
        sched = Scheduler(2)
        sched.submit(7, prompt_len=4, max_new=4)
        with pytest.raises(ValueError, match="already submitted"):
            sched.submit(7, prompt_len=4, max_new=4)
        # terminal ids stay reserved until collected, too
        sched.cancel(7)
        with pytest.raises(ValueError, match="already submitted"):
            sched.submit(7, prompt_len=4, max_new=4)

    def test_pop_output_unknown_in_flight_and_failed(self):
        sched = Scheduler(1)
        with pytest.raises(KeyError):
            sched.pop_output(42)
        sched.submit(1, prompt_len=4, max_new=4)
        with pytest.raises(ValueError, match="in flight"):
            sched.pop_output(1)
        sched.fail(1, "drill")
        assert sched.pop_output(1) == []     # failed: partial tokens (none)
        with pytest.raises(KeyError):        # collected: records released
            sched.pop_output(1)

    def test_cancel_while_prefilling(self, dense_model):
        """Cancel mid-chunked-prefill: the slot is released with the prompt
        only partially in the cache, and later requests admit cleanly."""
        cfg, model, params = dense_model
        eng = ContinuousEngine(model, params, max_seq=64, slots=1, chunk=4,
                               min_bucket=8, prefill_chunk=8)
        long_req = Request(prompt=jnp.arange(20) % cfg.vocab,
                           max_new_tokens=4)
        short = Request(prompt=jnp.arange(5) % cfg.vocab, max_new_tokens=4)
        solo = eng.run([short], key=jax.random.PRNGKey(2))[0]
        with eng._options_scope():
            eng._run_key = jax.random.PRNGKey(2)
            rid_long = eng.submit(long_req)
            eng.step_chunk()                     # prefills 8 of 20 tokens
            assert eng.sched.slots[0].prefilling
            eng.cancel(rid_long)
            assert eng.sched.slots[0].free
            rid_short = eng.submit(short, stream=0)
            while not eng.sched.idle:
                eng.step_chunk()
        res_long = eng.take_result(rid_long)
        assert res_long.state == "cancelled" and res_long.tokens == ()
        assert list(eng.take_result(rid_short).tokens) == solo

    def test_deadline_expiry_at_chunk_boundary(self):
        """Deadlines are swept at boundaries: an expiry mid-chunk takes
        effect at the NEXT sweep, with partial tokens kept (scheduler-level
        and deterministic via the ``now`` override)."""
        sched = Scheduler(1)
        sched.submit(1, prompt_len=4, max_new=8, deadline_s=10.0)
        sched.admissions()
        sched.record_first(0, 5)
        t_submit = sched.meta[1]["t_submit"]
        assert sched.check_deadlines(now=t_submit + 9.0) == []
        out = sched.check_deadlines(now=t_submit + 10.0)
        assert out == [(0, 1)]               # freed slot 0, request 1
        assert sched.slots[0].free
        res = sched.pop_result(1)
        assert res.state == "timeout" and list(res.tokens) == [5]
        # the sweep is idempotent: nothing left to expire
        assert sched.check_deadlines(now=t_submit + 11.0) == []
