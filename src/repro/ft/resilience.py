"""Fault tolerance: step watchdog (straggler detection), NaN guards with
step retry, auto-resume from the latest checkpoint, and elastic re-meshing.

At 1000+ nodes the failure model is: slow host (straggler), dead host
(restart), corrupted step (NaN/inf from flaky HBM).  The pieces here:

  * Watchdog       — per-step deadline; on breach it records the straggler
                     event (hook point for re-scheduling / pre-emption).
  * guard_update   — reject non-finite losses/grad-norms; the caller skips
                     the update (step retried with the next data batch —
                     deterministic data makes this reproducible).
  * TrainLoop      — checkpoint every N steps (async), restore-latest on
                     entry, bounded retry on exceptions.
  * elastic_remesh — rebuild a mesh from the currently-available device set
                     and re-place a host-resident checkpoint onto it.
"""
from __future__ import annotations

import logging
import threading
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

log = logging.getLogger("repro.ft")


class Watchdog:
    """Flags steps exceeding ``deadline_s`` (straggler mitigation hook).

    Race-free: ``threading.Timer.cancel()`` does not stop a callback that
    has already started, so ``_fire`` can run concurrently with — or just
    after — ``disarm()`` on the step-completion path, recording a spurious
    straggler for a step that finished in time.  Every ``arm()`` therefore
    issues a generation token; ``_fire`` re-checks under the lock that its
    generation is still the armed one (and fires at most once per arm),
    and ``disarm()`` retires the generation before cancelling the timer.
    Used by ``TrainLoop`` per train step and by the serving engines as the
    chunk-level straggler detector (``repro.serve.engine``).
    """

    def __init__(self, deadline_s: float = 300.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.deadline = deadline_s
        self.on_straggler = on_straggler or (
            lambda step, dt: log.warning(
                "step %d exceeded deadline (%.1fs > %.1fs) — straggler "
                "suspected", step, dt, self.deadline))
        self.events = []
        self._armed_at: Optional[float] = None
        self._step = 0
        self._timer: Optional[threading.Timer] = None
        self._lock = threading.Lock()
        self._gen = 0           # incremented on every arm
        self._live_gen = -1     # the generation allowed to fire (-1: none)
        self._fired = False     # current generation already fired

    def arm(self, step: int) -> None:
        with self._lock:
            self._retire_locked()
            self._gen += 1
            self._live_gen = self._gen
            self._fired = False
            self._step = step
            self._armed_at = time.monotonic()
            self._timer = threading.Timer(self.deadline, self._fire,
                                          args=(self._gen,))
            self._timer.daemon = True
            self._timer.start()

    def _fire(self, gen: int) -> None:
        with self._lock:
            if gen != self._live_gen or self._fired:
                return  # disarmed (step completed) or duplicate firing
            self._fired = True
            step = self._step
            dt = time.monotonic() - (self._armed_at or time.monotonic())
            self.events.append((step, dt))
        self.on_straggler(step, dt)

    def disarm(self) -> None:
        with self._lock:
            self._retire_locked()

    def _retire_locked(self) -> None:
        self._live_gen = -1
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


def guard_update(metrics: Dict) -> bool:
    """True if the step's numerics are sane (update may be applied)."""
    loss = float(metrics.get("loss", 0.0))
    gn = float(metrics.get("grad_norm", 0.0))
    return bool(np.isfinite(loss) and np.isfinite(gn))


def elastic_remesh(preferred, axis_names=None):
    """Build the largest mesh the *currently available* devices support,
    shrinking the leading (data) axis first — elastic scale-down after node
    loss; checkpoints re-place transparently because they are stored
    mesh-agnostically (ckpt/manager.py).

    ``preferred`` is a canonical mesh descriptor string
    (``"data=8"`` / ``"data=8,model=2"`` — ``repro.mesh.strategy``) or a
    legacy shape tuple paired with ``axis_names``.  The shrink itself is
    :func:`repro.mesh.strategy.shrink_descriptor`, so the shape the mesh is
    built from round-trips through ``parse_descriptor`` and is exactly what
    tuning/executor cache keys will carry for it."""
    from repro.mesh import strategy as ms
    if isinstance(preferred, str):
        if axis_names is not None:
            raise TypeError("axis_names only applies to shape-tuple form; "
                            "a descriptor string already names its axes")
        desc = preferred
    else:
        if axis_names is None:
            raise TypeError("shape-tuple form needs axis_names")
        desc = ",".join(f"{a}={int(s)}"
                        for a, s in zip(axis_names, preferred))
    axes = ms.parse_descriptor(ms.shrink_descriptor(desc, len(jax.devices())))
    if not axes:
        raise ValueError(f"elastic_remesh needs at least one axis, got "
                         f"{preferred!r}")
    return jax.make_mesh(tuple(axes.values()), tuple(axes.keys()))


class TrainLoop:
    """Checkpointed, auto-resuming, NaN-guarded train loop."""

    def __init__(self, step_fn, ckpt_mgr, data, *, ckpt_every: int = 100,
                 max_retries: int = 3, deadline_s: float = 600.0):
        self.step_fn = step_fn
        self.ckpt = ckpt_mgr
        self.data = data
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.watchdog = Watchdog(deadline_s)
        self.skipped_steps = 0

    def run(self, state, *, num_steps: int, on_metrics=None):
        restored = self.ckpt.restore_latest(state)
        start = 0
        data_state = None
        if restored is not None:
            start, state, extra = restored
            data_state = extra.get("data_state")
            log.info("resumed from checkpoint step %d", start)

        from repro.data.pipeline import DataState
        ds = (DataState.from_dict(data_state) if data_state
              else DataState(step=start))
        it = self.data.iterator(ds)

        retries = 0
        step = start
        while step < num_steps:
            batch, ds = next(it)
            try:
                self.watchdog.arm(step)
                new_state, metrics = self.step_fn(state, batch)
                metrics = jax.device_get(metrics)
                self.watchdog.disarm()
            except Exception:
                self.watchdog.disarm()
                retries += 1
                if retries > self.max_retries:
                    raise
                log.exception("step %d failed; restoring last checkpoint "
                              "(retry %d/%d)", step, retries,
                              self.max_retries)
                restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    step, state, extra = restored
                    ds = DataState.from_dict(extra.get(
                        "data_state", {"step": step}))
                    it = self.data.iterator(ds)
                continue

            if not guard_update(metrics):
                # the train step suppressed the update in-graph (train/step.py
                # 'applied' guard); record the event and move on
                log.warning("step %d non-finite (loss=%s) — update was "
                            "suppressed in-graph", step, metrics.get("loss"))
                self.skipped_steps += 1

            state = new_state
            retries = 0
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state,
                               extra={"data_state": ds.to_dict()})
        self.ckpt.wait()
        return state
