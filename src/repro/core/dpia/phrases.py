"""DPIA phrase AST (paper Fig. 4) with HOAS binders.

Functions inside phrases (the argument of ``map``, loop bodies, ``new``
scopes) are represented as Python callables receiving ``Var`` nodes — higher
order abstract syntax.  Beta reduction (all over Stage II) is function
application; printing / checking instantiate binders with fresh variables.

The strategy annotations of the paper's section 6 appear as ``level`` tags on
``map`` / ``reduce`` / ``parfor`` (OpenCL's workgroup/local/seq hierarchy,
re-based for TPU: mesh axis / Pallas grid dim / VPU lanes / sequential) and as
``space`` tags (toGlobal/toLocal/toPrivate -> HBM/VMEM/REG).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

from .types import (
    AccT, Arr, CommT, DataType, ExpT, FnT, Idx, Num, Pair, PhraseType, Vec,
    VarT, data_eq, dtype_of, is_numeric, promote_dtype, scalar_of, shape_of,
    show_data,
)

_counter = itertools.count()


def fresh(prefix: str = "x") -> str:
    return f"{prefix}_{next(_counter)}"


# ---------------------------------------------------------------------------
# Strategy levels (the paper's parallelism hierarchy, TPU re-based)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Par:
    """Where a map/reduce/parfor runs.

    kind: 'seq'   — sequential loop (paper: mapSeq / for)
          'par'   — unassigned parallel (paper: plain map / parfor)
          'grid'  — Pallas grid dimension ``axis`` (paper: mapWorkgroup/Local)
          'lanes' — whole-block VPU op (paper: asVector-ised map)
          'mesh'  — shard_map over mesh axis ``axis`` (our multi-device level)
    """
    kind: str
    axis: Union[int, str, None] = None

    def __repr__(self) -> str:
        return self.kind if self.axis is None else f"{self.kind}({self.axis})"


SEQ = Par("seq")
PAR = Par("par")
LANES = Par("lanes")


def GRID(axis: int = 0) -> Par:
    return Par("grid", axis)


def MESH(axis: str) -> Par:
    return Par("mesh", axis)


# Memory spaces (paper: global/local/private -> TPU: HBM/VMEM/registers)
HBM, VMEM, REG = "hbm", "vmem", "reg"


# ---------------------------------------------------------------------------
# AST nodes
# ---------------------------------------------------------------------------

class Phrase:
    def __repr__(self) -> str:  # pragma: no cover
        from .pretty import show
        try:
            return show(self)
        except Exception:
            return object.__repr__(self)


@dataclass(frozen=True, repr=False)
class Var(Phrase):
    name: str
    t: PhraseType


# -- functional expressions (Fig. 4a) ---------------------------------------

@dataclass(frozen=True, repr=False)
class Lit(Phrase):
    value: float
    d: DataType = Num("float32")


@dataclass(frozen=True, repr=False)
class UnOp(Phrase):
    op: str  # 'neg' | 'exp' | 'rsqrt' | 'abs' | 'log' | 'tanh' | 'sigmoid'
    e: Phrase


@dataclass(frozen=True, repr=False)
class BinOp(Phrase):
    op: str  # 'add' | 'sub' | 'mul' | 'div' | 'max' | 'min'
    a: Phrase
    b: Phrase


@dataclass(frozen=True, repr=False)
class Map(Phrase):
    f: Callable[[Phrase], Phrase]
    e: Phrase
    level: Par = PAR
    space: Optional[str] = None  # to{HBM,VMEM,REG} wrapper on the output


@dataclass(frozen=True, repr=False)
class Reduce(Phrase):
    f: Callable[[Phrase, Phrase], Phrase]  # (x, acc) -> acc'
    init: Phrase
    e: Phrase
    level: Par = SEQ


@dataclass(frozen=True, repr=False)
class Zip(Phrase):
    a: Phrase
    b: Phrase


@dataclass(frozen=True, repr=False)
class Split(Phrase):
    n: int  # chunk size; exp[(m*n).d] -> exp[m.n.d]
    e: Phrase


@dataclass(frozen=True, repr=False)
class Join(Phrase):
    e: Phrase  # exp[n.m.d] -> exp[(n*m).d]


@dataclass(frozen=True, repr=False)
class PairE(Phrase):
    a: Phrase
    b: Phrase


@dataclass(frozen=True, repr=False)
class Fst(Phrase):
    e: Phrase


@dataclass(frozen=True, repr=False)
class Snd(Phrase):
    e: Phrase


@dataclass(frozen=True, repr=False)
class IdxE(Phrase):
    e: Phrase  # exp[n.d]
    i: Phrase  # exp[idx(n)]


@dataclass(frozen=True, repr=False)
class AsVector(Phrase):
    w: int
    e: Phrase  # exp[(m*w).num] -> exp[m.num<w>]


@dataclass(frozen=True, repr=False)
class AsScalar(Phrase):
    e: Phrase  # exp[m.num<w>] -> exp[(m*w).num]


@dataclass(frozen=True, repr=False)
class Transpose(Phrase):
    e: Phrase  # exp[n.m.d] -> exp[m.n.d]


@dataclass(frozen=True, repr=False)
class DotBlock(Phrase):
    """MXU leaf contraction (TPU adaptation; DESIGN.md section 2).

    (k,)x(k,) -> num | (n,k)x(k,) -> (n,) | (n,k)x(k,m) -> (n,m).
    """
    a: Phrase
    b: Phrase
    acc_dtype: str = "float32"


@dataclass(frozen=True, repr=False)
class FullReduce(Phrase):
    """Whole-block VPU reduction: exp[n....num] -> exp[num]."""
    op: str  # 'add' | 'max'
    e: Phrase


@dataclass(frozen=True, repr=False)
class ToMem(Phrase):
    """Paper section 6.2 to{Global,Local,Private}: semantically the identity;
    steers where the translation materialises the wrapped value."""
    space: str
    e: Phrase


# -- imperative phrases (Fig. 4b) --------------------------------------------

@dataclass(frozen=True, repr=False)
class Skip(Phrase):
    pass


@dataclass(frozen=True, repr=False)
class SeqC(Phrase):
    c1: Phrase
    c2: Phrase


@dataclass(frozen=True, repr=False)
class Assign(Phrase):
    a: Phrase  # acc[d]
    e: Phrase  # exp[d]


@dataclass(frozen=True, repr=False)
class New(Phrase):
    d: DataType
    f: Callable[[Phrase], Phrase]  # var[d] -> comm
    space: str = HBM


@dataclass(frozen=True, repr=False)
class For(Phrase):
    n: int
    f: Callable[[Phrase], Phrase]  # exp[idx(n)] -> comm
    unroll: bool = False


@dataclass(frozen=True, repr=False)
class ParFor(Phrase):
    n: int
    d: DataType
    a: Phrase  # acc[n.d]
    f: Callable[[Phrase, Phrase], Phrase]  # (exp[idx(n)], acc[d]) ->p comm
    level: Par = PAR


# variable projections: var[d] = acc[d] x exp[d]
@dataclass(frozen=True, repr=False)
class AccPart(Phrase):
    v: Phrase


@dataclass(frozen=True, repr=False)
class ExpPart(Phrase):
    v: Phrase


@dataclass(frozen=True, repr=False)
class VView(Phrase):
    """A virtual ``var[d]`` built from an (acceptor, expression) pair.

    Introduced by allocation hoisting (paper section 6.4): the hoisted loop body
    receives a view of the enlarged outer buffer in place of its own ``new``."""
    acc: Phrase  # acc[d]
    exp: Phrase  # exp[d]


# acceptor-side data layout combinators (Fig. 4b)
@dataclass(frozen=True, repr=False)
class IdxAcc(Phrase):
    a: Phrase  # acc[n.d]
    i: Phrase  # exp[idx(n)]


@dataclass(frozen=True, repr=False)
class SplitAcc(Phrase):
    n: int
    a: Phrase  # acc[m.n.d] -> acc[(m*n).d]


@dataclass(frozen=True, repr=False)
class JoinAcc(Phrase):
    m: int
    a: Phrase  # acc[(n*m).d] -> acc[n.m.d]


@dataclass(frozen=True, repr=False)
class PairAcc1(Phrase):
    a: Phrase  # acc[d1 x d2] -> acc[d1]


@dataclass(frozen=True, repr=False)
class PairAcc2(Phrase):
    a: Phrase


@dataclass(frozen=True, repr=False)
class ZipAcc1(Phrase):
    a: Phrase  # acc[n.(d1 x d2)] -> acc[n.d1]


@dataclass(frozen=True, repr=False)
class ZipAcc2(Phrase):
    a: Phrase


@dataclass(frozen=True, repr=False)
class TransposeAcc(Phrase):
    a: Phrase  # acc[m.n.d] -> acc[n.m.d]


@dataclass(frozen=True, repr=False)
class AsScalarAcc(Phrase):
    a: Phrase  # acc[m.num<w>] -> acc[(m*w).num]


@dataclass(frozen=True, repr=False)
class AsVectorAcc(Phrase):
    w: int
    a: Phrase  # acc[(m*w).num] -> acc[m.num<w>]


# intermediate imperative combinators (Fig. 4c)
@dataclass(frozen=True, repr=False)
class MapI(Phrase):
    n: int
    d1: DataType
    d2: DataType
    f: Callable[[Phrase, Phrase], Phrase]  # (exp[d1], acc[d2]) ->p comm
    e: Phrase  # exp[n.d1]
    a: Phrase  # acc[n.d2]
    level: Par = PAR


@dataclass(frozen=True, repr=False)
class ReduceI(Phrase):
    n: int
    d1: DataType
    d2: DataType
    f: Callable[[Phrase, Phrase, Phrase], Phrase]  # (exp[d1],exp[d2],acc[d2])->comm
    init: Phrase  # exp[d2]
    e: Phrase  # exp[n.d1]
    k: Callable[[Phrase], Phrase]  # exp[d2] -> comm


# ---------------------------------------------------------------------------
# Type inference (the typing rules of Fig. 3 + primitive signatures of Fig. 4,
# with sizes concrete).  Raises DpiaTypeError on ill-typed phrases.
# ---------------------------------------------------------------------------

class DpiaTypeError(TypeError):
    pass


def _expect_exp(p: Phrase, what: str) -> DataType:
    t = type_of(p)
    if not isinstance(t, ExpT):
        raise DpiaTypeError(f"{what}: expected an expression, got {t}")
    return t.d


def _expect_acc(p: Phrase, what: str) -> DataType:
    t = type_of(p)
    if not isinstance(t, AccT):
        raise DpiaTypeError(f"{what}: expected an acceptor, got {t}")
    return t.d


def _expect_arr(d: DataType, what: str) -> Arr:
    if not isinstance(d, Arr):
        raise DpiaTypeError(f"{what}: expected an array, got {show_data(d)}")
    return d


def _elementwise(op: str, da: DataType, db: DataType) -> DataType:
    """BinOp typing: same-shape numeric, or scalar broadcast against array/vec.

    The paper types (+,*,...) at num only; the TPU adaptation lifts them
    pointwise to whole blocks (VPU ops)."""
    if not (is_numeric(da) and is_numeric(db)):
        raise DpiaTypeError(f"{op}: non-numeric operands "
                            f"{show_data(da)}, {show_data(db)}")
    if isinstance(da, (Num, Idx)) and not isinstance(db, (Num, Idx)):
        return db
    if isinstance(db, (Num, Idx)) and not isinstance(da, (Num, Idx)):
        return da
    if shape_of(da) != shape_of(db):
        raise DpiaTypeError(f"{op}: shape mismatch "
                            f"{show_data(da)} vs {show_data(db)}")
    if isinstance(da, Idx) and isinstance(db, Idx):
        return Num("int32")
    return da


def _proj_type(d: DataType, which: int) -> DataType:
    """fst/snd at pairs, lifted pointwise through arrays (struct-of-arrays
    makes the lifted projection a no-op re-view; TPU adaptation)."""
    if isinstance(d, Pair):
        return d.fst if which == 0 else d.snd
    if isinstance(d, Arr):
        return Arr(d.n, _proj_type(d.elem, which))
    raise DpiaTypeError(f"fst/snd: not (an array of) pairs: {show_data(d)}")


def type_of(p: Phrase) -> PhraseType:  # noqa: C901 - structural dispatch
    if isinstance(p, Var):
        return p.t
    if isinstance(p, Lit):
        return ExpT(p.d)
    if isinstance(p, UnOp):
        d = _expect_exp(p.e, p.op)
        if not is_numeric(d):
            raise DpiaTypeError(f"{p.op}: non-numeric operand {show_data(d)}")
        return ExpT(d)
    if isinstance(p, BinOp):
        da = _expect_exp(p.a, p.op)
        db = _expect_exp(p.b, p.op)
        return ExpT(_elementwise(p.op, da, db))
    if isinstance(p, Map):
        d = _expect_exp(p.e, "map")
        a = _expect_arr(d, "map input")
        x = Var(fresh("x"), ExpT(a.elem))
        d2 = _expect_exp(p.f(x), "map body")
        return ExpT(Arr(a.n, d2))
    if isinstance(p, Reduce):
        d = _expect_exp(p.e, "reduce")
        a = _expect_arr(d, "reduce input")
        d2 = _expect_exp(p.init, "reduce init")
        x = Var(fresh("x"), ExpT(a.elem))
        acc = Var(fresh("acc"), ExpT(d2))
        d2b = _expect_exp(p.f(x, acc), "reduce body")
        if not data_eq(d2, d2b):
            raise DpiaTypeError(
                f"reduce: accumulator {show_data(d2)} vs body {show_data(d2b)}")
        return ExpT(d2)
    if isinstance(p, Zip):
        da = _expect_arr(_expect_exp(p.a, "zip"), "zip lhs")
        db = _expect_arr(_expect_exp(p.b, "zip"), "zip rhs")
        if da.n != db.n:
            raise DpiaTypeError(f"zip: lengths {da.n} vs {db.n}")
        return ExpT(Arr(da.n, Pair(da.elem, db.elem)))
    if isinstance(p, Split):
        d = _expect_arr(_expect_exp(p.e, "split"), "split input")
        if d.n % p.n != 0:
            raise DpiaTypeError(f"split: {d.n} not divisible by chunk {p.n}")
        return ExpT(Arr(d.n // p.n, Arr(p.n, d.elem)))
    if isinstance(p, Join):
        d = _expect_arr(_expect_exp(p.e, "join"), "join input")
        inner = _expect_arr(d.elem, "join inner")
        return ExpT(Arr(d.n * inner.n, inner.elem))
    if isinstance(p, PairE):
        return ExpT(Pair(_expect_exp(p.a, "pair"), _expect_exp(p.b, "pair")))
    if isinstance(p, Fst):
        return ExpT(_proj_type(_expect_exp(p.e, "fst"), 0))
    if isinstance(p, Snd):
        return ExpT(_proj_type(_expect_exp(p.e, "snd"), 1))
    if isinstance(p, IdxE):
        d = _expect_arr(_expect_exp(p.e, "idx"), "idx input")
        di = _expect_exp(p.i, "idx index")
        if not isinstance(di, (Idx, Num)):
            raise DpiaTypeError(f"idx: bad index type {show_data(di)}")
        return ExpT(d.elem)
    if isinstance(p, AsVector):
        d = _expect_arr(_expect_exp(p.e, "asVector"), "asVector input")
        if not isinstance(d.elem, Num):
            raise DpiaTypeError("asVector: element type must be num")
        if d.n % p.w != 0:
            raise DpiaTypeError(f"asVector: {d.n} not divisible by {p.w}")
        return ExpT(Arr(d.n // p.w, Vec(p.w, d.elem.dtype)))
    if isinstance(p, AsScalar):
        d = _expect_arr(_expect_exp(p.e, "asScalar"), "asScalar input")
        if not isinstance(d.elem, Vec):
            raise DpiaTypeError("asScalar: element type must be a vector")
        return ExpT(Arr(d.n * d.elem.n, Num(d.elem.dtype)))
    if isinstance(p, Transpose):
        d = _expect_arr(_expect_exp(p.e, "transpose"), "transpose input")
        inner = _expect_arr(d.elem, "transpose inner")
        return ExpT(Arr(inner.n, Arr(d.n, inner.elem)))
    if isinstance(p, DotBlock):
        da = _expect_exp(p.a, "dotBlock")
        db = _expect_exp(p.b, "dotBlock")
        sa, sb = shape_of(da), shape_of(db)
        out_dt = p.acc_dtype
        if len(sa) == 1 and len(sb) == 1 and sa == sb:
            return ExpT(Num(out_dt))
        if len(sa) == 2 and len(sb) == 1 and sa[1] == sb[0]:
            return ExpT(Arr(sa[0], Num(out_dt)))
        if len(sa) == 2 and len(sb) == 2 and sa[1] == sb[0]:
            return ExpT(Arr(sa[0], Arr(sb[1], Num(out_dt))))
        raise DpiaTypeError(f"dotBlock: bad shapes {sa} x {sb}")
    if isinstance(p, FullReduce):
        d = _expect_exp(p.e, "fullReduce")
        if not is_numeric(d) or not isinstance(d, (Arr, Vec)):
            raise DpiaTypeError(f"fullReduce: need numeric array, got {show_data(d)}")
        return ExpT(Num(dtype_of(d)))
    if isinstance(p, ToMem):
        return ExpT(_expect_exp(p.e, "toMem"))
    # imperative
    if isinstance(p, Skip):
        return CommT()
    if isinstance(p, SeqC):
        for c in (p.c1, p.c2):
            if not isinstance(type_of(c), CommT):
                raise DpiaTypeError("seq: operand not a command")
        return CommT()
    if isinstance(p, Assign):
        da = _expect_acc(p.a, "assign lhs")
        de = _expect_exp(p.e, "assign rhs")
        if shape_of(da) != shape_of(de):
            raise DpiaTypeError(
                f"assign: {show_data(da)} := {show_data(de)} shape mismatch")
        return CommT()
    if isinstance(p, New):
        v = Var(fresh("v"), VarT(p.d))
        if not isinstance(type_of(p.f(v)), CommT):
            raise DpiaTypeError("new: body not a command")
        return CommT()
    if isinstance(p, For):
        i = Var(fresh("i"), ExpT(Idx(p.n)))
        if not isinstance(type_of(p.f(i)), CommT):
            raise DpiaTypeError("for: body not a command")
        return CommT()
    if isinstance(p, ParFor):
        da = _expect_acc(p.a, "parfor out")
        arr_d = _expect_arr(da, "parfor out")
        if arr_d.n != p.n or not data_eq(arr_d.elem, p.d):
            raise DpiaTypeError(
                f"parfor: acceptor {show_data(da)} does not match "
                f"{p.n}.{show_data(p.d)}")
        i = Var(fresh("i"), ExpT(Idx(p.n)))
        o = Var(fresh("o"), AccT(p.d))
        if not isinstance(type_of(p.f(i, o)), CommT):
            raise DpiaTypeError("parfor: body not a command")
        return CommT()
    if isinstance(p, VView):
        da = _expect_acc(p.acc, "vview acc")
        de = _expect_exp(p.exp, "vview exp")
        if not data_eq(da, de):
            raise DpiaTypeError("vview: acc/exp type mismatch")
        return VarT(da)
    if isinstance(p, AccPart):
        if isinstance(p.v, VView):
            return type_of(p.v.acc)
        t = type_of(p.v)
        if not isinstance(t, VarT):
            raise DpiaTypeError(f"'.1' of non-variable {t}")
        return AccT(t.d)
    if isinstance(p, ExpPart):
        if isinstance(p.v, VView):
            return type_of(p.v.exp)
        t = type_of(p.v)
        if not isinstance(t, VarT):
            raise DpiaTypeError(f"'.2' of non-variable {t}")
        return ExpT(t.d)
    if isinstance(p, IdxAcc):
        d = _expect_arr(_expect_acc(p.a, "idxAcc"), "idxAcc input")
        return AccT(d.elem)
    if isinstance(p, SplitAcc):
        d = _expect_arr(_expect_acc(p.a, "splitAcc"), "splitAcc input")
        inner = _expect_arr(d.elem, "splitAcc inner")
        if inner.n != p.n:
            raise DpiaTypeError("splitAcc: chunk mismatch")
        return AccT(Arr(d.n * inner.n, inner.elem))
    if isinstance(p, JoinAcc):
        d = _expect_arr(_expect_acc(p.a, "joinAcc"), "joinAcc input")
        if d.n % p.m != 0:
            raise DpiaTypeError("joinAcc: not divisible")
        return AccT(Arr(d.n // p.m, Arr(p.m, d.elem)))
    if isinstance(p, PairAcc1):
        d = _expect_acc(p.a, "pairAcc1")
        if not isinstance(d, Pair):
            raise DpiaTypeError("pairAcc1: not a pair acceptor")
        return AccT(d.fst)
    if isinstance(p, PairAcc2):
        d = _expect_acc(p.a, "pairAcc2")
        if not isinstance(d, Pair):
            raise DpiaTypeError("pairAcc2: not a pair acceptor")
        return AccT(d.snd)
    if isinstance(p, ZipAcc1):
        d = _expect_arr(_expect_acc(p.a, "zipAcc1"), "zipAcc1 input")
        if not isinstance(d.elem, Pair):
            raise DpiaTypeError("zipAcc1: element not a pair")
        return AccT(Arr(d.n, d.elem.fst))
    if isinstance(p, ZipAcc2):
        d = _expect_arr(_expect_acc(p.a, "zipAcc2"), "zipAcc2 input")
        if not isinstance(d.elem, Pair):
            raise DpiaTypeError("zipAcc2: element not a pair")
        return AccT(Arr(d.n, d.elem.snd))
    if isinstance(p, TransposeAcc):
        d = _expect_arr(_expect_acc(p.a, "transposeAcc"), "transposeAcc input")
        inner = _expect_arr(d.elem, "transposeAcc inner")
        return AccT(Arr(inner.n, Arr(d.n, inner.elem)))
    if isinstance(p, AsScalarAcc):
        d = _expect_arr(_expect_acc(p.a, "asScalarAcc"), "asScalarAcc input")
        if not isinstance(d.elem, Vec):
            raise DpiaTypeError("asScalarAcc: element not a vector")
        return AccT(Arr(d.n * d.elem.n, Num(d.elem.dtype)))
    if isinstance(p, AsVectorAcc):
        d = _expect_arr(_expect_acc(p.a, "asVectorAcc"), "asVectorAcc input")
        if not isinstance(d.elem, Num) or d.n % p.w != 0:
            raise DpiaTypeError("asVectorAcc: bad input")
        return AccT(Arr(d.n // p.w, Vec(p.w, d.elem.dtype)))
    if isinstance(p, MapI):
        de = _expect_exp(p.e, "mapI input")
        da = _expect_acc(p.a, "mapI output")
        if not data_eq(de, Arr(p.n, p.d1)) or not data_eq(da, Arr(p.n, p.d2)):
            raise DpiaTypeError(
                f"mapI: {show_data(de)} -> {show_data(da)} vs declared "
                f"{p.n}.{show_data(p.d1)} -> {p.n}.{show_data(p.d2)}")
        x = Var(fresh("x"), ExpT(p.d1))
        o = Var(fresh("o"), AccT(p.d2))
        if not isinstance(type_of(p.f(x, o)), CommT):
            raise DpiaTypeError("mapI: body not a command")
        return CommT()
    if isinstance(p, ReduceI):
        de = _expect_exp(p.e, "reduceI input")
        if not data_eq(de, Arr(p.n, p.d1)):
            raise DpiaTypeError("reduceI: input type mismatch")
        di = _expect_exp(p.init, "reduceI init")
        if not data_eq(di, p.d2):
            raise DpiaTypeError("reduceI: init type mismatch")
        x = Var(fresh("x"), ExpT(p.d1))
        y = Var(fresh("y"), ExpT(p.d2))
        o = Var(fresh("o"), AccT(p.d2))
        if not isinstance(type_of(p.f(x, y, o)), CommT):
            raise DpiaTypeError("reduceI: body not a command")
        r = Var(fresh("r"), ExpT(p.d2))
        if not isinstance(type_of(p.k(r)), CommT):
            raise DpiaTypeError("reduceI: continuation not a command")
        return CommT()
    raise DpiaTypeError(f"unknown phrase {p!r}")


def exp_data(p: Phrase) -> DataType:
    return _expect_exp(p, "exp_data")


def acc_data(p: Phrase) -> DataType:
    return _expect_acc(p, "acc_data")


# ---------------------------------------------------------------------------
# Ergonomic constructors
# ---------------------------------------------------------------------------

def lit(v, dtype: str = "float32") -> Lit:
    return Lit(float(v), Num(dtype))


def var_exp(name: str, d: DataType) -> Var:
    return Var(name, ExpT(d))


def var_acc(name: str, d: DataType) -> Var:
    return Var(name, AccT(d))


def add(a, b):
    return BinOp("add", a, b)


def sub(a, b):
    return BinOp("sub", a, b)


def mul(a, b):
    return BinOp("mul", a, b)


def div(a, b):
    return BinOp("div", a, b)


def fmax(a, b):
    return BinOp("max", a, b)


def map_seq(f, e):
    return Map(f, e, level=SEQ)


def map_par(f, e):
    return Map(f, e, level=PAR)


def map_grid(axis: int):
    return lambda f, e: Map(f, e, level=GRID(axis))


def map_lanes(f, e):
    return Map(f, e, level=LANES)


def map_mesh(axis: str):
    return lambda f, e: Map(f, e, level=MESH(axis))


def reduce_seq(f, init, e):
    return Reduce(f, init, e, level=SEQ)


def to_vmem(e):
    return ToMem(VMEM, e)


def to_reg(e):
    return ToMem(REG, e)


def to_hbm(e):
    return ToMem(HBM, e)
