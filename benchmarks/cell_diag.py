"""Per-cell diagnostic for the perf hillclimb: lower one (arch x shape) cell
on a reduced mesh, break down FLOPs/bytes/collectives by kind, and report
the roofline terms — the 'profile' of the dry-run world.

    PYTHONPATH=src python -m benchmarks.cell_diag --arch dbrx_132b \
        --shape train_4k [--devices 16 --mesh 4x4]
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    import numpy as np
    import jax
    from jax.sharding import Mesh

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = Mesh(np.array(jax.devices()[:int(np.prod(dims))]).reshape(dims),
                names)

    from repro.launch import dryrun
    dryrun._mesh = lambda mp: mesh
    import time
    t0 = time.time()
    rec = dryrun.lower_cell(args.arch, args.shape, False)
    print(f"[{args.arch} x {args.shape} on {args.mesh}] "
          f"{rec['status']} in {time.time()-t0:.0f}s")
    if rec["status"] != "ok":
        print(rec.get("reason") or rec.get("trace", "")[-2000:])
        return
    r = rec["roofline"]
    for k in ("flops", "bytes", "bytes_min", "coll_bytes", "compute_s",
              "memory_s", "memory_floor_s", "collective_s", "bottleneck",
              "useful_frac"):
        print(f"  {k:16s} {r.get(k)}")

    # detailed breakdown requires re-lowering with text capture
    print("\n-- re-lowering for kind breakdown --")
    from repro.analysis import hlo_counter as H
    from repro.configs import config
    from repro.launch import specs as S
    from repro.sharding import rules
    from repro.train.step import make_train_step, state_specs
    from jax.sharding import NamedSharding, PartitionSpec as PS
    import jax

    cfg = config(args.arch)
    model = S.model_for(cfg, args.shape)
    cfg = model.cfg
    named = lambda s: jax.tree_util.tree_map(  # noqa: E731
        lambda x: NamedSharding(mesh, x), s,
        is_leaf=lambda x: isinstance(x, PS))
    kind = S.SHAPES[args.shape]["kind"]
    if kind == "train":
        state_sds = S.train_state_sds(model)
        st_spec = state_specs(state_sds, mesh, cfg)
        step_fn, _, _ = make_train_step(model, mesh)
        batch_sds, batch_spec = S.input_specs(cfg, args.shape, mesh)
        fn = jax.jit(step_fn, in_shardings=(named(st_spec), named(batch_spec)),
                     out_shardings=(named(st_spec), None), donate_argnums=(0,))
        txt = fn.lower(state_sds, batch_sds).compile().as_text()
    elif kind == "prefill":
        params = S.params_sds(model)
        p_spec = rules.params_specs(params, mesh, cfg)
        cache = S.cache_sds(model, args.shape)
        c_spec = rules.cache_specs(cfg, mesh, cache)
        data_sds, data_spec = S.input_specs(cfg, args.shape, mesh)
        fn = jax.jit(lambda p, t, c: model.prefill(p, t, c),
                     in_shardings=(named(p_spec), named(data_spec["tokens"]),
                                   named(c_spec)),
                     out_shardings=(None, named(c_spec)), donate_argnums=(2,))
        txt = fn.lower(params, data_sds["tokens"], cache).compile().as_text()
    else:
        params = S.params_sds(model)
        p_spec = rules.params_specs(params, mesh, cfg)
        cache = S.cache_sds(model, args.shape)
        c_spec = rules.cache_specs(cfg, mesh, cache)
        data_sds, data_spec = S.input_specs(cfg, args.shape, mesh)
        fn = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos),
                     in_shardings=(named(p_spec), named(data_spec["token"]),
                                   named(c_spec), None),
                     out_shardings=(None, named(c_spec)), donate_argnums=(2,))
        txt = fn.lower(params, data_sds["token"], cache,
                       data_sds["pos"]).compile().as_text()

    m = H.HloModule(txt)
    from collections import Counter
    coll = Counter()
    fus = Counter()

    def walk(name, scale):
        comp = m.computations.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                walk(ins.attr("body"),
                     scale * m._trip_count(ins.attr("condition") or ""))
                continue
            if ins.op.replace("-start", "") in (
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"):
                coll[ins.op.replace("-start", "")] += \
                    H._bytes_of(ins.type_str) * scale
            if ins.op == "fusion":
                b, _ = m._fusion_bytes(comp, ins)
                fus[(ins.name.split(".")[0], ins.type_str[:44])] += b * scale

    walk(m.entry, 1.0)
    print("collective bytes by kind (per partition):")
    for k, b in coll.most_common():
        print(f"  {k:22s} {b/1e9:10.2f} GB")
    print("top fusion traffic (per partition):")
    for k, b in fus.most_common(10):
        print(f"  {b/1e9:8.1f} GB  {k[0][:36]:38s} {k[1]}")


if __name__ == "__main__":
    main()
