"""Span-based tracer with Chrome/Perfetto trace-event export.

Design constraints, in priority order:

  1. **Near-zero overhead when disabled.**  ``span(...)`` returns a shared
     no-op context manager and ``@traced`` functions call straight through
     — the disabled cost is one attribute read and one ``if``.  Nothing is
     allocated, no generator frames, no locks.
  2. **Thread-safe when enabled.**  Each thread keeps its own span *stack*
     (``threading.local``) so nesting is per-thread; completed events are
     appended to one shared buffer under a lock (appends are rare — one per
     span exit, not per operation inside the span).
  3. **Standard output format.**  ``to_chrome()`` emits the Chrome
     trace-event JSON object form (``{"traceEvents": [...]}``) that
     ``chrome://tracing`` and https://ui.perfetto.dev load directly:
     complete events (``ph: "X"``) for spans, instant events (``ph: "i"``)
     for point events, microsecond timestamps relative to the trace epoch.

Spans nest lexically::

    with trace.span("serve.step_chunk", slots=4):
        with trace.span("serve.decode_chunk"):
            ...

and the exporter's ``X`` events reconstruct the hierarchy from the
timestamps; the explicit per-thread stack additionally gives each event its
parent's name (``args["parent"]``) so a flat JSON consumer can group
without interval math.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Tracer", "tracer", "span", "traced", "instant",
           "enable", "disable", "enabled", "events", "clear", "to_chrome",
           "export", "set_span_sink"]

# Optional tap on span completions (the flight recorder registers here).
# Only consulted from _record, i.e. when tracing is enabled — the disabled
# path stays one attribute read + one ``if``.
_span_sink = None


def set_span_sink(fn) -> None:
    """Register ``fn(name, dur_us, args, error)`` to observe every span
    completion while tracing is enabled; ``None`` unregisters."""
    global _span_sink
    _span_sink = fn


class _NullSpan:
    """The disabled-mode context manager: one shared instance, no state."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records a complete ("ph": "X") event on exit."""
    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        self._tracer._stack().append(self.name)
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        stack = self._tracer._stack()
        stack.pop()
        self._tracer._record(self.name, self._t0, t1,
                             parent=stack[-1] if stack else None,
                             args=self.args,
                             error=exc_type.__name__ if exc_type else None)
        return False


class Tracer:
    """Process-wide event buffer + the enabled flag the hot paths read."""

    def __init__(self):
        self._enabled = False
        self._events: List[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._epoch_ns = time.perf_counter_ns()
        self._pid = os.getpid()

    # -- state ---------------------------------------------------------------

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    @property
    def enabled(self) -> bool:
        return self._enabled

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self._epoch_ns = time.perf_counter_ns()

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def depth(self) -> int:
        """Current span nesting depth on the calling thread."""
        return len(self._stack())

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args):
        """Context manager timing a region; a shared no-op when disabled."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        """A point event ("ph": "i"); dropped (one if) when disabled."""
        if not self._enabled:
            return
        t = time.perf_counter_ns()
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": (t - self._epoch_ns) / 1e3,
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = _jsonable(args)
        with self._lock:
            self._events.append(ev)

    def _record(self, name: str, t0_ns: int, t1_ns: int, *,
                parent: Optional[str], args: Optional[dict],
                error: Optional[str]) -> None:
        ev = {"name": name, "ph": "X",
              "ts": (t0_ns - self._epoch_ns) / 1e3,
              "dur": (t1_ns - t0_ns) / 1e3,
              "pid": self._pid, "tid": threading.get_ident()}
        extra = dict(args) if args else {}
        if parent is not None:
            extra["parent"] = parent
        if error is not None:
            extra["error"] = error
        if extra:
            ev["args"] = _jsonable(extra)
        with self._lock:
            self._events.append(ev)
        if _span_sink is not None:
            _span_sink(name, ev["dur"], args, error)

    # -- export --------------------------------------------------------------

    def events(self) -> List[dict]:
        """Snapshot of the recorded events (copies; safe to mutate)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def to_chrome(self) -> Dict[str, object]:
        """The Chrome trace-event JSON document (Perfetto-loadable)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (atomic tmp + rename)."""
        doc = self.to_chrome()
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return path


def _jsonable(d: dict) -> dict:
    """Coerce span args to JSON-safe scalars (repr anything exotic) so a
    stray array/object in an arg can never make the export unloadable."""
    out = {}
    for k, v in d.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool)) or x is None
                      else repr(x) for x in v]
        elif isinstance(v, dict):
            out[k] = _jsonable(v)
        else:
            out[k] = repr(v)
    return out


# ---------------------------------------------------------------------------
# module-level singleton + convenience API
# ---------------------------------------------------------------------------

tracer = Tracer()

span = tracer.span
instant = tracer.instant
enable = tracer.enable
disable = tracer.disable
events = tracer.events
clear = tracer.clear
to_chrome = tracer.to_chrome
export = tracer.export


def enabled() -> bool:
    return tracer._enabled


def traced(name: Optional[str] = None, **attrs):
    """Decorator: wrap calls in a span.  Disabled mode calls straight
    through — one attribute read + one ``if`` of overhead."""
    def deco(fn):
        label = name or fn.__qualname__
        import functools

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not tracer._enabled:
                return fn(*a, **kw)
            with tracer.span(label, **attrs):
                return fn(*a, **kw)
        return wrapper
    return deco


# $REPRO_TRACE=1 enables tracing at import; a path value ("…/trace.json")
# additionally registers an atexit export so ad-hoc runs need no code
_env = os.environ.get("REPRO_TRACE", "")
if _env and _env.lower() not in ("0", "false", "no", "off"):
    tracer.enable()
    if _env.lower() not in ("1", "true", "yes", "on"):
        import atexit
        atexit.register(lambda: tracer.export(_env))
