"""ShapeDtypeStruct stand-ins for every (arch x input-shape) cell + the
sharding specs that go with them.  Used by the dry-run (no allocation) and by
the roofline analyzer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as PS

from repro.models.common import ModelConfig
from repro.models.transformer import Model
from repro.sharding import rules

# the assigned shape grid (LM-family: seq_len x global_batch)
SHAPES: Dict[str, Dict] = {
    "train_4k":    {"seq": 4_096,   "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32_768,  "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32_768,  "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524_288, "batch": 1,   "kind": "decode"},
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (DESIGN.md section 5)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k skipped: pure full-attention architecture "
                       "(O(s^2) prefill / O(s) KV per step at 524k is out of "
                       "scope per the assignment)")
    return True, ""


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.shape]))


def _batch_ps(mesh: Mesh, batch: int) -> PS:
    dp = rules.dp_axes(mesh)
    if dp and batch % _dp_size(mesh) == 0:
        return PS(dp)
    return PS(None)


def input_specs(cfg: ModelConfig, shape: str, mesh: Optional[Mesh] = None):
    """Returns (sds_pytree, spec_pytree) for the step function's data inputs."""
    info = SHAPES[shape]
    seq, batch, kind = info["seq"], info["batch"], info["kind"]
    tok_shape = (batch, seq)
    if cfg.n_codebooks:
        tok_shape = tok_shape + (cfg.n_codebooks,)

    if kind == "train":
        sds = {
            "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        if mesh is None:
            return sds, None
        bp = _batch_ps(mesh, batch)
        return sds, {"tokens": bp, "labels": bp}

    if kind == "prefill":
        sds = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        if mesh is None:
            return sds, None
        return sds, {"tokens": _batch_ps(mesh, batch)}

    # decode: one new token against a seq-long cache
    tok1 = (batch, 1) + ((cfg.n_codebooks,) if cfg.n_codebooks else ())
    sds = {
        "token": jax.ShapeDtypeStruct(tok1, jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if mesh is None:
        return sds, None
    return sds, {"token": _batch_ps(mesh, batch), "pos": PS()}


def model_for(cfg: ModelConfig, shape: str) -> Model:
    info = SHAPES[shape]
    cfg = dataclasses.replace(cfg, max_seq=info["seq"])
    return Model(cfg)


def cache_sds(model: Model, shape: str):
    info = SHAPES[shape]
    return jax.eval_shape(
        lambda: model.init_cache(info["batch"], info["seq"]))


def params_sds(model: Model):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(model.init_params, key)


def train_state_sds(model: Model):
    from repro.train.step import make_train_state
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: make_train_state(model, k, use_8bit=model.cfg.opt_8bit), key)
