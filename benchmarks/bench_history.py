"""Append headline bench metrics to a committed history file.

The serve and resilience benches each write a full snapshot
(``BENCH_serve.json``, ``BENCH_resilience.json``) that is overwritten on
every run — good for "what is the current number", useless for "when did
it regress".  This tool distils the handful of headline metrics worth
tracking over time — decode throughput, recompiles after warm-up, drift
audit firings, resilience outcomes — into one compact entry and appends
it to ``BENCH_history.json``, which IS committed, so the repo's own git
log doubles as a perf/regression timeline.

Entry shape (validated by ``validate_trace.py --history``)::

    {"t": "2026-08-08T12:00:00Z",          # UTC ISO timestamp
     "serve": {"fused_tok_s": ..., "continuous_tok_s": ...},
     "recompiles": 0,                      # decode recompiles after warm
     "drift": 0,                           # tune.drift firings observed
     "resilience": {"faults_injected": ..., "clean_identical": ...,
                    "flight_dumps": ...},
     "host_loss": {"events": ..., "evacuations": ...,   # only when the
                   "token_identical": ...},             # drill phase ran
     "note": "..."}                        # optional, e.g. the git sha

Usage:
  PYTHONPATH=src python benchmarks/bench_history.py \
      [--serve BENCH_serve.json] [--resilience BENCH_resilience.json] \
      [--out BENCH_history.json] [--note TEXT]

Missing input files are skipped (their sections stay empty/zero) so the
tool works in CI legs that only ran one bench.  History is capped at the
most recent ``--keep`` entries (default 200).
"""
from __future__ import annotations

import argparse
import json
import os
import time

__all__ = ["headline_entry", "append_history", "main"]


def _load(path):
    if not path or not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _metric_value(doc, name, default=0.0):
    """A counter/gauge value out of a bench doc's embedded metrics snapshot."""
    m = (doc or {}).get("metrics") or {}
    entry = m.get(name)
    if isinstance(entry, dict) and isinstance(entry.get("value"), (int, float)):
        return float(entry["value"])
    return float(default)


def headline_entry(serve_doc=None, resil_doc=None, note="", t=None):
    """Distil the bench docs into one history entry (see module docstring)."""
    serve = {}
    recompiles = 0.0
    if serve_doc:
        dec = serve_doc.get("decode") or {}
        for src, dst in (("fused_tok_s", "fused_tok_s"),
                         ("continuous_tok_s_end_to_end", "continuous_tok_s"),
                         ("speedup_fused_vs_legacy", "speedup_fused")):
            v = dec.get(src)
            if isinstance(v, (int, float)):
                serve[dst] = round(float(v), 3)
        rc = serve_doc.get("recompiles") or {}
        v = rc.get("decode_recompiles_after_warmup")
        if isinstance(v, (int, float)):
            recompiles = float(v)

    # drift firings: whichever doc carried the tune.drift counter, summed —
    # the counter is per-process, so the docs never double-count one run
    drift = (_metric_value(serve_doc, "tune.drift")
             + _metric_value(resil_doc, "tune.drift"))

    resilience = {}
    if resil_doc:
        for k in ("faults_injected", "clean_identical", "degradations"):
            v = resil_doc.get(k)
            if isinstance(v, (int, float)):
                resilience[k] = float(v)
        fl = resil_doc.get("flight") or {}
        if isinstance(fl.get("dumps"), (int, float)):
            resilience["flight_dumps"] = float(fl["dumps"])

    host_loss = {}
    if resil_doc:
        hl = resil_doc.get("host_loss") or {}
        for k in ("events", "evacuations", "token_identical", "requests"):
            v = hl.get(k)
            if isinstance(v, (int, float)):
                host_loss[k] = float(v)

    entry = {
        "t": t or time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "serve": serve,
        "recompiles": recompiles,
        "drift": drift,
        "resilience": resilience,
    }
    if host_loss:
        # the host-loss drill's headline: a regression here means the
        # engine stopped surviving mesh shrinks to token identity
        entry["host_loss"] = host_loss
    if note:
        entry["note"] = note
    return entry


def append_history(path, entry, keep=200):
    """Append ``entry`` to the JSON list at ``path`` (created if missing);
    returns the new history.  The file is rewritten whole — it is small by
    construction (``keep`` compact entries)."""
    hist = _load(path)
    if not isinstance(hist, list):
        hist = []
    hist.append(entry)
    hist = hist[-keep:]
    with open(path, "w") as f:
        json.dump(hist, f, indent=1, sort_keys=True)
        f.write("\n")
    return hist


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--serve", default="BENCH_serve.json")
    ap.add_argument("--resilience", default="BENCH_resilience.json")
    ap.add_argument("--out", default="BENCH_history.json")
    ap.add_argument("--note", default="", help="free-form tag (e.g. git sha)")
    ap.add_argument("--keep", type=int, default=200,
                    help="cap the history at the most recent N entries")
    args = ap.parse_args(argv)

    serve_doc = _load(args.serve)
    resil_doc = _load(args.resilience)
    if serve_doc is None and resil_doc is None:
        print("bench_history: no bench docs found — nothing to record")
        return 1
    entry = headline_entry(serve_doc, resil_doc, note=args.note)
    hist = append_history(args.out, entry, keep=args.keep)
    print(f"bench_history: appended entry {len(hist)} to {args.out}: "
          f"{json.dumps(entry, sort_keys=True)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
